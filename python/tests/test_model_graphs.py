"""L2 correctness: the JAX graph builders (model.py) — pallas and xla
flavors must agree with each other and with the reference composition, and
their lowered HLO must declare the shapes the manifest promises."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def materialize(args, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(args))
    return [
        jax.random.normal(k, tuple(a.shape), dtype=jnp.float32)
        for k, a in zip(keys, args)
    ]


BUILDERS = {
    "batched_gemm": lambda impl: model.build_batched_gemm(3, 32, 16, 24, impl=impl),
    "fused_linear": lambda impl: model.build_fused_linear(2, 8, 64, 32, impl=impl),
    "mlp_block": lambda impl: model.build_mlp_block(2, 8, 64, 32, 16, impl=impl),
    "rnn_cell": lambda impl: model.build_rnn_cell(2, 64, impl=impl),
}


@pytest.mark.parametrize("kind", sorted(BUILDERS))
def test_pallas_and_xla_flavors_agree(kind):
    fn_p, args = BUILDERS[kind]("pallas")
    fn_x, _ = BUILDERS[kind]("xla")
    vals = materialize(args, seed=hash(kind) % 2**31)
    out_p = fn_p(*vals)[0]
    out_x = fn_x(*vals)[0]
    np.testing.assert_allclose(out_p, out_x, rtol=1e-4, atol=1e-4)


def test_batched_gemm_vs_ref():
    fn, args = model.build_batched_gemm(2, 16, 8, 12, impl="pallas")
    a, b = materialize(args, seed=1)
    np.testing.assert_allclose(
        fn(a, b)[0], ref.batched_gemm_ref(a, b), rtol=1e-5, atol=1e-5
    )


def test_mlp_block_vs_ref():
    fn, args = model.build_mlp_block(2, 8, 32, 16, 8, impl="pallas")
    x, w1, b1, w2 = materialize(args, seed=2)
    np.testing.assert_allclose(
        fn(x, w1, b1, w2)[0],
        ref.mlp_block_ref(x, w1, b1, w2),
        rtol=1e-4,
        atol=1e-4,
    )


def test_rnn_cell_is_tanh_of_sum():
    fn, args = model.build_rnn_cell(2, 32, impl="pallas")
    w_ih, w_hh, x, h = materialize(args, seed=3)
    want = jnp.tanh(
        ref.batched_gemm_ref(w_ih, x) + ref.batched_gemm_ref(w_hh, h)
    )
    np.testing.assert_allclose(fn(w_ih, w_hh, x, h)[0], want, rtol=1e-5, atol=1e-5)


def test_rnn_cell_output_bounded():
    fn, args = model.build_rnn_cell(1, 16, impl="xla")
    vals = [v * 10 for v in materialize(args, seed=4)]
    out = np.asarray(fn(*vals)[0])
    assert (np.abs(out) <= 1.0).all(), "tanh output must be in [-1, 1]"


# ---------------------------------------------------------------------------
# Lowering / manifest contract
# ---------------------------------------------------------------------------

def test_lower_entry_produces_hlo_text():
    fn, args = model.build_batched_gemm(1, 8, 8, 8, impl="xla")
    text = aot.lower_entry(fn, args)
    assert "HloModule" in text
    assert "f32[1,8,8]" in text


def test_catalog_quick_subset():
    cat = aot.build_catalog(quick=True)
    names = {c["name"] for c in cat}
    # (3 table1 + 1 extra) shapes x 3 buckets x 2 impls
    #   + 3 serving kinds x 3 buckets x 2 impls
    n_shapes = len(aot.TABLE1_SHAPES) + len(aot.EXTRA_SHAPES)
    assert len(cat) == len(names) == (n_shapes * 3 + 3 * 3) * 2
    rs = {c["meta"]["r"] for c in cat}
    assert rs == {1, 2, 8}


def test_catalog_full_buckets():
    cat = aot.build_catalog(quick=False)
    rs = sorted({c["meta"]["r"] for c in cat})
    assert rs == aot.R_BUCKETS == [1, 2, 4, 8, 16, 32, 64]


def test_catalog_args_match_meta():
    for entry in aot.build_catalog(quick=True):
        r = entry["meta"]["r"]
        for a in entry["args"]:
            assert a.shape[0] == r, f"{entry['name']}: leading dim != R"
            assert a.dtype == jnp.float32


def test_lowered_entry_runs_under_jit():
    """What aot lowers must be exactly what jit executes."""
    fn, args = model.build_fused_linear(2, 4, 16, 8, impl="pallas")
    vals = materialize(args, seed=5)
    eager = fn(*vals)[0]
    jitted = jax.jit(fn)(*vals)[0]
    np.testing.assert_allclose(eager, jitted, rtol=1e-5, atol=1e-5)
