"""L1 correctness: the Pallas batched-GEMM super-kernel vs the pure-jnp
oracle, swept over shapes (hypothesis) and pinned on the paper's Table 1
shape classes."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.batched_gemm import (
    MXU_EDGE,
    VMEM_BUDGET_BYTES,
    assert_vmem_budget,
    batched_gemm,
    pick_tiles,
    vmem_report,
)

TABLE1_SHAPES = {
    "rnn_matvec": (512, 1, 512),
    "conv2_2": (256, 128, 1152),
    "square": (256, 256, 256),
}


def rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def make_inputs(r, m, n, k, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return rand(k1, r, m, k), rand(k2, r, k, n)


def tol(k):
    """f32 GEMM tolerance: accumulation-order error grows ~sqrt(K).

    The Pallas kernel accumulates in bk-sized chunks while the einsum
    reference uses a different reduction order; for K ~ 1e3 the reassociation
    error on N(0,1) inputs is ~1e-4 absolute. Scale atol accordingly.
    """
    atol = max(1e-5, 3e-6 * float(k) ** 0.5 * 4)
    return dict(rtol=1e-4, atol=atol)


# ---------------------------------------------------------------------------
# Pinned paper shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(TABLE1_SHAPES))
@pytest.mark.parametrize("r", [1, 2, 4, 8])
def test_table1_shapes_match_ref(name, r):
    m, n, k = TABLE1_SHAPES[name]
    a, b = make_inputs(r, m, n, k)
    got = batched_gemm(a, b)
    want = ref.batched_gemm_ref(a, b)
    np.testing.assert_allclose(got, want, **tol(k))


@pytest.mark.parametrize("r", [1, 3, 8])
def test_fused_bias_relu_matches_ref(r):
    m, n, k = 64, 32, 48
    a, b = make_inputs(r, m, n, k, seed=1)
    bias = rand(jax.random.PRNGKey(7), r, 1, n)
    got = batched_gemm(a, b, bias=bias, fuse_relu=True)
    want = ref.fused_linear_ref(a, b, bias)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert (np.asarray(got) >= 0.0).all(), "relu epilogue must clamp at 0"


def test_relu_without_bias():
    a, b = make_inputs(2, 16, 8, 8, seed=2)
    got = batched_gemm(a, b, fuse_relu=True)
    want = jnp.maximum(ref.batched_gemm_ref(a, b), 0.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_bias_without_relu_clamps_too():
    # bias implies the fused epilogue (relu included): documented behaviour.
    a, b = make_inputs(1, 8, 8, 8, seed=3)
    bias = rand(jax.random.PRNGKey(9), 1, 1, 8)
    got = batched_gemm(a, b, bias=bias)
    want = ref.fused_linear_ref(a, b, bias)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Hypothesis shape sweep
# ---------------------------------------------------------------------------

dims = st.sampled_from([1, 2, 3, 4, 6, 8, 16, 24, 64, 128, 130, 256])
rs = st.integers(min_value=1, max_value=9)


@hypothesis.given(r=rs, m=dims, n=dims, k=dims)
@hypothesis.settings(max_examples=40, deadline=None)
def test_sweep_matches_ref(r, m, n, k):
    a, b = make_inputs(r, m, n, k, seed=(r * 1000003 + m * 101 + n * 11 + k))
    got = batched_gemm(a, b)
    want = ref.batched_gemm_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@hypothesis.given(r=rs, m=dims, n=dims, k=dims)
@hypothesis.settings(max_examples=20, deadline=None)
def test_sweep_fused_matches_ref(r, m, n, k):
    a, b = make_inputs(r, m, n, k, seed=(r + m + n + k))
    bias = rand(jax.random.PRNGKey(m * n + k), r, 1, n)
    got = batched_gemm(a, b, bias=bias, fuse_relu=True)
    want = ref.fused_linear_ref(a, b, bias)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Tile picker invariants
# ---------------------------------------------------------------------------

@hypothesis.given(
    m=st.integers(1, 2048), n=st.integers(1, 2048), k=st.integers(1, 4096)
)
@hypothesis.settings(max_examples=200, deadline=None)
def test_tiles_divide_and_fit_budget(m, n, k):
    bm, bn, bk = pick_tiles(m, n, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    assert_vmem_budget(bm, bn, bk)  # raises on violation
    assert 1 <= bm <= min(m, MXU_EDGE)
    assert 1 <= bn <= min(n, MXU_EDGE)


def test_tiles_mxu_aligned_for_paper_shapes():
    for m, n, k in TABLE1_SHAPES.values():
        bm, bn, bk = pick_tiles(m, n, k)
        # Output tiles should hit the MXU edge whenever the dims allow.
        if m % MXU_EDGE == 0:
            assert bm == MXU_EDGE
        if n % MXU_EDGE == 0:
            assert bn == MXU_EDGE


def test_vmem_report_fields():
    rep = vmem_report(256, 128, 1152)
    assert rep["vmem_resident_bytes"] <= VMEM_BUDGET_BYTES
    assert 0.0 < rep["mxu_utilization_estimate"] <= 1.0
    bm, bn, bk = rep["tiles"]
    assert rep["grid_cells_per_problem"] == (256 // bm) * (128 // bn) * (1152 // bk)


def test_explicit_tiles_respected():
    a, b = make_inputs(2, 64, 64, 64, seed=11)
    got = batched_gemm(a, b, tiles=(32, 32, 16))
    want = ref.batched_gemm_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_bad_tiles_rejected():
    a, b = make_inputs(1, 64, 64, 64, seed=12)
    with pytest.raises(AssertionError):
        batched_gemm(a, b, tiles=(48, 32, 16))  # 48 does not divide 64


def test_shape_mismatch_rejected():
    a = jnp.zeros((2, 8, 8), jnp.float32)
    b = jnp.zeros((3, 8, 8), jnp.float32)
    with pytest.raises(AssertionError):
        batched_gemm(a, b)


# ---------------------------------------------------------------------------
# Numerical edge cases
# ---------------------------------------------------------------------------

def test_zero_inputs():
    a = jnp.zeros((2, 16, 16), jnp.float32)
    b = jnp.zeros((2, 16, 16), jnp.float32)
    np.testing.assert_array_equal(batched_gemm(a, b), np.zeros((2, 16, 16)))


def test_identity_matmul():
    eye = jnp.tile(jnp.eye(32, dtype=jnp.float32)[None], (3, 1, 1))
    b = make_inputs(3, 32, 32, 32, seed=4)[1]
    np.testing.assert_allclose(batched_gemm(eye, b), b, rtol=1e-6, atol=1e-6)


def test_large_magnitudes_accumulate_in_f32():
    a, b = make_inputs(1, 8, 8, 1024, seed=5)
    a = a * 100.0
    got = batched_gemm(a, b)
    want = ref.batched_gemm_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)


def test_problems_are_independent():
    """Problem r's result must not depend on other problems in the batch —
    the isolation property the super-kernel must preserve (paper §4)."""
    m, n, k = 32, 16, 24
    a, b = make_inputs(4, m, n, k, seed=6)
    full = batched_gemm(a, b)
    for i in range(4):
        solo = batched_gemm(a[i : i + 1], b[i : i + 1])
        np.testing.assert_allclose(full[i], solo[0], rtol=1e-5, atol=1e-5)
