"""Pure-jnp correctness oracles for the Pallas kernels.

Everything in this file is deliberately the most obvious possible
implementation; pytest compares the Pallas kernels against these.
"""

import jax
import jax.numpy as jnp


def batched_gemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """out[r] = a[r] @ b[r]; a: [R, M, K], b: [R, K, N] -> [R, M, N]."""
    return jnp.einsum(
        "rmk,rkn->rmn", a, b, preferred_element_type=jnp.float32
    ).astype(jnp.float32)


def fused_linear_ref(a: jax.Array, b: jax.Array, bias: jax.Array) -> jax.Array:
    """relu(a @ b + bias); bias broadcast over M: [R, 1, N]."""
    return jnp.maximum(batched_gemm_ref(a, b) + bias, 0.0)


def mlp_block_ref(x: jax.Array, w1: jax.Array, b1: jax.Array,
                  w2: jax.Array) -> jax.Array:
    """Two-layer block: relu(x @ w1 + b1) @ w2 — the multi-layer inference
    unit served end-to-end by the rust coordinator."""
    h = fused_linear_ref(x, w1, b1)
    return batched_gemm_ref(h, w2)
