"""L1: the batched-GEMM super-kernel (Pallas).

This is the TPU re-think of the paper's `cublasSgemmBatched` super-kernel
(DESIGN.md §2 Hardware-Adaptation):

* CUDA threadblocks -> a Pallas grid ``(R, M/bm, N/bn, K/bk)``: each grid
  cell moves one ``(bm, bk)`` LHS tile and one ``(bk, bn)`` RHS tile
  HBM->VMEM and accumulates a ``(bm, bn)`` output tile. The R problems the
  paper spread over CUDA streams become the leading grid dimension of ONE
  launch -- the super-kernel insight taken to its limit.
* Tensor-core WMMA -> MXU: the inner op is ``jnp.dot`` with
  ``preferred_element_type=f32``, tiled to the 128x128 systolic array.
* Shared-memory staging -> VMEM budget: tile sizes are the largest
  divisors of (M, N, K) that fit ``VMEM_BUDGET_BYTES`` with
  double-buffering headroom; asserted at trace time.

The kernel always runs ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. Estimated real-TPU MXU
utilization is analyzed in EXPERIMENTS.md §Perf from the BlockSpec
structure, not from interpret-mode wallclock.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Per-core VMEM is ~16 MiB on modern TPUs; leave headroom for
# double-buffering (Pallas pipelines the HBM->VMEM copies, so two tiles of
# each operand may be resident) plus the output tile.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024

# MXU systolic array edge: prefer 128-multiples so the dot feeds the array
# fully; the VPU lane width (128) makes 128 the right N tile even for
# narrow problems.
MXU_EDGE = 128


def _largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= ``cap``."""
    cap = min(n, cap)
    for d in range(cap, 0, -1):
        if n % d == 0:
            return d
    return 1


def pick_tiles(m: int, n: int, k: int) -> tuple[int, int, int]:
    """Choose (bm, bn, bk): MXU-aligned when possible, VMEM-bounded always.

    Preference order mirrors the paper's CUDA tiling discussion: output
    tiles first (they are revisited across the K loop), then the deepest K
    tile that still fits the budget with double-buffering.
    """
    bm = _largest_divisor_leq(m, MXU_EDGE)
    bn = _largest_divisor_leq(n, MXU_EDGE)
    # Deepest K tile fitting: 2*(bm*bk + bk*bn) + bm*bn floats <= budget.
    budget_floats = VMEM_BUDGET_BYTES // 4
    avail = budget_floats - bm * bn
    cap = max(1, avail // (2 * (bm + bn)))
    bk = _largest_divisor_leq(k, min(cap, 512))
    assert_vmem_budget(bm, bn, bk)
    return bm, bn, bk


def assert_vmem_budget(bm: int, bn: int, bk: int) -> None:
    """Trace-time guard: tiles (double-buffered) must fit the VMEM budget."""
    resident = 2 * (bm * bk + bk * bn) + bm * bn
    bytes_ = 4 * resident
    assert bytes_ <= VMEM_BUDGET_BYTES, (
        f"tile ({bm},{bn},{bk}) needs {bytes_} B of VMEM, "
        f"budget is {VMEM_BUDGET_BYTES} B"
    )


def batched_gemm(a: jax.Array, b: jax.Array, *, bias: jax.Array | None = None,
                 fuse_relu: bool = False,
                 tiles: tuple[int, int, int] | None = None) -> jax.Array:
    """``out[r] = a[r] @ b[r]`` for r in 0..R as ONE Pallas launch.

    a: f32[R, M, K], b: f32[R, K, N] -> f32[R, M, N].
    Optional fused epilogue: ``relu(out + bias)`` with bias f32[R, 1, N]
    (the inference bias+activation of a dense/conv layer, folded into the
    GEMM the way TensorRT folds them -- keeps the request path one kernel).
    """
    r, m, k = a.shape
    rb, kb, n = b.shape
    assert r == rb and k == kb, f"shape mismatch: {a.shape} vs {b.shape}"
    bm, bn, bk = tiles if tiles is not None else pick_tiles(m, n, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"tiles ({bm},{bn},{bk}) must divide problem ({m},{n},{k})"
    )
    nk = k // bk
    fuse = fuse_relu or bias is not None
    if fuse and bias is None:
        bias = jnp.zeros((r, 1, n), jnp.float32)

    grid = (r, m // bm, n // bn, nk)
    in_specs = [
        pl.BlockSpec((1, bm, bk), lambda ri, mi, ni, ki: (ri, mi, ki)),
        pl.BlockSpec((1, bk, bn), lambda ri, mi, ni, ki: (ri, ki, ni)),
    ]
    args = [a, b]
    if fuse:
        in_specs.append(pl.BlockSpec((1, 1, bn), lambda ri, mi, ni, ki: (ri, 0, ni)))
        args.append(bias)

    kernel = functools.partial(
        _squeeze_lead_kernel, nk=nk, fuse_bias_relu=fuse
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda ri, mi, ni, ki: (ri, mi, ni)),
        out_shape=jax.ShapeDtypeStruct((r, m, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(*args)


def _squeeze_lead_kernel(a_ref, b_ref, *rest, nk: int, fuse_bias_relu: bool):
    """Adapter: blocks carry a leading length-1 R axis; squeeze it away."""
    if fuse_bias_relu:
        bias_ref, o_ref = rest
    else:
        (o_ref,) = rest
        bias_ref = None

    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jnp.dot(
        a_ref[0], b_ref[0], preferred_element_type=jnp.float32
    )
    o_ref[0, :, :] += acc.astype(o_ref.dtype)

    if fuse_bias_relu:
        @pl.when(k == nk - 1)
        def _epilogue():
            o_ref[0, :, :] = jnp.maximum(o_ref[0, :, :] + bias_ref[0], 0.0)


def vmem_report(m: int, n: int, k: int) -> dict:
    """Static L1 profile for DESIGN.md/EXPERIMENTS.md: tile geometry, VMEM
    footprint, MXU-utilization estimate for one grid cell."""
    bm, bn, bk = pick_tiles(m, n, k)
    resident_bytes = 4 * (2 * (bm * bk + bk * bn) + bm * bn)
    # MXU estimate: fraction of the 128x128 array the (bm, bn) tile feeds,
    # times the K-depth efficiency (pipelining startup over bk cycles).
    mxu_fill = min(bm, MXU_EDGE) * min(bn, MXU_EDGE) / (MXU_EDGE * MXU_EDGE)
    k_eff = bk / (bk + MXU_EDGE)  # systolic fill/drain amortization
    return {
        "tiles": (bm, bn, bk),
        "grid_cells_per_problem": (m // bm) * (n // bn) * (k // bk),
        "vmem_resident_bytes": resident_bytes,
        "vmem_budget_bytes": VMEM_BUDGET_BYTES,
        "mxu_fill": mxu_fill,
        "k_efficiency": k_eff,
        "mxu_utilization_estimate": mxu_fill * k_eff,
    }
