"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for rust (L3).

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Emits one ``<name>.hlo.txt`` per (graph kind, shape class, R bucket) plus a
``manifest.json`` the rust runtime uses to locate and type-check artifacts.

Usage: ``cd python && python -m compile.aot --out ../artifacts [--quick]``
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from compile import model

# R buckets the rust batcher rounds up to. Powers of two bound padding waste
# to <2x while keeping the executable cache small (ablated in
# benches/ablation_batcher.rs).
R_BUCKETS = [1, 2, 4, 8, 16, 32, 64]

# The paper's Table 1 shape classes (m, n, k).
TABLE1_SHAPES = {
    "rnn_matvec": (512, 1, 512),
    "conv2_2": (256, 128, 1152),
    "square": (256, 256, 256),
}

# Additional lowered shape classes: a small GEMM for fast integration tests
# and CI-grade serving checks (not part of the paper's evaluation grid).
EXTRA_SHAPES = {
    "small": (64, 32, 48),
}

# Serving-path model blocks for the end-to-end example.
MLP_BLOCK = {"m": 8, "hidden": 512, "k": 256, "n_out": 256}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


# Implementation flavors (see model._gemm): the `pallas` flavor validates
# the L1 kernel through the whole pipeline and carries the TPU BlockSpec
# structure; the `xla` flavor is the fast CPU-PJRT lowering the serving
# benches execute. Both compute identical math (pytest pins them together).
IMPLS = ("pallas", "xla")


def build_catalog(quick: bool) -> list[dict]:
    """Everything to lower: name, builder, metadata for the manifest."""
    buckets = [1, 2, 8] if quick else R_BUCKETS
    catalog = []

    def add(name: str, kind: str, builder, meta: dict) -> None:
        for impl in IMPLS:
            fn, args = builder(impl)
            catalog.append(
                dict(
                    name=f"{name}.{impl}",
                    kind=kind,
                    impl=impl,
                    fn=fn,
                    args=args,
                    meta=meta,
                )
            )

    all_shapes = {**TABLE1_SHAPES, **EXTRA_SHAPES}
    for shape_name, (m, n, k) in all_shapes.items():
        for r in buckets:
            add(
                f"gemm_{shape_name}_r{r}",
                "batched_gemm",
                lambda impl, r=r, m=m, n=n, k=k: model.build_batched_gemm(
                    r, m, n, k, impl=impl
                ),
                dict(m=m, n=n, k=k, r=r),
            )
    mb = MLP_BLOCK
    for r in buckets:
        add(
            f"fused_linear_r{r}",
            "fused_linear",
            lambda impl, r=r: model.build_fused_linear(r, 8, 256, 512, impl=impl),
            dict(m=8, n=256, k=512, r=r),
        )
        add(
            f"mlp_block_r{r}",
            "mlp_block",
            lambda impl, r=r: model.build_mlp_block(
                r, mb["m"], mb["hidden"], mb["k"], mb["n_out"], impl=impl
            ),
            dict(m=mb["m"], k=mb["k"], hidden=mb["hidden"], n=mb["n_out"], r=r),
        )
        add(
            f"rnn_cell_r{r}",
            "rnn_cell",
            lambda impl, r=r: model.build_rnn_cell(r, 512, impl=impl),
            dict(m=512, n=1, k=512, r=r, hidden=512),
        )
    return catalog


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--quick", action="store_true", help="small R-bucket subset (tests)"
    )
    ap.add_argument(
        "--only", default=None, help="lower only artifacts whose name contains this"
    )
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {"format": 1, "artifacts": []}
    catalog = build_catalog(args.quick)
    if args.only:
        catalog = [c for c in catalog if args.only in c["name"]]
    if not catalog:
        print("nothing to lower", file=sys.stderr)
        sys.exit(1)

    for entry in catalog:
        path = os.path.join(args.out, f"{entry['name']}.hlo.txt")
        text = lower_entry(entry["fn"], entry["args"])
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            dict(
                name=entry["name"],
                kind=entry["kind"],
                impl=entry["impl"],
                file=os.path.basename(path),
                meta=entry["meta"],
                inputs=[
                    dict(shape=list(a.shape), dtype=str(a.dtype))
                    for a in entry["args"]
                ],
            )
        )
        print(f"lowered {entry['name']}: {len(text)} chars")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
