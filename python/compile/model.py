"""L2: the JAX compute graphs wrapping the L1 Pallas super-kernel.

These are the functions `aot.py` lowers to HLO text for the rust runtime.
Each builder returns ``(fn, example_args)`` so lowering and testing share
one definition. All functions call the Pallas kernel from
``kernels.batched_gemm`` so the kernel lowers into the same HLO module —
Python never runs at serving time.
"""

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.batched_gemm import batched_gemm


def spec(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def _gemm(a, b, *, bias=None, fuse_relu=False, impl="pallas"):
    """Dispatch between the Pallas super-kernel and the plain-XLA lowering.

    Both implement the identical math (pytest pins them together). The
    ``pallas`` flavor carries the TPU BlockSpec structure and validates the
    L1 kernel through the whole AOT pipeline; the ``xla`` flavor lets XLA's
    native dot emitter produce the fast CPU code the serving benches run
    (interpret-mode Pallas on CPU pays a ~20x dynamic-slice tax — DESIGN.md
    §7). On a real TPU the pallas flavor IS the fast path.
    """
    if impl == "pallas":
        return batched_gemm(a, b, bias=bias, fuse_relu=fuse_relu)
    assert impl == "xla", impl
    if bias is not None or fuse_relu:
        b_ = bias if bias is not None else jnp.zeros((a.shape[0], 1, b.shape[2]), jnp.float32)
        return ref.fused_linear_ref(a, b, b_)
    return ref.batched_gemm_ref(a, b)


def build_batched_gemm(r: int, m: int, n: int, k: int, impl: str = "pallas"):
    """The super-kernel itself: out[i] = a[i] @ b[i], one launch.

    This is the paper's `cublasSgemmBatched` analog and the unit the rust
    batcher dispatches for Figure 7 / Table 1 workloads.
    """

    def fn(a, b):
        return (_gemm(a, b, impl=impl),)

    return fn, (spec(r, m, k), spec(r, k, n))


def build_fused_linear(r: int, m: int, n: int, k: int, impl: str = "pallas"):
    """Dense/conv layer with folded inference epilogue:
    relu(a @ w + bias). One kernel on the request path."""

    def fn(a, w, bias):
        return (_gemm(a, w, bias=bias, fuse_relu=True, impl=impl),)

    return fn, (spec(r, m, k), spec(r, k, n), spec(r, 1, n))


def build_mlp_block(r: int, m: int, hidden: int, k: int, n_out: int,
                    impl: str = "pallas"):
    """A two-layer inference block: relu(x@w1 + b1) @ w2.

    The multi-layer unit the end-to-end serving example executes per
    request batch: two super-kernel launches, weights are per-tenant
    inputs (tenants share architecture, never weights — paper §2).
    """

    def fn(x, w1, b1, w2):
        h = _gemm(x, w1, bias=b1, fuse_relu=True, impl=impl)
        return (_gemm(h, w2, impl=impl),)

    return fn, (
        spec(r, m, k),
        spec(r, k, hidden),
        spec(r, 1, hidden),
        spec(r, hidden, n_out),
    )


def build_rnn_cell(r: int, hidden: int, impl: str = "pallas"):
    """The paper's Table 1 RNN workload: h' = tanh(x@W_ih + h@W_hh).

    Both matvecs are Pallas super-kernel calls over the R-problem batch
    (the paper's M=512, N=1, K=512 shape per problem at hidden=512).
    """

    def fn(w_ih, w_hh, x, h):
        a = _gemm(w_ih, x, impl=impl)  # [R,hidden,hidden] @ [R,hidden,1]
        b = _gemm(w_hh, h, impl=impl)
        return (jnp.tanh(a + b),)

    # Paper layout: M=hidden rows of W times the length-1 activation column.
    return fn, (
        spec(r, hidden, hidden),
        spec(r, hidden, hidden),
        spec(r, hidden, 1),
        spec(r, hidden, 1),
    )
