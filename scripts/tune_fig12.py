#!/usr/bin/env python3
"""Thin wrapper around `stgpu tune` for the fig12 workload.

This script used to carry a full Python mirror of the cost model, batcher,
lane balancer, and adaptive controller so the fig12 bench constants could
be tuned numerically. That mirror is retired: the Rust autotuner
(rust/src/coordinator/tuner.rs, `stgpu tune`) replays the identical fig12
workload against the gpusim ground-truth cost model directly, so there is
exactly one implementation to keep in sync with the bench. This wrapper
just builds and invokes it.

Usage:
    python3 scripts/tune_fig12.py [--budget N] [--out-toml PATH]
        [--out-leaderboard PATH] [--check-baseline PATH] [--no-baseline]

Defaults tune the fig12 workload with the CI smoke budget, write the
winning config + leaderboard under rust/results/, and fail (exit 1) if
the recommendation's replayed SLO-met goodput falls below the committed
fig12 adaptive baseline — the same contract as the CI "tune smoke" step.
Pass `--no-baseline` to skip that check, or any `stgpu tune` flag via the
options above. Run `stgpu tune` directly (see `stgpu help`) for other
workloads or flags.
"""

import argparse
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--budget", type=int, default=20,
                    help="evaluation budget (grid + refinement), default 20")
    ap.add_argument("--out-toml", default="rust/results/tune_fig12.toml",
                    help="where to write the winning config fragment")
    ap.add_argument("--out-leaderboard",
                    default="rust/results/BENCH_tune_fig12_leaderboard.json",
                    help="where to write the JSON leaderboard")
    ap.add_argument("--check-baseline",
                    default="rust/bench_baselines/BENCH_fig12_adaptive_lanes.json",
                    help="baseline BENCH json the winner must clear")
    ap.add_argument("--no-baseline", action="store_true",
                    help="skip the baseline goodput check")
    args = ap.parse_args()

    cmd = [
        "cargo", "run", "--release", "--bin", "stgpu", "--", "tune",
        "--workload", "fig12",
        "--budget", str(args.budget),
        "--out-toml", args.out_toml,
        "--out-leaderboard", args.out_leaderboard,
    ]
    if not args.no_baseline:
        cmd += ["--check-baseline", args.check_baseline]
    print("+", " ".join(cmd), file=sys.stderr)
    return subprocess.call(cmd, cwd=REPO)


if __name__ == "__main__":
    sys.exit(main())
