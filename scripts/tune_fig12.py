#!/usr/bin/env python3
"""Tuning mirror for benches/fig12_adaptive_lanes.rs.

The fig12 bench asserts, on a simulated clock, that the adaptive
space-time controller matches or beats the best *static* lane setting per
load phase and strictly beats every static setting on the whole trace.
Those assertions gate CI, and their margins depend on the interaction of
the V100 roofline cost model, the batcher's bucketing, the greedy lane
balancer, and the controller's decision rules. This script mirrors all
four (same formulas as rust/src/gpusim/cost.rs + kernel.rs, same
controller logic as rust/src/coordinator/controller.rs, same replay
structure as the bench) so the bench's workload constants can be tuned
numerically before committing them.

Usage:
    python3 scripts/tune_fig12.py [seed ...]

Prints per-phase goodput, overall throughput/attainment and the margin of
every bench assertion for each seed (default: a handful of seeds). Keep
the constants here in sync with the bench when retuning.
"""

import math
import random
import sys
from collections import defaultdict, deque

# --- DeviceSpec::v100 ------------------------------------------------------
SMS = 80.0
FLOPS_PER_SM = 175e9
HBM_BW = 900e9
LAUNCH_OVERHEAD_S = 5e-6
OCC_HALF_SAT = 6.0
INTERF_COEFF = 0.08
BW_SAT_SMS = 20.0


def occupancy(cpsm):
    return cpsm / (cpsm + OCC_HALF_SAT) if cpsm > 0 else 0.0


def interference(n):
    return 1.0 / (1.0 + INTERF_COEFF * (n - 1))


def lane_stretch_seed(n):
    return 1.0 + INTERF_COEFF * (n - 1)


# --- GemmShape::tiling / ctas / tiled_bytes --------------------------------
def tiling(m, n, k):
    if n <= 4:
        return 64, max(n, 1), 1
    tm = 128 if m >= 128 else min(64, 1 << (m - 1).bit_length())
    tn = 64 if n >= 128 else min(32, 1 << (n - 1).bit_length())
    base = -(-m // tm) * -(-n // tn)
    split_k = min(max(32 // base, 1), 8) if (base < 32 and k >= 256) else 1
    return tm, tn, split_k


def gemm_ctas(m, n, k):
    tm, tn, sk = tiling(m, n, k)
    return -(-m // tm) * -(-n // tn) * sk


def gemm_bytes(m, n, k):
    tm, tn, sk = tiling(m, n, k)
    n_tiles = -(-n // tn)
    m_tiles = -(-m // tm)
    c = m * n * (2.0 * sk if sk > 1 else 1.0)
    return 4.0 * (m * k * n_tiles + k * n * m_tiles + c)


def gemm_flops(m, n, k):
    return 2.0 * m * n * k


# --- kernel_service_time (static_bw_partition = false, like fig10) ---------
def service_time(flops, bytes_, ctas, sms, conc):
    used = max(min(sms, ctas), 1e-9)
    cpsm = ctas / used
    eff = occupancy(cpsm) * interference(conc)
    compute = flops / (used * FLOPS_PER_SM * max(eff, 1e-12))
    bw = min(1.0, used / BW_SAT_SMS)
    memory = bytes_ / (HBM_BW * bw * interference(conc))
    return max(compute, memory)


def ground_truth(cls, r, active):
    m, n, k = cls
    r = max(r, 1)
    active = max(active, 1)
    return LAUNCH_OVERHEAD_S + service_time(
        r * gemm_flops(m, n, k),
        r * gemm_bytes(m, n, k),
        r * gemm_ctas(m, n, k),
        SMS / active,
        active,
    )


# --- queue::ArrivalRate mirror ---------------------------------------------
class ArrivalRate:
    def __init__(self, tau=0.1):
        self.rate = 0.0
        self.last = None
        self.tau = tau

    def observe(self, now):
        if self.last is None:
            self.last = now
            return
        dt = max(now - self.last, 1e-9)
        alpha = 1.0 - math.exp(-dt / self.tau)
        self.rate = alpha * (1.0 / dt) + (1.0 - alpha) * self.rate
        if now > self.last:
            self.last = now

    def rate_at(self, now):
        if self.last is None:
            return 0.0
        return self.rate * math.exp(-max(now - self.last, 0.0) / self.tau)


# --- controller mirror (coordinator::controller) ---------------------------
class Tracker:
    def __init__(self, alpha=0.2):
        self.alpha = alpha
        self.launches_pr = 0.0
        self.requests_pr = 0.0
        self.mean_launch = 0.0
        self.rounds = 0
        self.launch_obs = 0
        self.stretch = {}

    def _blend(self, seeded, ewma, sample):
        return self.alpha * sample + (1 - self.alpha) * ewma if seeded else sample

    def observe_round(self, launches, drained):
        if launches == 0:
            return
        self.launches_pr = self._blend(self.rounds > 0, self.launches_pr, launches)
        self.requests_pr = self._blend(self.rounds > 0, self.requests_pr, drained)
        self.rounds += 1

    def observe_launch(self, solo):
        if solo <= 0:
            return
        self.mean_launch = self._blend(self.launch_obs > 0, self.mean_launch, solo)
        self.launch_obs += 1

    def observe_stretch(self, lanes, ratio):
        if lanes <= 1 or ratio <= 0:
            return
        ew, obs = self.stretch.get(lanes, (0.0, 0))
        self.stretch[lanes] = (self._blend(obs > 0, ew, max(ratio, 1.0)), obs + 1)

    def stretch_table(self, max_lanes):
        out = [1.0, 1.0]
        for n in range(2, max_lanes + 1):
            ew, obs = self.stretch.get(n, (0.0, 0))
            out.append(max(ew, 1.0) if obs > 0 else lane_stretch_seed(n))
        return out


class Controller:
    def __init__(self, max_lanes, max_depth, dwell, improvement, slo_target):
        self.max_lanes = max_lanes
        self.max_depth = max_depth
        self.dwell = dwell
        self.improvement = improvement
        self.slo_target = slo_target
        self.lanes, self.depth = 1, 1
        self.since = 0
        self.prev_backlog = 0
        self.evals = 0
        self.last_explore = 0
        self.reconfigs = 0

    def _score(self, s, lanes, depth):
        launches = max(s["L"], 1.0)
        eff = max(min(lanes, math.ceil(launches)), 1)
        waves = max(launches / eff, 1.0)
        mk = waves * s["dur"] * s["stretch"][min(eff, len(s["stretch"]) - 1)]
        cadence = s["plan"] + mk if depth <= 1 else max(s["plan"], mk)
        tput = max(s["R"], 1.0) / max(cadence, 1e-12)
        lat = (depth - 1) * cadence + mk
        feas = s["slo"] <= 0 or lat <= s["slo"]
        return tput, lat, feas

    def tick(self):
        self.since += 1
        if self.since < self.dwell:
            return False
        self.since = 0
        return True

    def decide(self, s):
        if s["dur"] <= 0 or s["R"] <= 0:
            return
        self.evals += 1
        best = None
        cur = self._score(s, self.lanes, self.depth)
        for lanes in range(1, self.max_lanes + 1):
            for depth in range(1, self.max_depth + 1):
                c = (lanes, depth) + self._score(s, lanes, depth)
                if best is None:
                    best = c
                    continue
                cf, bf = c[4], best[4]
                if cf != bf:
                    if cf:
                        best = c
                elif cf:
                    if c[2] > best[2] * (1 + 1e-9):
                        best = c
                else:
                    if c[3] < best[3] * (1 - 1e-9):
                        best = c
        backlog_p = s["backlog"] > 2 * max(s["R"], 1.0) and (
            s["backlog"] >= self.prev_backlog or s["rate"] > cur[0])
        slo_p = s["att"] is not None and s["att"] < self.slo_target
        self.prev_backlog = s["backlog"]
        nl, nd = self.lanes, self.depth
        bl, bd, bt = best[0], best[1], best[2]
        if slo_p and not backlog_p:
            if self.lanes > 1:
                nl -= 1
            elif self.depth > 1:
                nd -= 1
        elif (bl, bd) != (self.lanes, self.depth) and (
            bt > cur[0] * (1 + self.improvement)
            or (not cur[2] and best[4])
            or (backlog_p and bt > cur[0])
        ):
            nl, nd = bl, bd
        elif backlog_p and self.lanes < self.max_lanes and (
            self.last_explore == 0 or self.evals >= self.last_explore + 2
        ):
            nl = max(math.ceil(max(s["L"], 1.0)), self.lanes + 1)
            self.last_explore = self.evals
        nl = min(max(nl, 1), self.max_lanes)
        nd = min(max(nd, 1), self.max_depth)
        if (nl, nd) != (self.lanes, self.depth):
            self.lanes, self.depth = nl, nd
            self.reconfigs += 1


# --- workload (keep in sync with the bench) --------------------------------
LAT_CLASSES = [(8192, 8192, 128), (8192, 8064, 128), (8064, 8192, 128), (8064, 8064, 128)]
BATCH_CLASSES = [(256, 128, 1152), (128, 256, 1152), (256, 128, 1024), (128, 256, 1024)]
N_LAT = 8  # two tenants per lat class
N_BATCH = 8  # two tenants per batch class
LAT_SLO = 0.0115
BATCH_SLO = 0.400
MAX_BATCH = 16
BUCKETS = [1, 2, 4, 8, 16, 32, 64]
PH_A, PH_B, PH_C = 1.0, 1.5, 2.0  # phase durations, seconds
HORIZON = PH_A + PH_B + PH_C
WAVE_PERIOD = 0.025
B_BATCH, C_BATCH = 68_000.0, 200.0
DWELL = 4
IMPROVEMENT = 0.10


def tenant_class(t):
    return LAT_CLASSES[t // 2] if t < N_LAT else BATCH_CLASSES[(t - N_LAT) // 2]


def tenant_slo(t):
    return LAT_SLO if t < N_LAT else BATCH_SLO


def phase_of(t_arr):
    if t_arr < PH_A:
        return 0
    if t_arr < PH_A + PH_B:
        return 1
    return 2


def gen_trace(seed):
    rng = random.Random(seed)
    reqs = []
    # Phase A: deterministic waves of the first two lat classes (tenants
    # 0..4), one request each, aligned — every round is a 2-launch wave.
    k = 1
    while k * WAVE_PERIOD < PH_A:
        for t in range(4):
            reqs.append((k * WAVE_PERIOD, t))
        k += 1
    # Phase C: waves of all four lat classes (tenants 0..8).
    k = 1
    while PH_A + PH_B + k * WAVE_PERIOD < HORIZON:
        for t in range(N_LAT):
            reqs.append((PH_A + PH_B + k * WAVE_PERIOD, t))
        k += 1
    # Batch tenants: Poisson, heavy in B, light in C.
    for t in range(N_LAT, N_LAT + N_BATCH):
        for (t0, t1), rate in [((PH_A, PH_A + PH_B), B_BATCH / N_BATCH),
                               ((PH_A + PH_B, HORIZON), C_BATCH / N_BATCH)]:
            x = t0 + rng.expovariate(rate)
            while x < t1:
                reqs.append((x, t))
                x += rng.expovariate(rate)
    reqs.sort()
    return reqs


def bucket_for(n):
    for b in BUCKETS:
        if b >= n:
            return b
    return BUCKETS[-1]


def run(trace, lanes_mode):
    """lanes_mode: int (static) or 'adaptive'."""
    ctl = Controller(4, 1, DWELL, IMPROVEMENT, 0.99) if lanes_mode == "adaptive" else None
    tracker = Tracker()
    est = ArrivalRate()
    queues = [deque() for _ in range(N_LAT + N_BATCH)]
    idx, t = 0, 0.0
    hits = misses = 0
    win_hits = win_misses = 0
    phase_hits = [0, 0, 0]
    done = 0
    while True:
        while idx < len(trace) and trace[idx][0] <= t:
            arr, tn = trace[idx]
            est.observe(arr)
            queues[tn].append((arr, arr + tenant_slo(tn)))
            idx += 1
        if all(not q for q in queues):
            if idx < len(trace):
                t = trace[idx][0]
                continue
            break
        # controller
        if ctl is not None and ctl.tick():
            backlog = sum(len(q) for q in queues)
            att = win_hits / (win_hits + win_misses) if (win_hits + win_misses) else None
            ctl.decide({
                "L": tracker.launches_pr, "R": tracker.requests_pr,
                "dur": tracker.mean_launch, "plan": 0.0,
                "stretch": tracker.stretch_table(4),
                "backlog": backlog,
                "att": att,
                "slo": LAT_SLO,
                "rate": est.rate_at(t),
            })
            # The window's verdicts are consumed at every dwell boundary
            # (verdicts imply completions imply usable signals, so a
            # boundary with verdicts always evaluates).
            win_hits = win_misses = 0
        lanes_now = ctl.lanes if ctl is not None else lanes_mode
        # fair drain up to MAX_BATCH
        drained = []
        while len(drained) < MAX_BATCH:
            took = False
            for tn in range(len(queues)):
                if len(drained) >= MAX_BATCH:
                    break
                if queues[tn]:
                    drained.append((tn,) + queues[tn].popleft())
                    took = True
            if not took:
                break
        # batch per class (sorted), chunks of MAX_BATCH, pad to bucket
        by_class = defaultdict(list)
        for tn, arr, dl in drained:
            by_class[tenant_class(tn)].append((arr, dl))
        launches = []
        for cls in sorted(by_class):
            entries = by_class[cls]
            for i in range(0, len(entries), MAX_BATCH):
                chunk = entries[i:i + MAX_BATCH]
                launches.append((cls, chunk, bucket_for(len(chunk))))
        active = max(min(lanes_now, len(launches)), 1)
        # greedy lane assignment by flop-proxy weight, plan order
        load = [0.0] * active
        cursor = [0.0] * active
        for cls, chunk, rb in launches:
            lane = min(range(active), key=lambda i: load[i])
            load[lane] += gemm_flops(*cls) * rb
            dur = ground_truth(cls, rb, active)
            solo = ground_truth(cls, rb, 1)
            if ctl is not None:
                tracker.observe_launch(solo)
                if active > 1:
                    tracker.observe_stretch(active, dur / solo)
            cursor[lane] += dur
            fin = t + cursor[lane]
            for arr, dl in chunk:
                done += 1
                if fin <= dl:
                    hits += 1
                    win_hits += 1
                    phase_hits[phase_of(arr)] += 1
                else:
                    misses += 1
                    win_misses += 1
        if ctl is not None:
            tracker.observe_round(len(launches), len(drained))
        t += max(cursor)
    spans = [PH_A, PH_B, PH_C]
    return {
        "makespan": t, "done": done,
        # Whole-trace SLO-met throughput: the y-axis of fig12 (throughput
        # subject to SLO feasibility — the utility the controller targets).
        "tput": hits / HORIZON,
        "att": hits / max(hits + misses, 1),
        "goodput": [phase_hits[i] / spans[i] for i in range(3)],
        "reconfigs": ctl.reconfigs if ctl else 0,
    }


def main():
    seeds = [int(s) for s in sys.argv[1:]] or [1042, 7, 99, 2024]
    for seed in seeds:
        trace = gen_trace(seed)
        res = {m: run(trace, m) for m in [1, 2, 4, "adaptive"]}
        print(f"== seed {seed} ({len(trace)} requests) ==")
        for m, r in res.items():
            gp = " ".join(f"{g:9.0f}" for g in r["goodput"])
            print(f"  {str(m):>8}: tput {r['tput']:9.0f}  att {r['att']:.4f}  "
                  f"makespan {r['makespan']:.3f}  goodput[{gp}]  "
                  f"reconfigs {r['reconfigs']}")
        ad = res["adaptive"]
        ok = True
        for p in range(3):
            best = max(res[m]["goodput"][p] for m in [1, 2, 4])
            margin = ad["goodput"][p] / best if best > 0 else float("inf")
            flag = "OK " if margin >= 0.95 else "FAIL"
            ok &= margin >= 0.95
            print(f"  phase {p}: adaptive/best-static goodput = {margin:.3f} {flag}")
        for m in [1, 2, 4]:
            tm = ad["tput"] / res[m]["tput"]
            am = ad["att"] - res[m]["att"]
            flag = "OK " if (tm > 1.0 and am >= 0.0) else "FAIL"
            ok &= tm > 1.0 and am >= 0.0
            print(f"  vs static {m}: tput x{tm:.3f}, att {am:+.4f} {flag}")
        print("  =>", "ALL OK" if ok else "ASSERTIONS WOULD FAIL")


if __name__ == "__main__":
    main()
