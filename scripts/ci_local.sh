#!/usr/bin/env bash
# Reproduce the full CI matrix locally (.github/workflows/ci.yml) so a
# builder without GitHub runners can pre-flight tier-1 before pushing.
#
# Usage:  scripts/ci_local.sh [--skip-bench]
#
# Steps (in CI-job order):
#   build-test:  cargo build --release && cargo test -q
#                && cargo build --benches --examples
#   bench-gate:  cargo bench --no-run, the fig11-fig16 smokes,
#                the `stgpu tune --budget 20` smoke (validated-TOML +
#                baseline check), then scripts/bench_gate.py against
#                rust/bench_baselines
#   journal-replay: a parallel 4-node cluster simulation persisting its
#                decision journal, then `stgpu replay` asserting the
#                serial re-execution is bitwise identical
#   lint:        cargo fmt --check && cargo clippy --all-targets -D warnings
#                && cargo run -p xtask -- lint (repo-specific rules)
#   model-check: the schedule-exhaustive lane-protocol and cluster
#                ticket-protocol suites with --nocapture so
#                explored-schedule counts are printed
#   doc:         cargo doc --no-deps with -D warnings
#
# --skip-bench skips the timed smoke benches + gate (the slowest step);
# everything else is identical to CI. The advisory Miri/TSan job is
# CI-only (needs a nightly toolchain and is non-blocking there anyway).

set -euo pipefail
cd "$(dirname "$0")/.."

SKIP_BENCH=0
for arg in "$@"; do
    case "$arg" in
        --skip-bench) SKIP_BENCH=1 ;;
        *) echo "unknown flag: $arg" >&2; exit 2 ;;
    esac
done

step() { printf '\n=== %s ===\n' "$*"; }

step "build-test: cargo build --release"
cargo build --release

step "build-test: cargo test -q"
cargo test -q

step "build-test: cargo build --benches --examples"
cargo build --benches --examples

step "bench-gate: cargo bench --no-run"
cargo bench --no-run

if [ "$SKIP_BENCH" -eq 0 ]; then
    step "bench-gate: fig11 round-overhead smoke"
    cargo bench --bench fig11_round_overhead
    step "bench-gate: fig12 adaptive-lanes smoke"
    cargo bench --bench fig12_adaptive_lanes
    step "bench-gate: fig13 sim-scale smoke"
    cargo bench --bench fig13_sim_scale
    step "bench-gate: fig14 cluster-scaleout smoke"
    cargo bench --bench fig14_cluster_scaleout
    step "bench-gate: fig15 work-stealing smoke"
    cargo bench --bench fig15_work_stealing
    step "bench-gate: fig16 overload-degradation smoke"
    cargo bench --bench fig16_overload_degradation
    step "bench-gate: stgpu tune smoke (budget 20)"
    cargo run --release --bin stgpu -- tune --workload fig12 --budget 20 \
        --out-toml rust/results/tune_fig12.toml \
        --out-leaderboard rust/results/BENCH_tune_fig12_leaderboard.json \
        --check-baseline rust/bench_baselines/BENCH_fig12_adaptive_lanes.json
    grep -q '^\[server\]' rust/results/tune_fig12.toml
    grep -q '^\[controller\]' rust/results/tune_fig12.toml
    python3 -c "import json; json.load(open('rust/results/BENCH_tune_fig12_leaderboard.json'))"
    step "bench-gate: scripts/bench_gate.py"
    python3 scripts/bench_gate.py
else
    step "bench-gate: SKIPPED (--skip-bench)"
fi

step "journal-replay: 4-node parallel cluster simulation"
cargo run --release --bin stgpu -- simulate --cluster 4 --rounds 120 \
    --journal rust/results/journal_smoke.bin

step "journal-replay: serial re-execution must be bitwise identical"
cargo run --release --bin stgpu -- replay rust/results/journal_smoke.bin

step "lint: cargo fmt --check"
cargo fmt --check

step "lint: cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

step "lint: cargo run -p xtask -- lint"
cargo run -p xtask -- lint

step "model-check: lane-protocol exhaustive + mutation suite"
cargo test --test modelcheck_protocol -- --nocapture

step "model-check: work-stealing deques exhaustive + mutation suite"
cargo test --test modelcheck_steal -- --nocapture

step "model-check: cluster ticket-protocol exhaustive + mutation suite"
cargo test --test modelcheck_cluster -- --nocapture

step "model-check: checker unit tests"
cargo test -p stgpu --lib util::modelcheck -- --nocapture

step "doc: cargo doc --no-deps (deny warnings)"
RUSTDOCFLAGS="-D warnings -A rustdoc::private-intra-doc-links" cargo doc --no-deps

printf '\nci_local: all steps green\n'
