#!/usr/bin/env python3
"""CI perf-regression gate over the figure benches' BENCH_*.json output.

Every figure bench emits `results/BENCH_<name>.json` on one schema
(name, throughput, p50, p99, slo_attainment, scale). This gate compares
the fresh results of the smoke benches against committed baselines and
FAILS (exit 1) when the perf trajectory regresses:

  * throughput drops more than --max-tput-drop (default 10%) below the
    baseline, or
  * slo_attainment drops below the baseline (any drop fails — baselines
    carry their own safety margin, see below), or
  * a baselined bench produced no fresh result at all.

p50/p99 deltas are reported informationally (latency distributions are
runner-dependent; throughput + attainment are the gated trajectory).

Scale-carrying benches (fig8 devices, fig13 simulated devices, fig14
nodes) record the scale the headline number was measured at. The gate
only compares throughput/attainment when baseline and fresh ran at the
SAME scale — a 4-device baseline is not a regression floor for a
1-device smoke run. A scale mismatch is reported as `scale-skip` (not a
failure): it means the smoke run was intentionally downsized, and the
baseline should be refreshed at the smoke scale if gating is desired.

A delta table is printed to stdout and, when running in GitHub Actions,
appended to the job summary ($GITHUB_STEP_SUMMARY).

Refreshing baselines
--------------------
Baselines live in rust/bench_baselines/ as verbatim BENCH_*.json files.
The committed values are deliberately conservative floors (they must not
flake across runner generations), with slo_attainment baselines set well
below the typically-observed value. The INITIAL baselines were authored
before any CI runner had executed the benches, so they are loose
catastrophic-regression floors; tighten them from real runner numbers
once a few green runs exist. To refresh after an intentional perf
change:

    cd rust
    cargo bench --bench fig11_round_overhead
    cargo bench --bench fig12_adaptive_lanes
    cp results/BENCH_fig11_round_overhead.json bench_baselines/
    cp results/BENCH_fig12_adaptive_lanes.json bench_baselines/
    # then hand-edit the new baselines DOWN by ~10-20% (throughput) and
    # ~0.02 (slo_attainment) so runner variance cannot trip the gate.

Usage:
    python3 scripts/bench_gate.py \
        [--baseline-dir rust/bench_baselines] [--results-dir rust/results] \
        [--max-tput-drop 0.10]
"""

import argparse
import json
import os
import sys
from pathlib import Path


def load(path):
    with open(path) as f:
        doc = json.load(f)
    for key in ("name", "throughput", "p50", "p99"):
        if key not in doc:
            raise ValueError(f"{path}: missing {key!r} (BENCH schema drift?)")
    return doc


def fmt_delta(fresh, base):
    if base in (None, 0):
        return "n/a"
    return f"{(fresh - base) / base * 100:+.1f}%"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", default="rust/bench_baselines")
    ap.add_argument("--results-dir", default="rust/results")
    ap.add_argument("--max-tput-drop", type=float, default=0.10,
                    help="max allowed fractional throughput drop (default 0.10)")
    args = ap.parse_args()

    baseline_dir = Path(args.baseline_dir)
    results_dir = Path(args.results_dir)
    baselines = sorted(baseline_dir.glob("BENCH_*.json"))
    if not baselines:
        print(f"bench-gate: no baselines in {baseline_dir} — nothing to gate",
              file=sys.stderr)
        return 1

    rows = []
    failures = []
    for bpath in baselines:
        base = load(bpath)
        fpath = results_dir / bpath.name
        if not fpath.exists():
            failures.append(f"{base['name']}: no fresh result at {fpath} "
                            "(bench did not run or did not emit JSON)")
            rows.append((base["name"], base["throughput"], None, "missing",
                         base.get("slo_attainment"), None, "missing", "FAIL"))
            continue
        fresh = load(fpath)
        scale_b, scale_f = base.get("scale"), fresh.get("scale")
        if scale_b is not None and scale_f is not None and scale_b != scale_f:
            print(f"[info] {base['name']}: baseline at scale {scale_b}, "
                  f"fresh at scale {scale_f} — not comparable, skipping gate")
            rows.append((base["name"], base["throughput"], fresh["throughput"],
                         "n/a", base.get("slo_attainment"),
                         fresh.get("slo_attainment"), "-", "scale-skip"))
            continue
        verdicts = []
        tput_b, tput_f = base["throughput"], fresh["throughput"]
        if tput_b > 0 and tput_f < tput_b * (1.0 - args.max_tput_drop):
            verdicts.append(
                f"throughput {tput_f:.1f} dropped >{args.max_tput_drop:.0%} "
                f"below baseline {tput_b:.1f}")
        att_b, att_f = base.get("slo_attainment"), fresh.get("slo_attainment")
        if att_b is not None and (att_f is None or att_f < att_b):
            verdicts.append(
                f"slo_attainment {att_f} dropped below baseline {att_b}")
        if verdicts:
            failures.append(f"{base['name']}: " + "; ".join(verdicts))
        rows.append((base["name"], tput_b, tput_f, fmt_delta(tput_f, tput_b),
                     att_b, att_f,
                     "-" if att_b is None else f"{att_f} vs {att_b}",
                     "FAIL" if verdicts else "ok"))
        # Informational latency deltas.
        print(f"[info] {base['name']}: p50 {fresh['p50']:.6f}s "
              f"({fmt_delta(fresh['p50'], base['p50'])} vs baseline), "
              f"p99 {fresh['p99']:.6f}s "
              f"({fmt_delta(fresh['p99'], base['p99'])})")

    header = ("| bench | baseline tput | fresh tput | Δ | baseline att "
              "| fresh att | verdict |")
    sep = "|---|---|---|---|---|---|---|"
    lines = [header, sep]
    for name, tb, tf, d, ab, af, _attcmp, verdict in rows:
        lines.append(
            f"| {name} | {tb:.1f} | "
            f"{'-' if tf is None else f'{tf:.1f}'} | {d} | "
            f"{'-' if ab is None else ab} | {'-' if af is None else af} | "
            f"{verdict} |")
    table = "\n".join(lines)
    print("\n## bench-gate\n" + table + "\n")
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write("\n## bench-gate\n" + table + "\n")

    if failures:
        for f in failures:
            print(f"bench-gate FAIL: {f}", file=sys.stderr)
        return 1
    print(f"bench-gate: {len(rows)} bench(es) within bounds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
