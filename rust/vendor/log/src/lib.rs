//! Minimal offline-vendored `log` facade: the five level macros, writing
//! straight to stderr. No logger registry — the binary is a CLI whose only
//! consumer of these macros is the serving leader loop.

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { eprintln!("[ERROR] {}", format!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { eprintln!("[WARN] {}", format!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { eprintln!("[INFO] {}", format!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { if cfg!(debug_assertions) { eprintln!("[DEBUG] {}", format!($($arg)*)) } };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { if false { let _ = format!($($arg)*); } };
}
