//! API-compatible stub of the `xla` (PJRT) bindings used by the runtime.
//!
//! The offline build environment does not ship the real `xla_extension`
//! native library, so this crate provides the exact API surface
//! `stgpu::runtime` compiles against — `Literal`, `PjRtClient`,
//! `PjRtBuffer`, `PjRtLoadedExecutable`, `HloModuleProto`,
//! `XlaComputation` — with real host-side tensor plumbing (literals,
//! buffers, tuple packing) but **no HLO compiler**: `PjRtClient::compile`
//! returns a descriptive error. Every artifact-dependent test in
//! `rust/tests/` already skips when `artifacts/manifest.json` is absent, so
//! the serving stack, scheduler, simulator and all tier-1 tests run
//! unaffected. To serve real AOT artifacts, replace this path dependency
//! with the real `xla` bindings (same API) in `rust/Cargo.toml`.

use std::fmt;

/// Stub error type, mirroring `xla::Error`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the stub can move across the host boundary (f32 only —
/// everything in this repo is fp32).
pub trait NativeType: Copy {
    fn to_f32(self) -> f32;
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn to_f32(self) -> f32 {
        self
    }
    fn from_f32(v: f32) -> Self {
        v
    }
}

/// Shape of a dense array literal.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host-side literal: a dense f32 array or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    Array { dims: Vec<i64>, data: Vec<f32> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal::Array {
            dims: vec![data.len() as i64],
            data: data.iter().map(|v| v.to_f32()).collect(),
        }
    }

    /// Reshape, preserving element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match self {
            Literal::Array { data, .. } => {
                let want: i64 = dims.iter().product();
                if want as usize != data.len() {
                    return Err(Error::new(format!(
                        "reshape {:?} incompatible with {} elements",
                        dims,
                        data.len()
                    )));
                }
                Ok(Literal::Array { dims: dims.to_vec(), data: data.clone() })
            }
            Literal::Tuple(_) => Err(Error::new("cannot reshape a tuple literal")),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { dims, .. } => Ok(ArrayShape { dims: dims.clone() }),
            Literal::Tuple(_) => Err(Error::new("tuple literal has no array shape")),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { data, .. } => {
                Ok(data.iter().map(|&v| T::from_f32(v)).collect())
            }
            Literal::Tuple(_) => Err(Error::new("tuple literal has no flat data")),
        }
    }

    /// Unpack a tuple literal (identity wrap for an array, matching the
    /// lenient behaviour the runtime relies on for single-output tuples).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts.clone()),
            arr @ Literal::Array { .. } => Ok(vec![arr.clone()]),
        }
    }
}

/// A parsed HLO module (stub: retains the source path for error messages).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    path: String,
}

impl HloModuleProto {
    /// Read an HLO text file. Parsing succeeds if the file is readable; the
    /// stub defers "cannot execute" to compile time.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("read {path}: {e}")))?;
        Ok(HloModuleProto { path: path.to_string() })
    }

    pub fn source_path(&self) -> &str {
        &self.path
    }
}

/// A computation handle (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    module: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { module: proto.clone() }
    }
}

/// A device-resident buffer (stub: host memory standing in for the device).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// A compiled executable. The stub never produces one (compile errors), but
/// the type must exist for the runtime to compile against.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _module: HloModuleProto,
}

/// Argument kinds accepted by `execute`/`execute_b`.
pub trait ExecuteArg {
    fn as_literal(&self) -> Result<Literal>;
}

impl ExecuteArg for Literal {
    fn as_literal(&self) -> Result<Literal> {
        Ok(self.clone())
    }
}

impl ExecuteArg for &PjRtBuffer {
    fn as_literal(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<A: ExecuteArg>(&self, _args: &[A]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(
            "stub backend cannot execute HLO (link the real xla bindings)",
        ))
    }

    pub fn execute_b<A: ExecuteArg>(&self, _args: &[A]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(
            "stub backend cannot execute HLO (link the real xla bindings)",
        ))
    }
}

/// The PJRT client (stub CPU platform).
#[derive(Debug)]
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: "cpu-stub" })
    }

    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(format!(
            "stub backend cannot compile {} (link the real xla bindings; \
             artifact-dependent tests skip without artifacts/)",
            comp.module.path
        )))
    }

    /// Upload a host buffer (stub: wraps it as a literal-backed buffer).
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let want: usize = dims.iter().product();
        if want != data.len() {
            return Err(Error::new(format!(
                "buffer dims {dims:?} incompatible with {} elements",
                data.len()
            )));
        }
        let dims_i: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(PjRtBuffer {
            literal: Literal::Array {
                dims: dims_i,
                data: data.iter().map(|v| v.to_f32()).collect(),
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn tuple_unpacks_and_array_self_wraps() {
        let a = Literal::vec1(&[1.0f32]);
        let t = Literal::Tuple(vec![a.clone(), a.clone()]);
        assert_eq!(t.to_tuple().unwrap().len(), 2);
        assert_eq!(a.to_tuple().unwrap().len(), 1);
    }

    #[test]
    fn client_uploads_but_never_compiles() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu-stub");
        let buf = c
            .buffer_from_host_buffer::<f32>(&[1.0, 2.0], &[2, 1], None)
            .unwrap();
        assert_eq!(buf.to_literal_sync().unwrap().to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
        assert!(c.buffer_from_host_buffer::<f32>(&[1.0], &[3], None).is_err());
        let proto = HloModuleProto { path: "x.hlo.txt".into() };
        let comp = XlaComputation::from_proto(&proto);
        assert!(c.compile(&comp).is_err());
    }
}
