//! Minimal, offline-vendored subset of the `anyhow` API.
//!
//! This workspace builds with no network access, so instead of the crates.io
//! `anyhow` we vendor the thin slice the codebase uses: [`Error`],
//! [`Result`], the [`anyhow!`] macro, and the [`Context`] extension trait.
//! Error values carry a message plus an optional chained cause; `{:#}`
//! formatting prints the whole chain like upstream anyhow does.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A boxed, context-chainable error.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Error { msg: msg.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Error { msg: ctx.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the chain outermost-first.
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            for cause in self.chain().skip(1) {
                write!(f, ": {}", cause.msg)?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&Error> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {}", c.msg)?;
            }
        }
        Ok(())
    }
}

// NB: like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which keeps the blanket `From` below coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Collect the std source chain outermost-first, then rebuild it as
        // nested `Error`s innermost-first.
        let mut chain: Vec<String> = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(c) = cur {
            chain.push(c.to_string());
            cur = c.source();
        }
        let mut source: Option<Box<Error>> = None;
        for msg in chain.into_iter().rev() {
            source = Some(Box::new(Error { msg, source }));
        }
        Error { msg: e.to_string(), source }
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Attach context to errors, mirroring anyhow's `Context` trait.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn anyhow_macro_forms() {
        let a: Error = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let s = String::from("from-a-string");
        let b: Error = anyhow!(s);
        assert_eq!(b.to_string(), "from-a-string");
        let c: Error = anyhow!("x={} y={}", 1, 2);
        assert_eq!(c.to_string(), "x=1 y=2");
    }

    #[test]
    fn context_chains_and_alternate_prints_chain() {
        let base: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "missing",
        ));
        let err = base.with_context(|| "opening config").unwrap_err();
        assert_eq!(format!("{err}"), "opening config");
        assert!(format!("{err:#}").contains("missing"));
        assert!(format!("{err:?}").contains("Caused by"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 12);
    }
}
