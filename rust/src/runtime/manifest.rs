//! The artifact manifest: the contract between `python/compile/aot.py`
//! (which lowers every (graph kind, shape class, R bucket) variant to HLO
//! text) and the rust runtime (which loads and executes them).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Shape + dtype of one executable input, as promised by the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered artifact.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    /// Unique name, e.g. `gemm_conv2_2_r8.pallas`.
    pub name: String,
    /// Graph kind: `batched_gemm`, `fused_linear`, `mlp_block`, `rnn_cell`.
    pub kind: String,
    /// Implementation flavor: `pallas` (L1 kernel) or `xla` (native dot).
    pub impl_: String,
    /// HLO text file, relative to the artifact directory.
    pub file: String,
    /// GEMM metadata: m, n, k, r (+ kind-specific keys like `hidden`).
    pub meta: BTreeMap<String, usize>,
    /// Input specs in positional order.
    pub inputs: Vec<TensorSpec>,
}

impl ArtifactInfo {
    pub fn r(&self) -> usize {
        *self.meta.get("r").expect("artifact meta missing 'r'")
    }

    pub fn mnk(&self) -> (usize, usize, usize) {
        (
            *self.meta.get("m").unwrap_or(&0),
            *self.meta.get("n").unwrap_or(&0),
            *self.meta.get("k").unwrap_or(&0),
        )
    }

    /// FLOPs one execution performs (2·M·N·K per GEMM problem, times R,
    /// times the number of GEMMs in the graph kind).
    pub fn flops(&self) -> f64 {
        let (m, n, k) = self.mnk();
        let gemms = match self.kind.as_str() {
            "mlp_block" => {
                let hidden = *self.meta.get("hidden").unwrap_or(&0);
                // x@w1 [m,k]·[k,h] + h@w2 [m,h]·[h,n]
                return self.r() as f64
                    * 2.0
                    * (m * k * hidden + m * hidden * n) as f64;
            }
            "rnn_cell" => 2, // two matvecs
            _ => 1,
        };
        self.r() as f64 * gemms as f64 * 2.0 * (m * n * k) as f64
    }
}

/// Parsed manifest: lookup tables over the artifact set.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactInfo>,
    by_name: BTreeMap<String, usize>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, String> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self, String> {
        let json = Json::parse(text)?;
        let format = json
            .get("format")
            .and_then(Json::as_usize)
            .ok_or("manifest missing 'format'")?;
        if format != 1 {
            return Err(format!("unsupported manifest format {format}"));
        }
        let arr = json
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or("manifest missing 'artifacts'")?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for a in arr {
            artifacts.push(Self::parse_artifact(a)?);
        }
        let by_name = artifacts
            .iter()
            .enumerate()
            .map(|(i, a)| (a.name.clone(), i))
            .collect();
        Ok(Manifest {
            dir,
            artifacts,
            by_name,
        })
    }

    fn parse_artifact(a: &Json) -> Result<ArtifactInfo, String> {
        let get_str = |k: &str| {
            a.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("artifact missing '{k}'"))
        };
        let meta = a
            .get("meta")
            .and_then(|m| match m {
                Json::Obj(o) => Some(o),
                _ => None,
            })
            .ok_or("artifact missing 'meta'")?
            .iter()
            .filter_map(|(k, v)| v.as_usize().map(|n| (k.clone(), n)))
            .collect();
        let mut inputs = Vec::new();
        for inp in a
            .get("inputs")
            .and_then(Json::as_arr)
            .ok_or("artifact missing 'inputs'")?
        {
            let shape = inp
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or("input missing 'shape'")?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let dtype = inp
                .get("dtype")
                .and_then(Json::as_str)
                .unwrap_or("float32")
                .to_string();
            inputs.push(TensorSpec { shape, dtype });
        }
        Ok(ArtifactInfo {
            name: get_str("name")?,
            kind: get_str("kind")?,
            impl_: get_str("impl")?,
            file: get_str("file")?,
            meta,
            inputs,
        })
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactInfo> {
        self.by_name.get(name).map(|&i| &self.artifacts[i])
    }

    pub fn hlo_path(&self, a: &ArtifactInfo) -> PathBuf {
        self.dir.join(&a.file)
    }

    /// All R buckets available for a (kind, impl) pair, ascending.
    pub fn r_buckets(&self, kind: &str, impl_: &str) -> Vec<usize> {
        let mut rs: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == kind && a.impl_ == impl_)
            .map(ArtifactInfo::r)
            .collect();
        rs.sort_unstable();
        rs.dedup();
        rs
    }

    /// Find the artifact for (kind, impl, shape-class, exact R bucket).
    /// `mnk = (0,0,0)` skips the shape filter (kinds with one shape class).
    pub fn find(
        &self,
        kind: &str,
        impl_: &str,
        mnk: (usize, usize, usize),
        r: usize,
    ) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| {
            a.kind == kind
                && a.impl_ == impl_
                && a.r() == r
                && (mnk == (0, 0, 0) || a.mnk() == mnk)
        })
    }

    /// Smallest R bucket >= `r` for (kind, impl, shape). The batcher's
    /// round-up rule; returns None if `r` exceeds the largest bucket.
    pub fn bucket_for(
        &self,
        kind: &str,
        impl_: &str,
        mnk: (usize, usize, usize),
        r: usize,
    ) -> Option<&ArtifactInfo> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == kind
                    && a.impl_ == impl_
                    && a.r() >= r
                    && (mnk == (0, 0, 0) || a.mnk() == mnk)
            })
            .min_by_key(|a| a.r())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> &'static str {
        r#"{
 "format": 1,
 "artifacts": [
  {"name": "gemm_square_r1.xla", "kind": "batched_gemm", "impl": "xla",
   "file": "gemm_square_r1.xla.hlo.txt",
   "meta": {"m": 256, "n": 256, "k": 256, "r": 1},
   "inputs": [{"shape": [1, 256, 256], "dtype": "float32"},
              {"shape": [1, 256, 256], "dtype": "float32"}]},
  {"name": "gemm_square_r4.xla", "kind": "batched_gemm", "impl": "xla",
   "file": "gemm_square_r4.xla.hlo.txt",
   "meta": {"m": 256, "n": 256, "k": 256, "r": 4},
   "inputs": [{"shape": [4, 256, 256], "dtype": "float32"},
              {"shape": [4, 256, 256], "dtype": "float32"}]},
  {"name": "rnn_cell_r2.pallas", "kind": "rnn_cell", "impl": "pallas",
   "file": "rnn_cell_r2.pallas.hlo.txt",
   "meta": {"m": 512, "n": 1, "k": 512, "r": 2, "hidden": 512},
   "inputs": [{"shape": [2, 512, 512], "dtype": "float32"},
              {"shape": [2, 512, 512], "dtype": "float32"},
              {"shape": [2, 512, 1], "dtype": "float32"},
              {"shape": [2, 512, 1], "dtype": "float32"}]}
 ]
}"#
    }

    fn manifest() -> Manifest {
        Manifest::parse(sample(), PathBuf::from("/tmp/artifacts")).unwrap()
    }

    #[test]
    fn parses_and_indexes() {
        let m = manifest();
        assert_eq!(m.len(), 3);
        let a = m.get("gemm_square_r4.xla").unwrap();
        assert_eq!(a.r(), 4);
        assert_eq!(a.mnk(), (256, 256, 256));
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![4, 256, 256]);
        assert_eq!(a.inputs[0].elements(), 4 * 256 * 256);
    }

    #[test]
    fn bucket_rounds_up() {
        let m = manifest();
        let a = m
            .bucket_for("batched_gemm", "xla", (256, 256, 256), 2)
            .unwrap();
        assert_eq!(a.r(), 4);
        let exact = m
            .bucket_for("batched_gemm", "xla", (256, 256, 256), 1)
            .unwrap();
        assert_eq!(exact.r(), 1);
        assert!(m
            .bucket_for("batched_gemm", "xla", (256, 256, 256), 5)
            .is_none());
    }

    #[test]
    fn find_is_exact() {
        let m = manifest();
        assert!(m.find("batched_gemm", "xla", (256, 256, 256), 4).is_some());
        assert!(m.find("batched_gemm", "xla", (256, 256, 256), 2).is_none());
        assert!(m.find("batched_gemm", "pallas", (256, 256, 256), 4).is_none());
    }

    #[test]
    fn r_buckets_sorted_dedup() {
        let m = manifest();
        assert_eq!(m.r_buckets("batched_gemm", "xla"), vec![1, 4]);
        assert_eq!(m.r_buckets("rnn_cell", "pallas"), vec![2]);
        assert!(m.r_buckets("nope", "xla").is_empty());
    }

    #[test]
    fn flops_scales_with_r_and_kind() {
        let m = manifest();
        let a1 = m.get("gemm_square_r1.xla").unwrap();
        let a4 = m.get("gemm_square_r4.xla").unwrap();
        assert!((a4.flops() / a1.flops() - 4.0).abs() < 1e-9);
        // rnn_cell does two matvecs per problem.
        let rnn = m.get("rnn_cell_r2.pallas").unwrap();
        assert!((rnn.flops() - 2.0 * 2.0 * 2.0 * (512 * 512) as f64).abs() < 1.0);
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse(r#"{"format": 9, "artifacts": []}"#, "/tmp".into()).is_err());
        assert!(Manifest::parse("not json", "/tmp".into()).is_err());
        assert!(Manifest::parse(r#"{"artifacts": []}"#, "/tmp".into()).is_err());
    }
}
