//! PJRT runtime (L3 ↔ artifacts boundary): loads the HLO-text executables
//! `python/compile/aot.py` produced, compiles them once on the CPU PJRT
//! client, and executes them from the coordinator's hot path. Python never
//! runs at serving time.

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::{EngineStats, LoadedArtifact, PjrtEngine};
pub use manifest::{ArtifactInfo, Manifest, TensorSpec};
pub use tensor::{host_batched_gemm, host_fused_linear, HostTensor};
