//! PJRT execution engine: loads AOT artifacts (HLO text), compiles them on
//! the CPU PJRT client, caches the executables, and runs them from the
//! coordinator's hot path.
//!
//! Design constraints (DESIGN.md §7, /opt/xla-example/README.md):
//! * HLO **text** interchange — `HloModuleProto::from_text_file` reassigns
//!   instruction ids, sidestepping xla_extension 0.5.1's 32-bit-id limit.
//! * Everything lowered with `return_tuple=True`, so results unwrap with
//!   `to_tuple`.
//! * One `PjRtClient` per process; executables are compiled once and
//!   cached behind an `RwLock` (reads on the hot path are shared).

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::manifest::{ArtifactInfo, Manifest};
use super::tensor::HostTensor;

/// Statistics the engine accumulates (read by metrics + benches).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub compiles: u64,
    pub compile_secs: f64,
    pub executions: u64,
    pub execute_secs: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// A compiled executable plus its manifest entry.
pub struct LoadedArtifact {
    pub info: ArtifactInfo,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedArtifact {
    /// Execute with host tensors; validates shapes against the manifest.
    pub fn execute(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.info.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.info.name,
                self.info.inputs.len(),
                inputs.len()
            ));
        }
        for (i, (t, spec)) in inputs.iter().zip(&self.info.inputs).enumerate() {
            if t.shape != spec.shape {
                return Err(anyhow!(
                    "{}: input {i} shape {:?} != manifest {:?}",
                    self.info.name,
                    t.shape,
                    spec.shape
                ));
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal().map_err(|e| anyhow!("{e:?}")))
            .collect::<Result<_>>()?;
        self.execute_literals(&literals)
    }

    /// Execute with prebuilt literals (hot path: the caller owns pooling).
    pub fn execute_literals(&self, literals: &[xla::Literal]) -> Result<Vec<HostTensor>> {
        let result = self
            .exe
            .execute::<xla::Literal>(literals)
            .with_context(|| format!("execute {}", self.info.name))?;
        Self::fetch_outputs(&result[0][0], &self.info.name)
    }

    /// Execute with device-resident buffers (the fast path: weight operands
    /// cached on device skip the host→device copy entirely — the paper's
    /// "data is preallocated on the device as in a real-world DNN inference
    /// setting", §4.1).
    pub fn execute_buffers(&self, buffers: &[&xla::PjRtBuffer]) -> Result<Vec<HostTensor>> {
        if buffers.len() != self.info.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.info.name,
                self.info.inputs.len(),
                buffers.len()
            ));
        }
        let result = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(buffers)
            .with_context(|| format!("execute_b {}", self.info.name))?;
        Self::fetch_outputs(&result[0][0], &self.info.name)
    }

    fn fetch_outputs(buf: &xla::PjRtBuffer, name: &str) -> Result<Vec<HostTensor>> {
        let lit = buf
            .to_literal_sync()
            .with_context(|| format!("fetch result of {name}"))?;
        // aot.py lowers with return_tuple=True: unwrap the tuple.
        let parts = lit.to_tuple().map_err(|e| anyhow!("{e:?}"))?;
        parts
            .iter()
            .map(|p| HostTensor::from_literal(p).map_err(|e| anyhow!("{e:?}")))
            .collect()
    }
}

/// The process-wide PJRT runtime.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RwLock<HashMap<String, Arc<LoadedArtifact>>>,
    stats: Mutex<EngineStats>,
}

impl PjrtEngine {
    /// Create a CPU-PJRT engine over an artifact directory.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let manifest =
            Manifest::load(&artifact_dir).map_err(|e| anyhow!("manifest: {e}"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        Ok(PjrtEngine {
            client,
            manifest,
            cache: RwLock::new(HashMap::new()),
            stats: Mutex::new(EngineStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.lock().unwrap().clone()
    }

    /// Number of executables currently cached.
    pub fn cached_count(&self) -> usize {
        self.cache.read().unwrap().len()
    }

    /// Load (compile-once, cached) an artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Arc<LoadedArtifact>> {
        if let Some(hit) = self.cache.read().unwrap().get(name) {
            self.stats.lock().unwrap().cache_hits += 1;
            return Ok(hit.clone());
        }
        let info = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        let path = self.manifest.hlo_path(&info);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {name}"))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut s = self.stats.lock().unwrap();
            s.compiles += 1;
            s.compile_secs += dt;
            s.cache_misses += 1;
        }
        let loaded = Arc::new(LoadedArtifact { info, exe });
        let mut w = self.cache.write().unwrap();
        // Another thread may have compiled concurrently; first write wins.
        Ok(w.entry(name.to_string()).or_insert(loaded).clone())
    }

    /// Load + execute in one call, with timing recorded in the stats.
    pub fn run(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let exe = self.load(name)?;
        let t0 = Instant::now();
        let out = exe.execute(inputs)?;
        let dt = t0.elapsed().as_secs_f64();
        let mut s = self.stats.lock().unwrap();
        s.executions += 1;
        s.execute_secs += dt;
        Ok(out)
    }

    /// Upload a host tensor to a device-resident buffer (weights pinned at
    /// tenant registration / first use; reused across launches).
    pub fn to_device(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
            .map_err(|e| anyhow!("to_device: {e:?}"))
    }

    /// Precompile every artifact matching a predicate (warm-up; the serving
    /// path then never compiles).
    pub fn warmup(&self, pred: impl Fn(&ArtifactInfo) -> bool) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| pred(a))
            .map(|a| a.name.clone())
            .collect();
        for n in &names {
            self.load(n)?;
        }
        Ok(names.len())
    }
}

// SAFETY: the xla crate's raw pointers are not Sync-annotated, but the PJRT
// CPU client is thread-safe for compile/execute (it is exactly how the C API
// is used from multi-threaded serving frameworks). The engine wraps all
// mutable state in locks.
#[allow(unsafe_code)]
unsafe impl Send for PjrtEngine {}
// SAFETY: see the Send impl above — thread-safe client, locked mutable state.
#[allow(unsafe_code)]
unsafe impl Sync for PjrtEngine {}

#[cfg(test)]
mod tests {
    // Engine tests that need real artifacts live in
    // rust/tests/integration_runtime.rs (they require `make artifacts`).
    use super::*;

    #[test]
    fn missing_dir_is_error() {
        assert!(PjrtEngine::new("/nonexistent/artifacts").is_err());
    }
}
