//! Host-side tensors crossing the PJRT boundary.
//!
//! `HostTensor` is the coordinator's in-memory representation of request
//! payloads and model weights: a dense row-major f32 buffer plus shape.
//! Conversion to/from `xla::Literal` happens only at the runtime boundary.

use crate::util::prng::Rng;

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match buffer length {}",
            data.len()
        );
        HostTensor { shape, data }
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        HostTensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    /// Uniform(-0.5, 0.5) tensor — deterministic per seed; stands in for
    /// per-tenant weights/inputs in tests, benches and examples.
    pub fn random(shape: &[usize], rng: &mut Rng) -> Self {
        let n = shape.iter().product();
        let data = (0..n).map(|_| rng.next_f64() as f32 - 0.5).collect();
        HostTensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Convert to an XLA literal of the same shape.
    pub fn to_literal(&self) -> Result<xla::Literal, xla::Error> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&self.data).reshape(&dims)
    }

    /// Read a literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self, xla::Error> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(HostTensor::new(dims, data))
    }

    /// Slice out problem `r` of a leading-R batch: `[R, ...] -> [...]`.
    pub fn slice_problem(&self, r: usize) -> HostTensor {
        assert!(self.rank() >= 1 && r < self.shape[0]);
        let inner: usize = self.shape[1..].iter().product();
        HostTensor::new(
            self.shape[1..].to_vec(),
            self.data[r * inner..(r + 1) * inner].to_vec(),
        )
    }

    /// Stack `parts` (all the same shape) into a leading-R batch, padding
    /// with zero-problems up to `r_total`. This is the batcher's gather
    /// step: R tenant sub-problems -> one super-kernel operand.
    pub fn stack(parts: &[&HostTensor], r_total: usize) -> HostTensor {
        assert!(!parts.is_empty() && parts.len() <= r_total);
        let inner_shape = parts[0].shape.clone();
        let inner: usize = inner_shape.iter().product();
        let mut data = Vec::with_capacity(r_total * inner);
        for p in parts {
            assert_eq!(p.shape, inner_shape, "stack requires uniform shapes");
            data.extend_from_slice(&p.data);
        }
        data.resize(r_total * inner, 0.0);
        let mut shape = vec![r_total];
        shape.extend_from_slice(&inner_shape);
        HostTensor::new(shape, data)
    }

    /// Stack into a preallocated buffer (the hot-path variant: no
    /// allocation when the pool already has a tensor of the right size).
    pub fn stack_into(parts: &[&HostTensor], r_total: usize, out: &mut HostTensor) {
        assert!(!parts.is_empty() && parts.len() <= r_total);
        let inner_shape = &parts[0].shape;
        let inner: usize = inner_shape.iter().product();
        out.shape.clear();
        out.shape.push(r_total);
        out.shape.extend_from_slice(inner_shape);
        out.data.clear();
        out.data.reserve(r_total * inner);
        for p in parts {
            debug_assert_eq!(&p.shape, inner_shape);
            out.data.extend_from_slice(&p.data);
        }
        out.data.resize(r_total * inner, 0.0);
    }

    /// Max |a - b| across elements (shape-checked).
    pub fn max_abs_diff(&self, other: &HostTensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Reference batched GEMM on the host: `out[r] = a[r] @ b[r]`.
///
/// The rust-side oracle used by integration tests to validate what comes
/// back from the PJRT executables (mirrors python `kernels/ref.py`).
pub fn host_batched_gemm(a: &HostTensor, b: &HostTensor) -> HostTensor {
    assert_eq!(a.rank(), 3);
    assert_eq!(b.rank(), 3);
    let (r, m, k) = (a.shape[0], a.shape[1], a.shape[2]);
    let (rb, kb, n) = (b.shape[0], b.shape[1], b.shape[2]);
    assert_eq!(r, rb);
    assert_eq!(k, kb);
    let mut out = vec![0.0f32; r * m * n];
    for ri in 0..r {
        let ab = &a.data[ri * m * k..(ri + 1) * m * k];
        let bb = &b.data[ri * k * n..(ri + 1) * k * n];
        let ob = &mut out[ri * m * n..(ri + 1) * m * n];
        for i in 0..m {
            for kk in 0..k {
                let av = ab[i * k + kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &bb[kk * n..(kk + 1) * n];
                let orow = &mut ob[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
        }
    }
    HostTensor::new(vec![r, m, n], out)
}

/// Reference fused linear: `relu(a @ b + bias)`, bias `[R, 1, N]`.
pub fn host_fused_linear(a: &HostTensor, b: &HostTensor, bias: &HostTensor) -> HostTensor {
    let mut out = host_batched_gemm(a, b);
    let (r, m, n) = (out.shape[0], out.shape[1], out.shape[2]);
    assert_eq!(bias.shape, vec![r, 1, n]);
    for ri in 0..r {
        for i in 0..m {
            for j in 0..n {
                let idx = ri * m * n + i * n + j;
                out.data[idx] = (out.data[idx] + bias.data[ri * n + j]).max(0.0);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stack_and_slice_roundtrip() {
        let mut rng = Rng::new(1);
        let a = HostTensor::random(&[2, 3], &mut rng);
        let b = HostTensor::random(&[2, 3], &mut rng);
        let stacked = HostTensor::stack(&[&a, &b], 4);
        assert_eq!(stacked.shape, vec![4, 2, 3]);
        assert_eq!(stacked.slice_problem(0), a);
        assert_eq!(stacked.slice_problem(1), b);
        // Padding problems are zero.
        assert!(stacked.slice_problem(2).data.iter().all(|&x| x == 0.0));
        assert!(stacked.slice_problem(3).data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn stack_into_matches_stack() {
        let mut rng = Rng::new(2);
        let a = HostTensor::random(&[4, 4], &mut rng);
        let b = HostTensor::random(&[4, 4], &mut rng);
        let want = HostTensor::stack(&[&a, &b], 8);
        let mut got = HostTensor::zeros(&[1]);
        HostTensor::stack_into(&[&a, &b], 8, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    #[should_panic]
    fn stack_rejects_mixed_shapes() {
        let a = HostTensor::zeros(&[2, 3]);
        let b = HostTensor::zeros(&[3, 2]);
        HostTensor::stack(&[&a, &b], 2);
    }

    #[test]
    fn host_gemm_identity() {
        let mut eye = HostTensor::zeros(&[1, 3, 3]);
        for i in 0..3 {
            eye.data[i * 3 + i] = 1.0;
        }
        let mut rng = Rng::new(3);
        let b = HostTensor::random(&[1, 3, 3], &mut rng);
        let out = host_batched_gemm(&eye, &b);
        assert!(out.max_abs_diff(&b) < 1e-6);
    }

    #[test]
    fn host_gemm_known_values() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let a = HostTensor::new(vec![1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = HostTensor::new(vec![1, 2, 2], vec![1.0; 4]);
        let out = host_batched_gemm(&a, &b);
        assert_eq!(out.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn fused_linear_clamps_at_zero() {
        let a = HostTensor::new(vec![1, 1, 1], vec![-2.0]);
        let b = HostTensor::new(vec![1, 1, 1], vec![3.0]);
        let bias = HostTensor::new(vec![1, 1, 1], vec![1.0]);
        let out = host_fused_linear(&a, &b, &bias);
        assert_eq!(out.data, vec![0.0]); // relu(-6 + 1)
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let a = HostTensor::random(&[8], &mut Rng::new(7));
        let b = HostTensor::random(&[8], &mut Rng::new(7));
        assert_eq!(a, b);
    }
}
