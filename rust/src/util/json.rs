//! Tiny JSON writer + reader.
//!
//! `serde`/`serde_json` are not vendored, so the artifact manifest and the
//! metrics snapshots use this minimal implementation. The parser accepts the
//! JSON subset our own tools emit (objects, arrays, strings, numbers, bools,
//! null); it is not a general-purpose validator.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. `BTreeMap` keeps emission deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse from text.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected ',' or ']', got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected ',' or '}}', got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("name", Json::str("conv2_2")),
            ("m", Json::num(256)),
            ("ok", Json::Bool(true)),
            ("tags", Json::Arr(vec![Json::str("a"), Json::str("b")])),
            ("nothing", Json::Null),
        ]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2.5, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x\ny")
        );
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::num(26).to_string(), "26");
        assert_eq!(Json::num(2.5).to_string(), "2.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn string_escapes_roundtrip() {
        let j = Json::str("quote\" slash\\ nl\n tab\t");
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""A""#).unwrap();
        assert_eq!(j.as_str(), Some("A"));
    }
}
