//! Small statistics helpers shared by the metrics layer, the bench harness
//! and the experiment reports.

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation. Returns 0.0 for slices shorter than 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation (std/mean); 0.0 when the mean is 0.
pub fn cov(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        std_dev(xs) / m
    }
}

/// Geometric mean. All inputs must be positive; returns 0.0 when empty.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    debug_assert!(xs.iter().all(|&x| x > 0.0), "geomean needs positive inputs");
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Percentile via linear interpolation on a *sorted* slice (p in [0, 100]).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Percentile of an unsorted slice (copies + sorts).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Min/max helpers that tolerate NaN-free data.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Summary of a sample, used in bench output and experiment tables.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n: v.len(),
            mean: mean(&v),
            std: std_dev(&v),
            min: if v.is_empty() { 0.0 } else { v[0] },
            p50: percentile_sorted(&v, 50.0),
            p90: percentile_sorted(&v, 90.0),
            p99: percentile_sorted(&v, 99.0),
            max: if v.is_empty() { 0.0 } else { v[v.len() - 1] },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_slices_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_single_element() {
        assert_eq!(percentile(&[42.0], 99.0), 42.0);
    }

    #[test]
    fn summary_is_consistent() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!(s.p90 > s.p50 && s.p99 > s.p90);
    }

    #[test]
    fn cov_of_constant_is_zero() {
        assert_eq!(cov(&[3.0, 3.0, 3.0]), 0.0);
    }
}
