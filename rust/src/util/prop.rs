//! Minimal property-based testing support.
//!
//! `proptest` is not vendored in this offline environment, so we provide the
//! subset the test-suite needs: a seeded case runner with shrinking-free
//! failure reporting (the failing seed + case index is printed, which is
//! enough to reproduce deterministically), plus generator combinators built
//! on [`crate::util::prng::Rng`].

use crate::util::prng::Rng;

/// Number of cases each property runs by default.
pub const DEFAULT_CASES: usize = 256;

/// Run `prop` for `cases` random cases. On panic, re-raises with the seed and
/// case index embedded so the exact failing input can be regenerated.
pub fn run_prop<F>(name: &str, seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng),
{
    for case in 0..cases {
        // Derive a per-case seed so failures identify a single case.
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (reproduce with seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Convenience wrapper using the default case count.
pub fn check<F>(name: &str, seed: u64, prop: F)
where
    F: FnMut(&mut Rng),
{
    run_prop(name, seed, DEFAULT_CASES, prop);
}

/// Generate a vector of length in `[min_len, max_len]` with `gen` per element.
pub fn vec_of<T>(
    rng: &mut Rng,
    min_len: usize,
    max_len: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
) -> Vec<T> {
    let len = rng.gen_range_inclusive(min_len as u64, max_len as u64) as usize;
    (0..len).map(|_| gen(rng)).collect()
}

/// A biased "size" generator: mostly small values, occasionally large — the
/// distribution that shakes out boundary bugs fastest.
pub fn sized(rng: &mut Rng, max: u64) -> u64 {
    debug_assert!(max >= 1);
    match rng.gen_range(10) {
        0..=5 => rng.gen_range_inclusive(1, max.min(8)),
        6..=8 => rng.gen_range_inclusive(1, max.min(64).max(1)),
        _ => rng.gen_range_inclusive(1, max),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        run_prop("count", 1, 50, |_rng| {
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_seed() {
        run_prop("fails", 2, 50, |rng| {
            // Fails at the first case where a generated value exceeds 10.
            assert!(rng.gen_range(100) <= 10, "value too big");
        });
    }

    #[test]
    fn vec_of_respects_bounds() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let v = vec_of(&mut rng, 2, 9, |r| r.gen_range(5));
            assert!((2..=9).contains(&v.len()));
        }
    }

    #[test]
    fn sized_in_bounds_and_biased_small() {
        let mut rng = Rng::new(4);
        let mut small = 0;
        for _ in 0..1000 {
            let v = sized(&mut rng, 10_000);
            assert!((1..=10_000).contains(&v));
            if v <= 8 {
                small += 1;
            }
        }
        assert!(small > 400, "expected a bias to small sizes, got {small}");
    }
}
