//! Shared infrastructure: PRNG, statistics, property-test runner, bench
//! harness, and a minimal JSON reader/writer. Everything here exists because
//! the offline environment vendors only the `xla` crate's dependency closure
//! (no rand / proptest / criterion / serde).

pub mod bench;
pub mod json;
pub mod modelcheck;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod sync;
