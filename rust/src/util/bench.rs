//! Criterion-less benchmark harness.
//!
//! `criterion` is not vendored offline, so every `benches/*.rs` binary uses
//! this harness instead: warmup + timed iterations, robust summary statistics,
//! and table/CSV emission that mirrors the paper's figures and tables.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;

/// One benchmark measurement: wall-clock samples of a closure.
pub struct Bencher {
    pub warmup_iters: usize,
    pub sample_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            sample_iters: 10,
        }
    }
}

impl Bencher {
    pub fn new(warmup_iters: usize, sample_iters: usize) -> Self {
        Self {
            warmup_iters,
            sample_iters,
        }
    }

    /// Time `f` returning per-iteration durations (seconds).
    pub fn run<F: FnMut()>(&self, mut f: F) -> Vec<f64> {
        for _ in 0..self.warmup_iters {
            f();
        }
        (0..self.sample_iters)
            .map(|_| {
                let t0 = Instant::now();
                f();
                t0.elapsed().as_secs_f64()
            })
            .collect()
    }

    /// Time `f` and summarize.
    pub fn summarize<F: FnMut()>(&self, f: F) -> Summary {
        Summary::of(&self.run(f))
    }
}

/// Format a duration given in seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Format a throughput in FLOP/s with an adaptive unit.
pub fn fmt_flops(f: f64) -> String {
    if f >= 1e12 {
        format!("{:.2} TFLOP/s", f / 1e12)
    } else if f >= 1e9 {
        format!("{:.2} GFLOP/s", f / 1e9)
    } else if f >= 1e6 {
        format!("{:.2} MFLOP/s", f / 1e6)
    } else {
        format!("{f:.0} FLOP/s")
    }
}

/// Simple fixed-width table writer for bench output; mirrors the row/series
/// layout of the paper's figures so EXPERIMENTS.md can quote it verbatim.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width must match header width"
        );
        self.rows.push(cells.to_vec());
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push_str(&format!(
            "{}\n",
            "-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1))
        ));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Render as CSV (for plotting / EXPERIMENTS.md appendices).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Print the table and also write the CSV next to the bench results.
    pub fn emit(&self, name: &str) {
        println!("{}", self.render());
        let dir = std::path::Path::new("results");
        if std::fs::create_dir_all(dir).is_ok() {
            let path = dir.join(format!("{name}.csv"));
            if let Err(e) = std::fs::write(&path, self.to_csv()) {
                eprintln!("warn: could not write {path:?}: {e}");
            } else {
                println!("[csv written to {}]", path.display());
            }
        }
    }
}

/// Machine-readable bench result — one `results/BENCH_<name>.json` per
/// figure bench, all sharing one schema (`name`, `throughput`, `p50`,
/// `p99`, `slo_attainment`) so the perf trajectory is trackable across
/// PRs and CI can upload the files as artifacts. Fields a bench has no
/// natural value for stay at 0 (`slo_attainment`: null); each bench's
/// field semantics are listed in the README's Performance section.
pub struct BenchJson {
    name: String,
    /// Headline rate: requests-, rounds-, or FLOP-per-second — whatever
    /// the figure's y-axis is.
    throughput: f64,
    /// Median of the bench's latency-like distribution, seconds.
    p50_s: f64,
    /// Tail of the same distribution, seconds.
    p99_s: f64,
    /// Fraction of deadline-carrying requests that met their SLO.
    slo_attainment: Option<f64>,
    /// The scale the headline number was measured at (devices for fig8,
    /// simulated devices for fig13, nodes for fig14). Baseline comparison
    /// (`scripts/bench_gate.py`) only compares runs at matching scale —
    /// a 4-device throughput is not a regression floor for a 1-device run.
    scale: Option<f64>,
}

impl BenchJson {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            throughput: 0.0,
            p50_s: 0.0,
            p99_s: 0.0,
            slo_attainment: None,
            scale: None,
        }
    }

    pub fn throughput(mut self, v: f64) -> Self {
        self.throughput = v;
        self
    }

    pub fn p50_s(mut self, v: f64) -> Self {
        self.p50_s = v;
        self
    }

    pub fn p99_s(mut self, v: f64) -> Self {
        self.p99_s = v;
        self
    }

    pub fn slo_attainment(mut self, v: f64) -> Self {
        self.slo_attainment = Some(v);
        self
    }

    pub fn scale(mut self, v: f64) -> Self {
        self.scale = Some(v);
        self
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("throughput", Json::num(self.throughput)),
            ("p50", Json::num(self.p50_s)),
            ("p99", Json::num(self.p99_s)),
            (
                "slo_attainment",
                self.slo_attainment.map_or(Json::Null, Json::num),
            ),
            ("scale", self.scale.map_or(Json::Null, Json::num)),
        ])
    }

    /// Write `results/BENCH_<name>.json` (best-effort, like the CSVs).
    pub fn write(&self) {
        let dir = std::path::Path::new("results");
        if std::fs::create_dir_all(dir).is_err() {
            return;
        }
        let path = dir.join(format!("BENCH_{}.json", self.name));
        match std::fs::write(&path, self.to_json().to_string()) {
            Ok(()) => println!("[bench json written to {}]", path.display()),
            Err(e) => eprintln!("warn: could not write {path:?}: {e}"),
        }
    }
}

/// Banner printed at the top of each figure/table bench binary.
pub fn banner(id: &str, claim: &str) {
    println!("==============================================================");
    println!("  {id}");
    println!("  paper claim: {claim}");
    println!("==============================================================");
}

/// Measure wall-clock of a single invocation.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_counts_iterations() {
        let mut calls = 0usize;
        let b = Bencher::new(2, 5);
        let samples = b.run(|| calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(samples.len(), 5);
        assert!(samples.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn table_renders_and_escapes_csv() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "x,y".into()]);
        let txt = t.render();
        assert!(txt.contains('a') && txt.contains("x,y"));
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn bench_json_schema_round_trips() {
        let j = BenchJson::new("fig0_test")
            .throughput(1234.5)
            .p50_s(0.001)
            .p99_s(0.005)
            .slo_attainment(0.99)
            .scale(4.0)
            .to_json();
        let back = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("name").unwrap().as_str(), Some("fig0_test"));
        assert_eq!(back.get("throughput").unwrap().as_f64(), Some(1234.5));
        assert_eq!(back.get("p50").unwrap().as_f64(), Some(0.001));
        assert_eq!(back.get("p99").unwrap().as_f64(), Some(0.005));
        assert_eq!(back.get("slo_attainment").unwrap().as_f64(), Some(0.99));
        assert_eq!(back.get("scale").unwrap().as_f64(), Some(4.0));
        // Unset attainment and scale serialize as null.
        let j2 = BenchJson::new("fig0_na").to_json();
        let back2 = crate::util::json::Json::parse(&j2.to_string()).unwrap();
        assert!(matches!(
            back2.get("slo_attainment"),
            Some(crate::util::json::Json::Null)
        ));
        assert!(matches!(
            back2.get("scale"),
            Some(crate::util::json::Json::Null)
        ));
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(2.0), "2.000 s");
        assert_eq!(fmt_secs(0.002), "2.000 ms");
        assert_eq!(fmt_secs(2e-6), "2.000 us");
        assert!(fmt_flops(3.2e12).contains("TFLOP"));
        assert!(fmt_flops(3.2e9).contains("GFLOP"));
    }
}
