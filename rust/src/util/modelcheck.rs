//! A hand-rolled loom-style deterministic model checker for the
//! coordinator's synchronization protocol (no new vendored deps — the
//! offline environment has no `loom`/`shuttle`).
//!
//! ## How it works
//!
//! Code under test is written against the
//! [`crate::coordinator::protocol::SyncEnv`] abstraction. Under
//! [`ModelEnv`], every channel operation (send / recv / try_recv), spawn
//! start, join, and explicit [`SyncEnv::yield_now`] becomes a **decision
//! point**: the virtual thread parks and a scheduler — running on the
//! thread that called [`explore`] — picks which parked thread performs its
//! pending operation next. Virtual threads are real OS threads driven
//! cooperatively: exactly one is between decision points at any moment, so
//! every execution is a deterministic function of the schedule (the
//! sequence of choices).
//!
//! [`explore`] enumerates schedules by DFS over the decision tree with
//! schedule-prefix replay: run a schedule to completion recording, at each
//! step, the canonical list of enabled threads and the index chosen; then
//! backtrack to the deepest step with an untried alternative and re-execute
//! with that prefix. Two standard soundness/state-space controls:
//!
//! * **Bounded preemption** ([`CheckOpts::max_preemptions`]): choosing a
//!   thread other than the previously-running one *while the previous one
//!   is still enabled* counts as a preemption; schedules exceeding the cap
//!   are not explored. With the cap at `usize::MAX` exploration is fully
//!   exhaustive; small caps (2–3) catch the overwhelming majority of
//!   concurrency bugs (CHESS) at a fraction of the schedule count.
//! * **State hashing** ([`CheckOpts::hash_states`], off by default): prune
//!   a schedule when the scheduler-visible state (thread statuses +
//!   pending ops + channel mirrors of [`ProtoPayload::fingerprint`]s)
//!   repeats. This is a *heuristic*: thread-local data (loop counters,
//!   accumulators) is not part of the hash, so pruning can in principle
//!   skip states that differ only thread-locally. Leave it off for
//!   soundness-critical runs; turn it on to tame symmetric workloads.
//!
//! **Deadlock detection**: if every live thread is parked and none is
//! enabled (e.g. the driver blocked on a `collect` that can never arrive —
//! the "stuck submitter"), the run fails with the parked-op listing.
//!
//! ## Determinism requirements
//!
//! Bodies must be deterministic: no wall-clock reads, no RNG, no
//! iteration over `HashMap`s whose order feeds scheduling-visible
//! behavior. Bodies must also join every virtual thread they spawn
//! (dropping a [`ModelJoin`] unjoined detaches the OS thread; the
//! [`crate::coordinator::protocol::LaneProtocol`] joins its workers on
//! drop, so protocol-based tests get this for free).
//!
//! All [`explore`] calls are serialized process-wide (one global gate), so
//! model-check `#[test]`s can run under the default parallel test harness.

use std::cell::Cell;
use std::collections::hash_map::DefaultHasher;
use std::collections::{HashSet, VecDeque};
use std::hash::{Hash, Hasher};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};

use crate::coordinator::protocol::{
    ProtoJoin, ProtoPayload, ProtoReceiver, ProtoSender, SyncEnv,
};

// ---------------------------------------------------------------------------
// Options / results
// ---------------------------------------------------------------------------

/// Exploration limits. Defaults suit small protocol models (a driver plus
/// a handful of lane workers, tens of operations).
#[derive(Clone, Copy, Debug)]
pub struct CheckOpts {
    /// Hard cap on explored schedules; exceeding it sets
    /// [`CheckStats::truncated`] instead of looping forever.
    pub max_schedules: usize,
    /// Bounded-preemption cap (see module docs). `usize::MAX` = fully
    /// exhaustive.
    pub max_preemptions: usize,
    /// Per-schedule step cap — a livelock backstop.
    pub max_steps: usize,
    /// Visited-state pruning (heuristic; see module docs).
    pub hash_states: bool,
}

impl Default for CheckOpts {
    fn default() -> Self {
        Self {
            max_schedules: 50_000,
            max_preemptions: 3,
            max_steps: 10_000,
            hash_states: false,
        }
    }
}

/// Summary of a completed exploration (no invariant violated).
#[derive(Clone, Copy, Debug)]
pub struct CheckStats {
    /// Schedules executed to completion (including pruned ones).
    pub schedules: usize,
    /// Schedules cut short by state-hash pruning.
    pub pruned: usize,
    /// True if `max_schedules` stopped exploration before the DFS
    /// frontier was exhausted — the run was NOT exhaustive.
    pub truncated: bool,
    /// Deepest decision-point count observed in any schedule.
    pub max_depth: usize,
}

impl std::fmt::Display for CheckStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} schedules explored ({} pruned, max depth {}{})",
            self.schedules,
            self.pruned,
            self.max_depth,
            if self.truncated { ", TRUNCATED" } else { "" }
        )
    }
}

/// A schedule that violated an invariant: the panic message (or deadlock
/// report) plus the decision trace that reached it.
#[derive(Clone, Debug)]
pub struct CheckFailure {
    /// Schedules executed up to and including the failing one.
    pub schedules: usize,
    pub message: String,
    /// Human-readable decision trace of the failing schedule.
    pub trace: Vec<String>,
}

impl std::fmt::Display for CheckFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "schedule {} failed: {}", self.schedules, self.message)?;
        writeln!(f, "decision trace:")?;
        for line in &self.trace {
            writeln!(f, "  {line}")?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Run state
// ---------------------------------------------------------------------------

/// Teardown signal: parked threads woken after an abort unwind with this
/// token; the vthread wrapper swallows it (it is not a failure by itself).
struct AbortToken;

#[derive(Clone, Copy, Debug, Hash, PartialEq, Eq)]
enum Op {
    /// First decision point of every vthread, before its body runs —
    /// scheduling the spawn itself.
    Start,
    Yield,
    Send { chan: usize },
    Recv { chan: usize },
    TryRecv { chan: usize },
    Join { target: usize },
}

impl Op {
    fn label(&self) -> String {
        match self {
            Op::Start => "start".into(),
            Op::Yield => "yield".into(),
            Op::Send { chan } => format!("send(ch{chan})"),
            Op::Recv { chan } => format!("recv(ch{chan})"),
            Op::TryRecv { chan } => format!("try_recv(ch{chan})"),
            Op::Join { target } => format!("join(t{target})"),
        }
    }
}

#[derive(Clone, Debug)]
enum Status {
    /// Registered; its OS thread has not reached the Start decision yet.
    Starting,
    /// Granted — between decision points.
    Running,
    Parked(Op),
    Finished,
}

struct VThread {
    name: String,
    status: Status,
}

/// Scheduler-visible mirror of one typed channel: endpoint counts plus
/// the queued payloads' fingerprints (order-sensitive, for hashing and
/// `recv` enabledness; the typed values live in [`ModelChannel::queue`]).
struct ChanMirror {
    senders: usize,
    receiver_alive: bool,
    fingerprints: VecDeque<u64>,
}

struct RunState {
    threads: Vec<VThread>,
    chans: Vec<ChanMirror>,
    /// Tid currently granted but not yet running (decision handshake).
    grant: Option<usize>,
    aborted: bool,
    failure: Option<String>,
    trace: Vec<String>,
}

struct Run {
    state: Mutex<RunState>,
    cv: Condvar,
}

/// Poison-recovering lock: a vthread that panics while parked (impossible
/// today, but belt-and-braces) must not wedge the whole exploration.
fn lock_run(run: &Run) -> MutexGuard<'_, RunState> {
    run.state.lock().unwrap_or_else(PoisonError::into_inner)
}

impl Run {
    fn new() -> Self {
        Self {
            state: Mutex::new(RunState {
                threads: Vec::new(),
                chans: Vec::new(),
                grant: None,
                aborted: false,
                failure: None,
                trace: Vec::new(),
            }),
            cv: Condvar::new(),
        }
    }

    fn register_thread(&self, name: &str) -> usize {
        let mut st = lock_run(self);
        st.threads.push(VThread { name: name.to_string(), status: Status::Starting });
        st.threads.len() - 1
    }

    fn register_chan(&self) -> usize {
        let mut st = lock_run(self);
        st.chans.push(ChanMirror {
            senders: 1,
            receiver_alive: true,
            fingerprints: VecDeque::new(),
        });
        st.chans.len() - 1
    }

    /// Park at `op` and wait for the scheduler's grant. On abort: panic
    /// with [`AbortToken`] to unwind the vthread — unless the thread is
    /// already unwinding (a `Drop`-path operation), in which case return
    /// silently and let the caller free-run its (non-blocking) effect.
    fn decide(&self, op: Op) {
        let tid = current_tid().expect("model operation outside a model vthread");
        let mut st = lock_run(self);
        loop {
            if st.aborted {
                drop(st);
                if std::thread::panicking() {
                    return;
                }
                std::panic::panic_any(AbortToken);
            }
            match st.grant {
                Some(g) if g == tid => {
                    st.grant = None;
                    st.threads[tid].status = Status::Running;
                    return;
                }
                _ => {
                    if !matches!(st.threads[tid].status, Status::Parked(_)) {
                        st.threads[tid].status = Status::Parked(op);
                        self.cv.notify_all();
                    }
                    st = self
                        .cv
                        .wait(st)
                        .unwrap_or_else(PoisonError::into_inner);
                }
            }
        }
    }

    fn finish(&self, tid: usize) {
        let mut st = lock_run(self);
        st.threads[tid].status = Status::Finished;
        self.cv.notify_all();
    }

    /// Record the first failure and abort the run (wakes every parked
    /// thread for teardown).
    fn fail(&self, message: String) {
        let mut st = lock_run(self);
        if st.failure.is_none() {
            st.failure = Some(message);
        }
        st.aborted = true;
        self.cv.notify_all();
    }

    fn is_aborted(&self) -> bool {
        lock_run(self).aborted
    }
}

// ---------------------------------------------------------------------------
// Global current-run plumbing
// ---------------------------------------------------------------------------

/// Serializes [`explore`] calls process-wide so model tests can run under
/// the parallel test harness.
static EXPLORE_GATE: Mutex<()> = Mutex::new(());
/// The run the current exploration executes under; read by vthreads when
/// they create channels / spawn workers.
static CURRENT: Mutex<Option<Arc<Run>>> = Mutex::new(None);

thread_local! {
    static TID: Cell<Option<usize>> = const { Cell::new(None) };
}

fn current_run() -> Arc<Run> {
    CURRENT
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .clone()
        .expect("ModelEnv operation outside modelcheck::explore()")
}

fn current_tid() -> Option<usize> {
    TID.with(|c| c.get())
}

fn vthread_wrapper(run: Arc<Run>, tid: usize, body: impl FnOnce()) {
    TID.with(|c| c.set(Some(tid)));
    let result = catch_unwind(AssertUnwindSafe(|| {
        run.decide(Op::Start);
        body();
    }));
    if let Err(payload) = result {
        if !payload.is::<AbortToken>() {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            run.fail(format!("thread '{}' panicked: {msg}", thread_name(&run, tid)));
        }
    }
    run.finish(tid);
}

fn thread_name(run: &Run, tid: usize) -> String {
    lock_run(run).threads[tid].name.clone()
}

// ---------------------------------------------------------------------------
// ModelEnv: the checker-instrumented SyncEnv
// ---------------------------------------------------------------------------

/// The model-checking environment: instantiate protocol code with this in
/// place of [`crate::coordinator::protocol::StdEnv`] inside an [`explore`]
/// body.
pub struct ModelEnv;

struct ModelChannel<T> {
    id: usize,
    run: Arc<Run>,
    queue: Mutex<VecDeque<T>>,
}

pub struct ModelSender<T>(Arc<ModelChannel<T>>);
pub struct ModelReceiver<T>(Arc<ModelChannel<T>>);

impl<T> Clone for ModelSender<T> {
    fn clone(&self) -> Self {
        let mut st = lock_run(&self.0.run);
        st.chans[self.0.id].senders += 1;
        drop(st);
        ModelSender(self.0.clone())
    }
}

impl<T> Drop for ModelSender<T> {
    fn drop(&mut self) {
        // Not a decision point: a drop executes atomically with the
        // running thread's current step (loom-style reduction). It can
        // only *enable* a parked recv (channel closure), and the scheduler
        // recomputes enabledness at every step.
        let mut st = lock_run(&self.0.run);
        st.chans[self.0.id].senders -= 1;
        self.0.run.cv.notify_all();
    }
}

impl<T> Drop for ModelReceiver<T> {
    fn drop(&mut self) {
        let mut st = lock_run(&self.0.run);
        st.chans[self.0.id].receiver_alive = false;
        self.0.run.cv.notify_all();
    }
}

impl<T: ProtoPayload> ProtoSender<T> for ModelSender<T> {
    fn send(&self, value: T) -> Result<(), T> {
        self.0.run.decide(Op::Send { chan: self.0.id });
        let mut st = lock_run(&self.0.run);
        if !st.chans[self.0.id].receiver_alive {
            return Err(value);
        }
        st.chans[self.0.id].fingerprints.push_back(value.fingerprint());
        drop(st);
        self.0
            .queue
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .push_back(value);
        Ok(())
    }
}

impl<T: ProtoPayload> ProtoReceiver<T> for ModelReceiver<T> {
    fn recv(&self) -> Option<T> {
        // Enabled only when an item is queued or every sender is gone, so
        // a granted recv never busy-waits: it pops or observes closure.
        self.0.run.decide(Op::Recv { chan: self.0.id });
        self.pop()
    }

    fn try_recv(&self) -> Option<T> {
        self.0.run.decide(Op::TryRecv { chan: self.0.id });
        self.pop()
    }
}

impl<T> ModelReceiver<T> {
    fn pop(&self) -> Option<T> {
        let mut st = lock_run(&self.0.run);
        if st.chans[self.0.id].fingerprints.is_empty() {
            return None;
        }
        st.chans[self.0.id].fingerprints.pop_front();
        drop(st);
        self.0
            .queue
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .pop_front()
    }
}

pub struct ModelJoin {
    target: usize,
    run: Arc<Run>,
    os: Option<std::thread::JoinHandle<()>>,
}

impl ProtoJoin for ModelJoin {
    fn join(mut self) {
        // After an abort the vthreads are already unwinding; go straight
        // to the OS join (a scheduled Join decision would just re-panic).
        if !self.run.is_aborted() {
            self.run.decide(Op::Join { target: self.target });
        }
        if let Some(h) = self.os.take() {
            let _ = h.join();
        }
    }
}

impl SyncEnv for ModelEnv {
    type Sender<T: ProtoPayload> = ModelSender<T>;
    type Receiver<T: ProtoPayload> = ModelReceiver<T>;
    type Join = ModelJoin;

    fn channel<T: ProtoPayload>() -> (ModelSender<T>, ModelReceiver<T>) {
        let run = current_run();
        let id = run.register_chan();
        let ch = Arc::new(ModelChannel { id, run, queue: Mutex::new(VecDeque::new()) });
        (ModelSender(ch.clone()), ModelReceiver(ch))
    }

    fn spawn(name: String, f: impl FnOnce() + Send + 'static) -> ModelJoin {
        let run = current_run();
        let tid = run.register_thread(&name);
        let r2 = run.clone();
        let os = std::thread::Builder::new()
            .name(format!("mc-{name}"))
            .spawn(move || vthread_wrapper(r2, tid, f))
            .expect("spawn model vthread");
        ModelJoin { target: tid, run, os: Some(os) }
    }

    fn yield_now() {
        if current_tid().is_some() {
            current_run().decide(Op::Yield);
        }
    }
}

// ---------------------------------------------------------------------------
// The scheduler + DFS explorer
// ---------------------------------------------------------------------------

/// One decision point's record: how many threads were enabled (canonical
/// order), which index was chosen, and the preemption bookkeeping needed
/// to bound the backtrack.
#[derive(Clone, Copy)]
struct StepRec {
    enabled: usize,
    idx: usize,
    prev_enabled: bool,
    preempts_before: usize,
}

enum Outcome {
    Done(Vec<StepRec>),
    Pruned(Vec<StepRec>),
    Failed,
}

fn op_enabled(st: &RunState, op: &Op) -> bool {
    match op {
        Op::Start | Op::Yield | Op::Send { .. } | Op::TryRecv { .. } => true,
        Op::Recv { chan } => {
            let c = &st.chans[*chan];
            !c.fingerprints.is_empty() || c.senders == 0
        }
        Op::Join { target } => matches!(st.threads[*target].status, Status::Finished),
    }
}

fn hash_state(st: &RunState) -> u64 {
    let mut h = DefaultHasher::new();
    for t in &st.threads {
        match &t.status {
            Status::Starting => 0u8.hash(&mut h),
            Status::Running => 1u8.hash(&mut h),
            Status::Parked(op) => {
                2u8.hash(&mut h);
                op.hash(&mut h);
            }
            Status::Finished => 3u8.hash(&mut h),
        }
    }
    for c in &st.chans {
        c.senders.hash(&mut h);
        c.receiver_alive.hash(&mut h);
        c.fingerprints.hash(&mut h);
    }
    h.finish()
}

/// Drive one schedule to completion, replaying `prefix` then extending
/// with the canonical default (index 0 = the previously-running thread
/// when still enabled — the non-preempting continuation).
fn run_schedule(
    run: &Run,
    prefix: &[usize],
    opts: &CheckOpts,
    seen: &mut HashSet<u64>,
) -> Outcome {
    let mut records: Vec<StepRec> = Vec::new();
    let mut prev: Option<usize> = None;
    let mut preempts = 0usize;
    let mut st = lock_run(run);
    loop {
        // Quiesce: wait until nothing is starting/running and no grant is
        // outstanding — every live thread parked at its next operation.
        while !st.aborted
            && (st.grant.is_some()
                || st
                    .threads
                    .iter()
                    .any(|t| matches!(t.status, Status::Starting | Status::Running)))
        {
            st = run.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        if st.aborted {
            // A vthread recorded a failure (assert / panic) and aborted.
            return Outcome::Failed;
        }
        if st.threads.iter().all(|t| matches!(t.status, Status::Finished)) {
            return Outcome::Done(records);
        }

        // Canonical enabled list: previously-running thread first (the
        // non-preempting choice), then the rest by ascending tid.
        let parked: Vec<usize> = (0..st.threads.len())
            .filter(|&i| matches!(st.threads[i].status, Status::Parked(_)))
            .collect();
        let mut enabled: Vec<usize> = parked
            .iter()
            .copied()
            .filter(|&i| match &st.threads[i].status {
                Status::Parked(op) => op_enabled(&st, op),
                _ => false,
            })
            .collect();
        let prev_enabled = match prev {
            Some(p) => enabled.contains(&p),
            None => false,
        };
        if prev_enabled {
            let p = prev.unwrap();
            enabled.retain(|&t| t != p);
            enabled.insert(0, p);
        }

        if enabled.is_empty() {
            // Deadlock: live threads exist but none can make progress —
            // e.g. the submitter stuck on a completion that cannot arrive.
            let stuck: Vec<String> = parked
                .iter()
                .map(|&i| match &st.threads[i].status {
                    Status::Parked(op) => {
                        format!("'{}' blocked at {}", st.threads[i].name, op.label())
                    }
                    _ => unreachable!(),
                })
                .collect();
            st.failure = Some(format!("deadlock: {}", stuck.join(", ")));
            st.aborted = true;
            run.cv.notify_all();
            return Outcome::Failed;
        }
        if records.len() >= opts.max_steps {
            st.failure = Some(format!(
                "schedule exceeded {} steps (livelock?)",
                opts.max_steps
            ));
            st.aborted = true;
            run.cv.notify_all();
            return Outcome::Failed;
        }
        if opts.hash_states && records.len() >= prefix.len() {
            let h = hash_state(&st);
            if !seen.insert(h) {
                st.aborted = true;
                run.cv.notify_all();
                return Outcome::Pruned(records);
            }
        }

        let idx = if records.len() < prefix.len() {
            let want = prefix[records.len()];
            if want >= enabled.len() {
                st.failure = Some(format!(
                    "non-deterministic body: replay step {} wants choice {want} \
                     but only {} threads are enabled",
                    records.len(),
                    enabled.len()
                ));
                st.aborted = true;
                run.cv.notify_all();
                return Outcome::Failed;
            }
            want
        } else {
            0
        };
        let chosen = enabled[idx];
        records.push(StepRec {
            enabled: enabled.len(),
            idx,
            prev_enabled,
            preempts_before: preempts,
        });
        if prev_enabled && idx > 0 {
            preempts += 1;
        }
        if let Status::Parked(op) = &st.threads[chosen].status {
            let op = *op;
            let line = format!(
                "{:3}: {} {}",
                records.len() - 1,
                st.threads[chosen].name,
                op.label()
            );
            st.trace.push(line);
        }
        prev = Some(chosen);
        st.grant = Some(chosen);
        run.cv.notify_all();
    }
}

/// Deepest step with an untried alternative that respects the preemption
/// cap; `None` when the DFS frontier is exhausted.
fn next_prefix(records: &[StepRec], cap: usize) -> Option<Vec<usize>> {
    for s in (0..records.len()).rev() {
        let r = records[s];
        if r.idx + 1 >= r.enabled {
            continue;
        }
        let cost = usize::from(r.prev_enabled); // any index > 0 preempts
        if r.preempts_before + cost > cap {
            continue;
        }
        let mut prefix: Vec<usize> = records[..s].iter().map(|x| x.idx).collect();
        prefix.push(r.idx + 1);
        return Some(prefix);
    }
    None
}

/// Exhaustively explore every schedule of `body` (up to the
/// bounded-preemption cap). `body` runs once per schedule on a fresh
/// virtual-thread universe; it should build its world from [`ModelEnv`]
/// primitives and assert its invariants inline. Returns the exploration
/// stats, or the first failing schedule.
pub fn explore<F>(name: &str, opts: CheckOpts, body: F) -> Result<CheckStats, CheckFailure>
where
    F: Fn() + Send + Sync + 'static,
{
    let _gate = EXPLORE_GATE.lock().unwrap_or_else(PoisonError::into_inner);
    let body = Arc::new(body);
    let mut prefix: Vec<usize> = Vec::new();
    let mut seen: HashSet<u64> = HashSet::new();
    let mut stats = CheckStats { schedules: 0, pruned: 0, truncated: false, max_depth: 0 };
    loop {
        let run = Arc::new(Run::new());
        *CURRENT.lock().unwrap_or_else(PoisonError::into_inner) = Some(run.clone());
        let root_tid = run.register_thread("main");
        let b = body.clone();
        let r2 = run.clone();
        let root = std::thread::Builder::new()
            .name(format!("mc-{name}"))
            .spawn(move || vthread_wrapper(r2, root_tid, move || (*b)()))
            .expect("spawn model root");
        let outcome = run_schedule(&run, &prefix, &opts, &mut seen);
        // Root unwinds (abort) or completes; its drops join the workers,
        // so after this join the whole virtual universe is quiesced.
        let _ = root.join();
        *CURRENT.lock().unwrap_or_else(PoisonError::into_inner) = None;
        stats.schedules += 1;
        match outcome {
            Outcome::Failed => {
                let st = lock_run(&run);
                let n = st.trace.len();
                return Err(CheckFailure {
                    schedules: stats.schedules,
                    message: st
                        .failure
                        .clone()
                        .unwrap_or_else(|| "<no failure message>".into()),
                    trace: st.trace[n.saturating_sub(60)..].to_vec(),
                });
            }
            Outcome::Done(records) | Outcome::Pruned(records) => {
                if matches!(outcome_kind(&run), OutcomeKind::Pruned) {
                    stats.pruned += 1;
                }
                stats.max_depth = stats.max_depth.max(records.len());
                match next_prefix(&records, opts.max_preemptions) {
                    Some(p) => prefix = p,
                    None => return Ok(stats),
                }
            }
        }
        if stats.schedules >= opts.max_schedules {
            stats.truncated = true;
            return Ok(stats);
        }
    }
}

/// Distinguish Done from Pruned post-match (a pruned run aborted without
/// recording a failure).
enum OutcomeKind {
    Done,
    Pruned,
}

fn outcome_kind(run: &Run) -> OutcomeKind {
    let st = lock_run(run);
    if st.aborted && st.failure.is_none() {
        OutcomeKind::Pruned
    } else {
        OutcomeKind::Done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{ProtoReceiver as _, ProtoSender as _};

    struct Msg(u64);
    impl ProtoPayload for Msg {
        fn fingerprint(&self) -> u64 {
            self.0
        }
    }

    #[test]
    fn explores_multiple_schedules_of_a_two_producer_race() {
        let stats = explore("two-producers", CheckOpts::default(), || {
            let (tx, rx) = ModelEnv::channel::<Msg>();
            let tx2 = tx.clone();
            let a = ModelEnv::spawn("p1".into(), move || {
                tx.send(Msg(1)).ok();
            });
            let b = ModelEnv::spawn("p2".into(), move || {
                tx2.send(Msg(2)).ok();
            });
            let x = rx.recv().expect("first value");
            let y = rx.recv().expect("second value");
            assert_eq!(x.0 + y.0, 3, "both producers deliver exactly once");
            a.join();
            b.join();
        })
        .expect("no schedule violates the invariant");
        println!("two-producer race: {stats}");
        assert!(stats.schedules > 1, "the race must fork the schedule tree");
        assert!(!stats.truncated);
    }

    #[test]
    fn detects_a_deadlocked_receiver_as_a_stuck_submitter() {
        let err = explore("stuck-recv", CheckOpts::default(), || {
            let (tx, rx) = ModelEnv::channel::<Msg>();
            // The sender half stays alive but nothing is ever sent: recv
            // can neither pop nor observe closure.
            let _tx = tx;
            let _ = rx.recv();
        })
        .expect_err("must detect the deadlock");
        assert!(err.message.contains("deadlock"), "got: {}", err.message);
        assert!(err.message.contains("recv"), "got: {}", err.message);
    }

    #[test]
    fn surfaces_an_interleaving_dependent_assertion_failure() {
        // The bug only fires when the consumer runs between the two sends
        // — a schedule an example-based test would almost never hit.
        let err = explore("torn-pair", CheckOpts::default(), || {
            let (tx, rx) = ModelEnv::channel::<Msg>();
            let w = ModelEnv::spawn("producer".into(), move || {
                tx.send(Msg(1)).ok();
                tx.send(Msg(2)).ok();
            });
            let first = rx.recv().expect("one value arrives");
            // Bogus invariant: "pairs arrive atomically".
            let second = rx.try_recv();
            assert!(
                second.is_some(),
                "pair torn: saw {} alone",
                first.0
            );
            let _ = second;
            w.join();
        })
        .expect_err("the checker must find the torn interleaving");
        assert!(err.message.contains("pair torn"), "got: {}", err.message);
        assert!(!err.trace.is_empty(), "failure must carry its schedule");
    }

    #[test]
    fn state_hashing_prunes_symmetric_schedules() {
        let opts = CheckOpts { hash_states: true, ..CheckOpts::default() };
        let stats = explore("symmetric", opts, || {
            let (tx, rx) = ModelEnv::channel::<Msg>();
            let tx2 = tx.clone();
            // Identical payloads → identical fingerprints → symmetric
            // interleavings collapse to one state.
            let a = ModelEnv::spawn("s1".into(), move || {
                tx.send(Msg(7)).ok();
            });
            let b = ModelEnv::spawn("s2".into(), move || {
                tx2.send(Msg(7)).ok();
            });
            assert_eq!(rx.recv().map(|m| m.0), Some(7));
            assert_eq!(rx.recv().map(|m| m.0), Some(7));
            a.join();
            b.join();
        })
        .expect("symmetric workload is invariant-clean");
        println!("symmetric pruning: {stats}");
        assert!(stats.pruned > 0, "hashing must prune symmetric states");
    }

    #[test]
    fn preemption_cap_zero_explores_fewer_schedules() {
        let body = || {
            let (tx, rx) = ModelEnv::channel::<Msg>();
            let tx2 = tx.clone();
            let a = ModelEnv::spawn("p1".into(), move || {
                tx.send(Msg(1)).ok();
                ModelEnv::yield_now();
                tx.send(Msg(2)).ok();
            });
            let b = ModelEnv::spawn("p2".into(), move || {
                tx2.send(Msg(3)).ok();
            });
            for _ in 0..3 {
                let _ = rx.recv();
            }
            a.join();
            b.join();
        };
        let full = explore(
            "cap-full",
            CheckOpts { max_preemptions: usize::MAX, ..CheckOpts::default() },
            body,
        )
        .unwrap();
        let capped = explore(
            "cap-zero",
            CheckOpts { max_preemptions: 0, ..CheckOpts::default() },
            body,
        )
        .unwrap();
        println!("full: {full}; capped: {capped}");
        assert!(capped.schedules < full.schedules);
        assert!(capped.schedules >= 1);
    }
}
