//! Small synchronization helpers shared across the coordinator.

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock a mutex, recovering from poisoning.
///
/// A poisoned mutex only means *some* thread panicked while holding the
/// guard — the coordinator's shared structures (fusion cache, cost model)
/// are counters/caches that remain internally consistent after any panic
/// the lane workers contain (`catch_unwind` converts executor panics into
/// `Err` completions before the guard scope is re-entered). Propagating
/// the poison would turn one contained launch panic into a shard-wide
/// crash on the *next* unrelated `lock()`; recovering keeps the shard
/// serving. See `coordinator::scheduler` tests for the regression this
/// guards against.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::Mutex;

    #[test]
    fn lock_recover_survives_a_poisoned_mutex() {
        let m = Mutex::new(41u64);
        // Poison: panic with the guard held.
        let poisoner = catch_unwind(AssertUnwindSafe(|| {
            let _guard = m.lock().unwrap();
            panic!("poison");
        }));
        assert!(poisoner.is_err());
        assert!(m.is_poisoned(), "the mutex must actually be poisoned");
        // A plain lock().unwrap() would now panic; recovery keeps going
        // and the data is intact.
        let mut g = lock_recover(&m);
        assert_eq!(*g, 41);
        *g += 1;
        drop(g);
        assert_eq!(*lock_recover(&m), 42);
    }
}
