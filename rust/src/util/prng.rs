//! Deterministic pseudo-random number generation.
//!
//! The offline environment does not vendor the `rand` crate, so we carry a
//! small, well-known generator family ourselves: `SplitMix64` for seeding and
//! `Xoshiro256StarStar` as the workhorse. Both are reproducible across
//! platforms, which the simulator relies on (every experiment is seeded).

/// SplitMix64 — used to expand a single `u64` seed into generator state.
///
/// Reference: Steele, Lea, Flood — "Fast splittable pseudorandom number
/// generators" (OOPSLA 2014).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality 64-bit generator.
///
/// Reference: Blackman & Vigna — "Scrambled linear pseudorandom number
/// generators" (2018).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a single seed via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = sm.next_u64();
        }
        // All-zero state is invalid for xoshiro; SplitMix64 cannot produce
        // four consecutive zeros for any seed, but keep the guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as `f32`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` using Lemire's multiply-shift rejection.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range: empty range");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn gen_range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "gen_range_inclusive: lo > hi");
        lo + self.gen_range(hi - lo + 1)
    }

    /// Uniform float in `[lo, hi)`.
    #[inline]
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Exponential variate with rate `lambda` (mean `1/lambda`).
    /// Used for Poisson-process inter-arrival times.
    #[inline]
    pub fn gen_exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0, "gen_exp: lambda must be positive");
        // Avoid ln(0): next_f64 is in [0,1), so 1-u is in (0,1].
        -(1.0 - self.next_f64()).ln() / lambda
    }

    /// Standard normal variate (Box–Muller, one value per call).
    pub fn gen_normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.next_f64(); // (0, 1]
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        mean + std * r * theta.cos()
    }

    /// Log-normal variate parameterized by the *underlying* normal.
    pub fn gen_lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.gen_normal(mu, sigma).exp()
    }

    /// Poisson variate (Knuth for small mean, normal approximation above 30).
    pub fn gen_poisson(&mut self, mean: f64) -> u64 {
        assert!(mean >= 0.0);
        if mean == 0.0 {
            return 0;
        }
        if mean > 30.0 {
            let v = self.gen_normal(mean, mean.sqrt()).round();
            return if v < 0.0 { 0 } else { v as u64 };
        }
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.next_f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose: empty slice");
        &xs[self.gen_range(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_is_deterministic_and_seeded() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let mut c = Rng::new(8);
        let va: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let v = r.gen_range(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn gen_range_inclusive_hits_endpoints() {
        let mut r = Rng::new(3);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            match r.gen_range_inclusive(5, 8) {
                5 => lo_seen = true,
                8 => hi_seen = true,
                v => assert!((5..=8).contains(&v)),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.gen_exp(2.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} should be ~0.5");
    }

    #[test]
    fn normal_moments_close() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean {mean}");
        assert!((var - 4.0).abs() < 0.15, "var {var}");
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Rng::new(6);
        for &m in &[0.5, 4.0, 50.0] {
            let n = 50_000;
            let sum: u64 = (0..n).map(|_| r.gen_poisson(m)).sum();
            let mean = sum as f64 / n as f64;
            assert!(
                (mean - m).abs() < 0.1 * m.max(1.0),
                "poisson mean {mean} vs expected {m}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn gen_bool_probability() {
        let mut r = Rng::new(10);
        let hits = (0..100_000).filter(|_| r.gen_bool(0.3)).count();
        let p = hits as f64 / 100_000.0;
        assert!((p - 0.3).abs() < 0.01, "p {p}");
    }
}
