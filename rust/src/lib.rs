//! # stgpu — Dynamic Space-Time Scheduling for GPU Inference
//!
//! A production-shaped reproduction of *Dynamic Space-Time Scheduling for
//! GPU Inference* (Jain et al., 2018): a multi-tenant inference coordinator
//! that merges same-shape GEMM kernels from disjoint model graphs into
//! batched *super-kernels*, trading off spatial and temporal multiplexing to
//! keep the GPU full while preserving latency predictability and isolation.
//!
//! Three layers (see DESIGN.md):
//! 1. **L1** — a Pallas batched-GEMM super-kernel (`python/compile/kernels`),
//!    AOT-lowered to HLO text at build time.
//! 2. **L2** — JAX compute graphs wrapping the kernel
//!    (`python/compile/model.py`).
//! 3. **L3** — this crate: the rust coordinator (scheduling, batching, SLO
//!    monitoring), the PJRT runtime that executes the AOT artifacts, and the
//!    V100 simulator substrate that stands in for the paper's testbed.

// Unsafe code is denied crate-wide; the only exceptions are the documented
// Send/Sync impls over PJRT handles in `coordinator::fusion_cache` and
// `runtime::engine`, each carrying a `// SAFETY:` justification and a
// per-site `#[allow(unsafe_code)]` (the allowlist is enforced by
// `cargo run -p xtask -- lint`).
#![deny(unsafe_code)]

pub mod config;
pub mod coordinator;
pub mod gpusim;
pub mod metrics;
pub mod models;
pub mod runtime;
pub mod server;
pub mod util;
pub mod workload;
