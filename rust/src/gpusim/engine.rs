//! Discrete-event execution engine: runs multi-tenant kernel workloads under
//! each of the paper's multiplexing policies and reports per-tenant latency,
//! throughput, launch counts and a schedule trace.
//!
//! Policies (paper §3):
//! * [`Policy::Exclusive`] — every tenant gets a *private* GPU (the paper's
//!   single-tenant lower bound; simulated as independent devices).
//! * [`Policy::TimeMux`] — one device, one resident CUDA context at a time,
//!   round-robin quanta with context-switch penalties.
//! * [`Policy::SpaceMuxMps`] — implicit spatial sharing through the MPS
//!   proxy: concurrent kernels, static BW partitioning, straggler anomalies.
//! * [`Policy::SpaceMuxStreams`] — explicit CUDA streams in one process:
//!   concurrent kernels, demand-shared bandwidth, no MPS proxy overhead.
//! * [`Policy::SpaceTime`] — the paper's contribution: per-round inter-model
//!   batching of same-shape GEMMs into super-kernels that fill the device.
//!
//! # Engines
//!
//! [`run`] dispatches to one of two implementations that share this module's
//! report format: the default **vectorized** engine below, and the original
//! per-event reference engine (`engine_legacy`, selected with
//! [`Engine::Legacy`] / `--engine legacy`). The reference engine is kept as
//! the bit-for-bit oracle — the equivalence property test and
//! `benches/fig13_sim_scale.rs` replay both on identical workloads and
//! require bitwise-identical reports.
//!
//! # The vectorized hot path
//!
//! The reference engine pays three per-event costs that dominate cluster-
//! scale runs: it re-derives each kernel's fusion-group key every round
//! (cloning the name `String` for non-GEMM kernels), it chases
//! `Vec<KernelDesc>` and re-runs the roofline model for costs that never
//! change, and it *builds* a [`TraceEvent`] (label clone included) for every
//! completion even when tracing is off. The vectorized engine removes all
//! three:
//!
//! * **Struct-of-arrays state.** [`KernelSoA`] flattens every kernel's
//!   `flops`/`bytes`/`ctas`/`fused`, its interned
//!   [`ClassId`](crate::gpusim::classes::ClassId), and its precomputed
//!   exclusive-context duration into parallel arrays indexed by
//!   `offsets[tenant] + kidx`; [`CursorSoA`] does the same for per-tenant
//!   progress. The round loops touch only these dense arrays.
//! * **Interned classes.** [`ClassTable`](crate::gpusim::classes::ClassTable)
//!   assigns every distinct fusion-group class a dense rank in the legacy
//!   `BTreeMap` iteration order at setup, so per-round grouping is integer
//!   bucketing with zero string traffic.
//! * **Opt-in tracing.** Events are recorded through
//!   [`Trace::record_with`], which takes a closure — with tracing disabled
//!   the closure (and its label clone) never runs, so a no-trace simulation
//!   performs no per-event allocation at all. [`SimReport::scratch_grows`]
//!   counts post-warmup capacity growth of the reusable scratch buffers
//!   (the `RoundArena` grows-counter idiom from `coordinator::driver`) and
//!   must stay 0 in steady state.
//!
//! # The event wheel
//!
//! Each policy replaces the reference engine's ad-hoc scans with a
//! pre-sorted structure:
//!
//! * **Time-mux** keeps a *ready ring* (`VecDeque` of pending tenants in
//!   rotation order): the next quantum's tenant is popped from the front and
//!   re-enqueued at the back while it has work, replacing the legacy
//!   skip-scan over all tenants. Tenants only retire during their own
//!   quantum, so the ring provably visits tenants in the legacy order.
//! * **Space-time** plans each round through a *calendar of class buckets*:
//!   an array indexed by interned class rank, plus a `touched` list sorted
//!   ascending. Because ranks reproduce the legacy `BTreeMap` order, walking
//!   touched ranks replays the reference plan exactly — without building a
//!   map, keys, or member vectors per round.
//! * **Space-mux** deliberately keeps the dense min-scan over resident
//!   flights rather than a timer heap: processor sharing re-prices *every*
//!   resident flight at each completion (SM allocations change with the
//!   concurrency), so a heap's cached deadlines would be invalidated on
//!   every event; with at most `max_concurrent_kernels` residents the O(k)
//!   scan is both faster and allocation-free. The flight set itself is SoA
//!   with mirrored `swap_remove` order.

use std::collections::VecDeque;

use crate::coordinator::controller::{
    AdaptiveController, ControlSignals, ControllerParams, Decision, SignalTracker,
};
use crate::gpusim::classes::{ClassId, ClassKey, ClassTable, WorkloadClassRef};
use crate::gpusim::cost::{kernel_service_time, CostCtx};
use crate::gpusim::device::DeviceSpec;
use crate::gpusim::kernel::KernelDesc;
use crate::gpusim::mps::MpsAnomaly;
use crate::gpusim::trace::{Trace, TraceEvent};

/// One tenant's closed-loop workload: `iterations` repetitions of the kernel
/// sequence (one sequence = one inference / forward pass).
#[derive(Debug, Clone)]
pub struct TenantWorkload {
    pub kernels: Vec<KernelDesc>,
    pub iterations: u32,
}

/// Placement class of a workload: workloads sharing a class can fuse, so
/// the device pool keeps them on one shard when load allows (see
/// [`crate::gpusim::pool`]).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum WorkloadClass {
    /// Head kernel is a batchable GEMM of this (M, N, K).
    Gemm(u32, u32, u32),
    /// Head kernel is a non-GEMM kernel, keyed by name.
    Other(String),
    /// No kernels.
    Empty,
}

impl TenantWorkload {
    pub fn new(kernels: Vec<KernelDesc>, iterations: u32) -> Self {
        Self { kernels, iterations }
    }

    pub fn total_flops(&self) -> f64 {
        self.kernels.iter().map(|k| k.flops).sum::<f64>() * self.iterations as f64
    }

    /// Fusion/placement class (head-kernel shape — paper §2: same
    /// architecture tenants have aligned kernel streams), as an owned value.
    ///
    /// Clones the head kernel's name for non-GEMM workloads; anything on a
    /// hot or per-workload path should use [`TenantWorkload::class_ref`]
    /// instead, which borrows.
    pub fn class_key(&self) -> WorkloadClass {
        match self.kernels.first() {
            Some(k) => match k.shape {
                Some(s) => WorkloadClass::Gemm(s.m, s.n, s.k),
                None => WorkloadClass::Other(k.name.clone()),
            },
            None => WorkloadClass::Empty,
        }
    }

    /// Borrowed, allocation-free view of [`TenantWorkload::class_key`]:
    /// identical variant order (so `Ord` groups identically), no name clone.
    pub fn class_ref(&self) -> WorkloadClassRef<'_> {
        match self.kernels.first() {
            Some(k) => match k.shape {
                Some(s) => WorkloadClassRef::Gemm(s.m, s.n, s.k),
                None => WorkloadClassRef::Other(&k.name),
            },
            None => WorkloadClassRef::Empty,
        }
    }
}

/// Multiplexing policy under simulation.
#[derive(Debug, Clone, PartialEq)]
pub enum Policy {
    Exclusive,
    TimeMux,
    SpaceMuxMps { anomaly_seed: u64 },
    SpaceMuxStreams,
    SpaceTime { max_batch: u32 },
    /// Space-time with `lanes` concurrent spatial execution lanes: each
    /// round's super-kernels are balanced across lanes that execute
    /// concurrently, each on a static `sms / lanes` SM fraction with the
    /// deterministic interference derate of [`DeviceSpec::interference`] —
    /// planned spatial sharing replaces the MPS anomaly table on this path
    /// (the scheduler owns the interference model; DARIS, arXiv:2504.08795).
    /// `lanes = 1` degenerates to [`Policy::SpaceTime`].
    SpaceTimeLanes { max_batch: u32, lanes: u32 },
    /// Space-time with the **adaptive controller** choosing the resident
    /// lane count online — the same
    /// [`crate::coordinator::controller::AdaptiveController`] the serving
    /// driver runs, fed simulated signals (round width, exclusive-time
    /// launch durations, measured `dur_overlapped / dur_solo` stretch),
    /// so the control loop can be validated against the simulator's
    /// ground-truth cost model (`stgpu simulate/trace --adaptive`).
    /// `max_lanes = 1` degenerates to [`Policy::SpaceTime`].
    SpaceTimeAdaptive { max_batch: u32, max_lanes: u32 },
}

impl Policy {
    pub fn label(&self) -> &'static str {
        match self {
            Policy::Exclusive => "exclusive",
            Policy::TimeMux => "time-mux",
            Policy::SpaceMuxMps { .. } => "space-mux (MPS)",
            Policy::SpaceMuxStreams => "space-mux (streams)",
            Policy::SpaceTime { .. } => "space-time",
            Policy::SpaceTimeLanes { .. } => "space-time (lanes)",
            Policy::SpaceTimeAdaptive { .. } => "space-time (adaptive)",
        }
    }
}

/// Which engine implementation [`run`] executes. Both produce bitwise
/// identical [`SimReport`]s; the legacy engine exists as the equivalence
/// oracle and the fig13 speedup baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Engine {
    /// The struct-of-arrays engine (default): interned classes, pre-sized
    /// scratch, opt-in tracing.
    #[default]
    Vectorized,
    /// The original per-event reference implementation
    /// (`stgpu simulate --engine legacy`).
    Legacy,
}

impl Engine {
    /// Parse a CLI `--engine` value.
    pub fn parse(s: &str) -> Option<Engine> {
        match s {
            "vectorized" | "soa" | "fast" => Some(Engine::Vectorized),
            "legacy" | "reference" => Some(Engine::Legacy),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Engine::Vectorized => "vectorized",
            Engine::Legacy => "legacy",
        }
    }
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub spec: DeviceSpec,
    pub policy: Policy,
    pub capture_trace: bool,
    pub engine: Engine,
    /// Work-conserving lane execution for the space-time policies: a lane
    /// that drains its queue steals the most recently planned launch off
    /// the back of the lane with the most remaining work, mirroring the
    /// coordinator's stealable-deque protocol. Vectorized engine only —
    /// the legacy engine ignores it and stays the non-stealing oracle.
    /// `false` (the default) leaves every policy bit-for-bit identical to
    /// the pre-stealing engine.
    pub steal: bool,
}

impl SimConfig {
    pub fn new(spec: DeviceSpec, policy: Policy) -> Self {
        Self {
            spec,
            policy,
            capture_trace: false,
            engine: Engine::default(),
            steal: false,
        }
    }

    pub fn with_trace(mut self) -> Self {
        self.capture_trace = true;
        self
    }

    pub fn with_engine(mut self, engine: Engine) -> Self {
        self.engine = engine;
        self
    }

    pub fn with_steal(mut self, steal: bool) -> Self {
        self.steal = steal;
        self
    }
}

/// Per-tenant results.
#[derive(Debug, Clone, Default)]
pub struct TenantReport {
    /// Wall-clock latency of each completed inference, seconds.
    pub latencies: Vec<f64>,
    pub completed: u64,
    pub flops: f64,
}

impl TenantReport {
    pub fn mean_latency(&self) -> f64 {
        crate::util::stats::mean(&self.latencies)
    }
}

/// Whole-run results.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    pub tenants: Vec<TenantReport>,
    pub makespan: f64,
    pub kernel_launches: u64,
    pub superkernel_launches: u64,
    /// Total problems executed inside super-kernels.
    pub fused_problems: u64,
    /// Scheduling rounds executed: planning rounds for the space-time
    /// policies, context quanta for time-mux, 0 for the round-less
    /// policies. Completion events carry their round in
    /// [`TraceEvent::round`].
    pub rounds: u64,
    /// Post-warmup capacity growths of the vectorized engine's reusable
    /// scratch buffers (the `RoundArena` grows-counter idiom from
    /// `coordinator::driver`): 0 in steady state — asserted by the
    /// zero-alloc regression test and the fig13 bench. Always 0 on the
    /// legacy engine, which allocates fresh buffers per event instead.
    pub scratch_grows: u64,
    /// Launches executed on a lane other than the one the round planner
    /// assigned them to ([`SimConfig::steal`] mode). Always 0 with
    /// stealing off and on the legacy engine.
    pub steals: u64,
    pub trace: Trace,
}

impl SimReport {
    pub fn total_flops(&self) -> f64 {
        self.tenants.iter().map(|t| t.flops).sum()
    }

    pub fn total_completed(&self) -> u64 {
        self.tenants.iter().map(|t| t.completed).sum()
    }

    pub fn throughput_flops(&self) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            self.total_flops() / self.makespan
        }
    }

    pub fn mean_latency(&self) -> f64 {
        let all: Vec<f64> = self
            .tenants
            .iter()
            .flat_map(|t| t.latencies.iter().copied())
            .collect();
        crate::util::stats::mean(&all)
    }

    /// Fastest vs slowest tenant mean-latency gap (Figure 4 metric).
    pub fn straggler_gap(&self) -> f64 {
        let means: Vec<f64> = self
            .tenants
            .iter()
            .filter(|t| !t.latencies.is_empty())
            .map(|t| t.mean_latency())
            .collect();
        if means.len() < 2 {
            return 0.0;
        }
        let fast = means.iter().cloned().fold(f64::INFINITY, f64::min);
        let slow = means.iter().cloned().fold(0.0, f64::max);
        if fast <= 0.0 {
            0.0
        } else {
            slow / fast - 1.0
        }
    }
}

/// Run `workloads` under `cfg`.
pub fn run(cfg: &SimConfig, workloads: &[TenantWorkload]) -> SimReport {
    if cfg.engine == Engine::Legacy {
        return crate::gpusim::engine_legacy::run_legacy(cfg, workloads);
    }
    match &cfg.policy {
        Policy::Exclusive => run_exclusive(cfg, workloads),
        Policy::TimeMux => run_time_mux(cfg, workloads),
        Policy::SpaceMuxMps { anomaly_seed } => {
            let anomaly = MpsAnomaly::new(*anomaly_seed, workloads.len());
            run_space_mux(cfg, workloads, &anomaly, true, cfg.spec.mps_launch_overhead_s)
        }
        Policy::SpaceMuxStreams => {
            let anomaly = MpsAnomaly::none(workloads.len());
            run_space_mux(
                cfg,
                workloads,
                &anomaly,
                false,
                cfg.spec.dispatch_serialization_s,
            )
        }
        Policy::SpaceTime { max_batch } => {
            run_space_time(cfg, workloads, *max_batch, LaneMode::Static(1))
        }
        Policy::SpaceTimeLanes { max_batch, lanes } => {
            run_space_time(cfg, workloads, *max_batch, LaneMode::Static((*lanes).max(1)))
        }
        Policy::SpaceTimeAdaptive { max_batch, max_lanes } => run_space_time(
            cfg,
            workloads,
            *max_batch,
            LaneMode::Adaptive { max_lanes: (*max_lanes).max(1) },
        ),
    }
}

/// How the space-time round loop picks its lane count. Shared with the
/// legacy engine, which must replay the identical decision sequence.
#[derive(Clone, Copy)]
pub(crate) enum LaneMode {
    /// Fixed lane count for the whole run.
    Static(u32),
    /// The coordinator's
    /// [`crate::coordinator::controller::AdaptiveController`] re-decides
    /// the lane count every [`ADAPTIVE_DWELL_ROUNDS`] rounds from
    /// simulated signals.
    Adaptive { max_lanes: u32 },
}

/// Decision cadence of the simulated controller. Short on purpose:
/// simulated workloads run tens of rounds, and the point of the policy is
/// validating the control loop against ground truth, not modeling dwell
/// economics (the serving default is 32).
pub(crate) const ADAPTIVE_DWELL_ROUNDS: u32 = 2;

// ---------------------------------------------------------------------------
// Shared vectorized state: flattened kernels, cursors, cost probe, watchdog.
// ---------------------------------------------------------------------------

/// Every kernel of every workload flattened into parallel arrays; tenant
/// `t`'s kernels occupy `offsets[t]..offsets[t + 1]`. Built once per run
/// (cold); the hot loops never touch a [`KernelDesc`] except to read a name
/// when tracing is enabled.
struct KernelSoA {
    offsets: Vec<usize>,
    flops: Vec<f64>,
    bytes: Vec<f64>,
    ctas: Vec<u32>,
    fused: Vec<u32>,
    class: Vec<ClassId>,
    /// Precomputed `launch_overhead_s + kernel_service_time(k, exclusive)`:
    /// the per-kernel duration on the exclusive and time-mux paths, and the
    /// value is bit-identical to the legacy recomputation because
    /// [`kernel_service_time`] is pure.
    dur_excl: Vec<f64>,
}

impl KernelSoA {
    fn build(spec: &DeviceSpec, workloads: &[TenantWorkload]) -> (Self, ClassTable) {
        let (table, ids) = ClassTable::build(workloads);
        let total: usize = workloads.iter().map(|w| w.kernels.len()).sum();
        let mut soa = KernelSoA {
            offsets: Vec::with_capacity(workloads.len() + 1),
            flops: Vec::with_capacity(total),
            bytes: Vec::with_capacity(total),
            ctas: Vec::with_capacity(total),
            fused: Vec::with_capacity(total),
            class: Vec::with_capacity(total),
            dur_excl: Vec::with_capacity(total),
        };
        let excl = CostCtx::exclusive(spec);
        let mut off = 0usize;
        for (t, w) in workloads.iter().enumerate() {
            soa.offsets.push(off);
            for (j, k) in w.kernels.iter().enumerate() {
                soa.flops.push(k.flops);
                soa.bytes.push(k.bytes);
                soa.ctas.push(k.ctas);
                soa.fused.push(k.fused);
                soa.class.push(ids[t][j]);
                soa.dur_excl
                    .push(spec.launch_overhead_s + kernel_service_time(spec, k, &excl));
            }
            off += w.kernels.len();
        }
        soa.offsets.push(off);
        (soa, table)
    }
}

/// Per-tenant progress cursors in struct-of-arrays form.
struct CursorSoA {
    iter: Vec<u32>,
    kidx: Vec<usize>,
    /// Submission time of the in-flight inference (saturated closed loop:
    /// t=0, then each completion submits the next).
    inf_start: Vec<f64>,
    done: Vec<bool>,
}

impl CursorSoA {
    fn new(workloads: &[TenantWorkload]) -> Self {
        Self {
            iter: vec![0; workloads.len()],
            kidx: vec![0; workloads.len()],
            inf_start: vec![0.0; workloads.len()],
            done: workloads
                .iter()
                .map(|w| w.iterations == 0 || w.kernels.is_empty())
                .collect(),
        }
    }
}

/// Reusable cost-query kernel: [`kernel_service_time`] reads only the
/// `flops`/`bytes`/`ctas` fields, so one heap-free descriptor (empty name,
/// no shape) serves every query with bit-identical results to costing the
/// real (or merged) kernel.
struct CostProbe {
    k: KernelDesc,
}

impl CostProbe {
    fn new() -> Self {
        Self {
            k: KernelDesc {
                name: String::new(),
                tenant: 0,
                flops: 0.0,
                bytes: 0.0,
                ctas: 1,
                shape: None,
                fused: 1,
            },
        }
    }

    fn time(&mut self, spec: &DeviceSpec, flops: f64, bytes: f64, ctas: u32, ctx: &CostCtx) -> f64 {
        self.k.flops = flops;
        self.k.bytes = bytes;
        self.k.ctas = ctas;
        kernel_service_time(spec, &self.k, ctx)
    }
}

/// Capacity watchdog (the `RoundArena` grows-counter idiom): snapshot the
/// scratch capacities after the first event/round (warmup sizes the
/// buffers), then count every later capacity growth. A steady-state hot
/// loop must report zero grows.
fn watch_caps<const K: usize>(
    warmed: &mut bool,
    snap: &mut [usize; K],
    grows: &mut u64,
    now: [usize; K],
) {
    if !*warmed {
        *snap = now;
        *warmed = true;
        return;
    }
    for i in 0..K {
        if now[i] > snap[i] {
            *grows += 1;
            snap[i] = now[i];
        }
    }
}

/// Total kernel executions a workload set will perform: the exact event
/// count for the per-kernel policies and an upper bound for space-time
/// (which merges launches) — used to pre-size the trace buffer.
fn est_events(workloads: &[TenantWorkload]) -> usize {
    workloads
        .iter()
        .map(|w| w.iterations as usize * w.kernels.len())
        .sum()
}

/// Tenant reports with latency buffers pre-sized to the known completion
/// count, so steady-state completions never grow them.
fn sized_tenant_reports(workloads: &[TenantWorkload]) -> Vec<TenantReport> {
    workloads
        .iter()
        .map(|w| {
            let mut tr = TenantReport::default();
            if !w.kernels.is_empty() {
                tr.latencies.reserve(w.iterations as usize);
            }
            tr
        })
        .collect()
}

/// Record a per-kernel completion event. The [`TraceEvent`] — and its label
/// clone — is only built inside the closure, i.e. never when tracing is
/// disabled. Kept out of the `// lint: hot-path` functions so the hot loops
/// stay token-free.
#[allow(clippy::too_many_arguments)]
fn record_kernel(
    trace: &mut Trace,
    k: &KernelDesc,
    t_start: f64,
    t_end: f64,
    lane: usize,
    tenant: usize,
    sms: f64,
    round: u64,
) {
    trace.record_with(|| TraceEvent {
        t_start,
        t_end,
        lane,
        tenant,
        label: k.name.clone(),
        sms,
        fused: k.fused,
        round,
    });
}

/// Record a space-time round launch. Label construction replays the legacy
/// naming exactly: a multi-member GEMM chunk gets the super-kernel name,
/// anything else the first member's head-kernel name — built only when
/// tracing is enabled.
#[allow(clippy::too_many_arguments)]
fn record_merged(
    trace: &mut Trace,
    table: &ClassTable,
    workloads: &[TenantWorkload],
    cursors: &CursorSoA,
    members: &[usize],
    rank: usize,
    fused: u32,
    t_start: f64,
    t_end: f64,
    lane: usize,
    sms: f64,
    round: u64,
) {
    trace.record_with(|| {
        let first = members[0];
        let label = match table.key(ClassId(rank as u32)) {
            ClassKey::Gemm(m, n, k) if members.len() > 1 => {
                format!("sgemm_batched R={fused} {m}x{n}x{k}")
            }
            _ => workloads[first].kernels[cursors.kidx[first]].name.clone(),
        };
        TraceEvent {
            t_start,
            t_end,
            lane,
            tenant: if members.len() == 1 { members[0] } else { usize::MAX },
            label,
            sms,
            fused,
            round,
        }
    });
}

// ---------------------------------------------------------------------------
// Exclusive: each tenant on a private device.
// ---------------------------------------------------------------------------

fn run_exclusive(cfg: &SimConfig, workloads: &[TenantWorkload]) -> SimReport {
    let spec = &cfg.spec;
    let (soa, _table) = KernelSoA::build(spec, workloads);
    let mut report = SimReport {
        trace: Trace::new(cfg.capture_trace),
        ..Default::default()
    };
    report.trace.reserve(est_events(workloads));
    let mut makespan: f64 = 0.0;
    for (tid, w) in workloads.iter().enumerate() {
        let mut tr = TenantReport::default();
        if w.kernels.is_empty() {
            report.tenants.push(tr);
            continue;
        }
        tr.latencies.reserve(w.iterations as usize);
        let t_end = exclusive_tenant(spec, w, &soa, tid, &mut tr, &mut report);
        makespan = makespan.max(t_end);
        // Exclusive "rounds" are inference iterations (events are tagged
        // with theirs); the run spans the longest tenant's count.
        report.rounds = report.rounds.max(w.iterations as u64);
        report.tenants.push(tr);
    }
    report.makespan = makespan;
    report
}

// lint: hot-path
fn exclusive_tenant(
    spec: &DeviceSpec,
    w: &TenantWorkload,
    soa: &KernelSoA,
    tid: usize,
    tr: &mut TenantReport,
    report: &mut SimReport,
) -> f64 {
    let base = soa.offsets[tid];
    let mut t = 0.0f64;
    for iter in 0..w.iterations {
        let start = t;
        for (j, k) in w.kernels.iter().enumerate() {
            let dur = soa.dur_excl[base + j];
            record_kernel(
                &mut report.trace,
                k,
                t,
                t + dur,
                tid,
                tid,
                (k.ctas as f64).min(spec.sms as f64),
                iter as u64,
            );
            t += dur;
            report.kernel_launches += 1;
            tr.flops += k.flops;
        }
        tr.latencies.push(t - start);
        tr.completed += 1;
    }
    t
}

// ---------------------------------------------------------------------------
// Time multiplexing: one resident context, round-robin quanta over the
// ready ring.
// ---------------------------------------------------------------------------

fn run_time_mux(cfg: &SimConfig, workloads: &[TenantWorkload]) -> SimReport {
    let spec = &cfg.spec;
    let n = workloads.len();
    let (soa, _table) = KernelSoA::build(spec, workloads);
    let mut cursors = CursorSoA::new(workloads);
    let mut report = SimReport {
        tenants: sized_tenant_reports(workloads),
        trace: Trace::new(cfg.capture_trace),
        ..Default::default()
    };
    report.trace.reserve(est_events(workloads));
    // The ready ring: pending tenants in rotation order. Tenants retire
    // only at the end of their own quantum, so pop-front / push-back visits
    // exactly the legacy cyclic scan order.
    let mut ring: VecDeque<usize> = VecDeque::with_capacity(n);
    ring.extend((0..n).filter(|&t| !cursors.done[t]));
    // Context-switch cost applies when more than one context exists
    // (decided once up front, as in the reference engine).
    let multi = ring.len() > 1;
    time_mux_rounds(spec, workloads, &soa, &mut cursors, &mut ring, multi, &mut report);
    report
}

// lint: hot-path
fn time_mux_rounds(
    spec: &DeviceSpec,
    workloads: &[TenantWorkload],
    soa: &KernelSoA,
    cursors: &mut CursorSoA,
    ring: &mut VecDeque<usize>,
    multi: bool,
    report: &mut SimReport,
) {
    let mut clock = 0.0f64;
    let mut quantum: u64 = 0;
    while let Some(t) = ring.pop_front() {
        if multi {
            clock += spec.ctx_switch_s;
        }
        // Run this tenant's kernels until the quantum is spent (kernels are
        // non-preemptible: always finish the one we started).
        let mut quantum_left = spec.timeslice_quantum_s;
        let w = &workloads[t];
        let base = soa.offsets[t];
        while quantum_left > 0.0 && !cursors.done[t] {
            let j = cursors.kidx[t];
            let k = &w.kernels[j];
            let dur = soa.dur_excl[base + j];
            record_kernel(
                &mut report.trace,
                k,
                clock,
                clock + dur,
                t,
                t,
                (k.ctas as f64).min(spec.sms as f64),
                quantum,
            );
            clock += dur;
            quantum_left -= dur;
            report.kernel_launches += 1;
            report.tenants[t].flops += k.flops;
            cursors.kidx[t] += 1;
            if cursors.kidx[t] == w.kernels.len() {
                cursors.kidx[t] = 0;
                cursors.iter[t] += 1;
                report.tenants[t].latencies.push(clock - cursors.inf_start[t]);
                report.tenants[t].completed += 1;
                cursors.inf_start[t] = clock; // next inference submitted immediately
                if cursors.iter[t] == w.iterations {
                    cursors.done[t] = true;
                }
            }
        }
        quantum += 1;
        if !cursors.done[t] {
            ring.push_back(t);
        }
    }
    report.rounds = quantum;
    report.makespan = clock;
}

// ---------------------------------------------------------------------------
// Spatial multiplexing: event-driven processor sharing over SMs.
// ---------------------------------------------------------------------------

/// In-flight kernels in struct-of-arrays form. Mirrors the legacy `Flight`
/// vector — including `swap_remove` order — so completion processing is
/// bit-identical.
struct FlightSoA {
    tenant: Vec<usize>,
    /// Remaining dispatch-phase time (absolute seconds).
    dispatch: Vec<f64>,
    /// Remaining execution fraction (service time is re-evaluated whenever
    /// the resident set changes).
    frac: Vec<f64>,
    started: Vec<f64>,
}

/// Reusable per-event scratch for the space-mux loop.
struct MuxScratch {
    allocs: Vec<f64>,
    times: Vec<f64>,
    completed: Vec<usize>,
}

/// Admit waiting tenants into the resident flight set (SoA mirror of the
/// legacy `admit`).
fn admit_flights(
    flights: &mut FlightSoA,
    waiting: &mut VecDeque<usize>,
    done: &[bool],
    clock: f64,
    max_resident: usize,
    overhead: f64,
) {
    while flights.tenant.len() < max_resident {
        let Some(t) = waiting.pop_front() else { break };
        debug_assert!(!done[t]);
        flights.tenant.push(t);
        flights.dispatch.push(overhead);
        flights.frac.push(1.0);
        flights.started.push(clock);
    }
}

fn run_space_mux(
    cfg: &SimConfig,
    workloads: &[TenantWorkload],
    anomaly: &MpsAnomaly,
    static_bw: bool,
    per_kernel_overhead: f64,
) -> SimReport {
    let spec = &cfg.spec;
    let n = workloads.len();
    let (soa, _table) = KernelSoA::build(spec, workloads);
    let mut cursors = CursorSoA::new(workloads);
    let mut report = SimReport {
        tenants: sized_tenant_reports(workloads),
        trace: Trace::new(cfg.capture_trace),
        ..Default::default()
    };
    report.trace.reserve(est_events(workloads));
    let max_resident = spec.max_concurrent_kernels as usize;
    let mut flights = FlightSoA {
        tenant: Vec::with_capacity(max_resident),
        dispatch: Vec::with_capacity(max_resident),
        frac: Vec::with_capacity(max_resident),
        started: Vec::with_capacity(max_resident),
    };
    // Tenants whose next kernel is ready but waiting for a hardware queue.
    let mut waiting: VecDeque<usize> = VecDeque::with_capacity(n);
    waiting.extend((0..n).filter(|&t| !cursors.done[t]));
    let mut scratch = MuxScratch {
        allocs: Vec::with_capacity(max_resident),
        times: Vec::with_capacity(max_resident),
        completed: Vec::with_capacity(max_resident),
    };
    let mut probe = CostProbe::new();
    space_mux_events(
        spec,
        workloads,
        &soa,
        &mut cursors,
        anomaly,
        static_bw,
        per_kernel_overhead,
        &mut flights,
        &mut waiting,
        &mut scratch,
        &mut probe,
        &mut report,
    );
    report
}

#[allow(clippy::too_many_arguments)]
// lint: hot-path
fn space_mux_events(
    spec: &DeviceSpec,
    workloads: &[TenantWorkload],
    soa: &KernelSoA,
    cursors: &mut CursorSoA,
    anomaly: &MpsAnomaly,
    static_bw: bool,
    overhead: f64,
    flights: &mut FlightSoA,
    waiting: &mut VecDeque<usize>,
    scratch: &mut MuxScratch,
    probe: &mut CostProbe,
    report: &mut SimReport,
) {
    let max_resident = spec.max_concurrent_kernels as usize;
    let total_sms = spec.sms as f64;
    let mut clock = 0.0f64;
    let (mut warmed, mut snap, mut grows) = (false, [0usize; 5], 0u64);
    admit_flights(flights, waiting, &cursors.done, clock, max_resident, overhead);
    while !flights.tenant.is_empty() {
        let conc = flights.tenant.len() as u32;
        // SM allocation proportional to CTA demand, capped by each kernel's
        // own CTA count; one redistribution round picks up the slack.
        let mut total_ctas = 0.0f64;
        for &t in &flights.tenant {
            total_ctas += soa.ctas[soa.offsets[t] + cursors.kidx[t]] as f64;
        }
        scratch.allocs.clear();
        for &t in &flights.tenant {
            let ctas = soa.ctas[soa.offsets[t] + cursors.kidx[t]] as f64;
            scratch.allocs.push((total_sms * ctas / total_ctas.max(1.0)).min(ctas));
        }
        let mut used = 0.0f64;
        for &a in &scratch.allocs {
            used += a;
        }
        let slack = (total_sms - used).max(0.0);
        if slack > 0.0 {
            // Give slack to kernels that can still use it (ctas > alloc).
            let mut extra_demand = 0.0f64;
            for (i, &t) in flights.tenant.iter().enumerate() {
                let ctas = soa.ctas[soa.offsets[t] + cursors.kidx[t]] as f64;
                extra_demand += (ctas - scratch.allocs[i]).max(0.0);
            }
            if extra_demand > 0.0 {
                for (i, &t) in flights.tenant.iter().enumerate() {
                    let ctas = soa.ctas[soa.offsets[t] + cursors.kidx[t]] as f64;
                    let want = (ctas - scratch.allocs[i]).max(0.0);
                    scratch.allocs[i] += slack * want / extra_demand;
                    scratch.allocs[i] = scratch.allocs[i].min(ctas);
                }
            }
        }

        // Time to next completion: dense scan (see module docs for why a
        // timer heap would lose here).
        let mut dt = f64::INFINITY;
        scratch.times.clear();
        for (i, &t) in flights.tenant.iter().enumerate() {
            let ki = soa.offsets[t] + cursors.kidx[t];
            let t_exec = probe.time(
                spec,
                soa.flops[ki],
                soa.bytes[ki],
                soa.ctas[ki],
                &CostCtx {
                    sms: scratch.allocs[i].max(1e-9),
                    concurrency: conc,
                    static_bw_partition: static_bw,
                },
            ) * anomaly.multiplier(t);
            scratch.times.push(t_exec);
            let remaining = flights.dispatch[i] + flights.frac[i] * t_exec;
            dt = dt.min(remaining);
        }
        debug_assert!(dt.is_finite() && dt >= 0.0);

        clock += dt;
        // Advance all flights by dt; collect completions.
        scratch.completed.clear();
        for i in 0..flights.tenant.len() {
            let mut step = dt;
            if flights.dispatch[i] > 0.0 {
                let d = flights.dispatch[i].min(step);
                flights.dispatch[i] -= d;
                step -= d;
            }
            if step > 0.0 && flights.frac[i] > 0.0 {
                flights.frac[i] -= step / scratch.times[i];
            }
            if flights.dispatch[i] <= 1e-15 && flights.frac[i] <= 1e-9 {
                scratch.completed.push(i);
            }
        }

        // Process completions (highest index first so removals are stable).
        for &i in scratch.completed.iter().rev() {
            let t = flights.tenant.swap_remove(i);
            flights.dispatch.swap_remove(i);
            flights.frac.swap_remove(i);
            let started = flights.started.swap_remove(i);
            let ki = soa.offsets[t] + cursors.kidx[t];
            report.kernel_launches += 1;
            report.tenants[t].flops += soa.flops[ki];
            record_kernel(
                &mut report.trace,
                &workloads[t].kernels[cursors.kidx[t]],
                started,
                clock,
                t % max_resident.max(1),
                t,
                (soa.ctas[ki] as f64).min(spec.sms as f64 / (conc as f64)),
                // Event-driven path: no round structure to tag.
                0,
            );
            cursors.kidx[t] += 1;
            if cursors.kidx[t] == workloads[t].kernels.len() {
                cursors.kidx[t] = 0;
                cursors.iter[t] += 1;
                report.tenants[t].latencies.push(clock - cursors.inf_start[t]);
                report.tenants[t].completed += 1;
                cursors.inf_start[t] = clock;
                if cursors.iter[t] == workloads[t].iterations {
                    cursors.done[t] = true;
                }
            }
            if !cursors.done[t] {
                waiting.push_back(t);
            }
        }
        admit_flights(flights, waiting, &cursors.done, clock, max_resident, overhead);
        watch_caps(
            &mut warmed,
            &mut snap,
            &mut grows,
            [
                flights.tenant.capacity(),
                scratch.allocs.capacity(),
                scratch.times.capacity(),
                scratch.completed.capacity(),
                waiting.capacity(),
            ],
        );
    }
    report.scratch_grows = grows;
    report.makespan = clock;
}

// ---------------------------------------------------------------------------
// Space-time: per-round inter-model super-kernel batching (the contribution),
// optionally spread over concurrent spatial lanes — statically or under the
// adaptive controller.
// ---------------------------------------------------------------------------

/// Reusable per-round scratch for the space-time loop: the class-bucket
/// calendar plus the planned launches in struct-of-arrays form
/// (`l_*[i]` describe launch `i`; its members are
/// `members[l_mstart[i] .. l_mstart[i] + l_mlen[i]]`).
struct RoundScratch {
    /// Per class rank: live tenants whose head kernel is in that class.
    buckets: Vec<Vec<usize>>,
    /// Ranks with members this round, sorted ascending before planning.
    touched: Vec<usize>,
    /// Flat member arena for all launches of the round.
    members: Vec<usize>,
    l_rank: Vec<usize>,
    l_mstart: Vec<usize>,
    l_mlen: Vec<usize>,
    l_flops: Vec<f64>,
    l_bytes: Vec<f64>,
    l_ctas: Vec<u32>,
    l_fused: Vec<u32>,
    /// Exclusive-context duration of the merged launch: the lane-balancing
    /// weight, and (adaptive mode) the controller's solo-duration signal.
    l_solo: Vec<f64>,
    l_lane: Vec<usize>,
    lane_load: Vec<f64>,
    lane_cursor: Vec<f64>,
    /// Steal mode only — overlapped-context duration per launch, the
    /// weight the work-conserving replay balances on (untouched with
    /// stealing off).
    l_dur: Vec<f64>,
    /// Steal mode only — the work-conserving execution order (indices
    /// into the round's launch arrays).
    exec_seq: Vec<usize>,
    /// Steal mode only — per-lane FIFO of planned launches. The owner
    /// pops the front (`q_head` advance); a thief pops the back.
    steal_q: Vec<Vec<usize>>,
    q_head: Vec<usize>,
    /// Steal mode only — remaining queued overlapped work per lane, the
    /// victim-selection key (mirrors the coordinator deque's `rem`).
    lane_rem: Vec<f64>,
    lane_sim: Vec<f64>,
    lane_done: Vec<bool>,
}

impl RoundScratch {
    fn new(n_tenants: usize, n_classes: usize, max_lanes: usize) -> Self {
        Self {
            buckets: (0..n_classes).map(|_| Vec::with_capacity(n_tenants)).collect(),
            touched: Vec::with_capacity(n_classes),
            members: Vec::with_capacity(n_tenants),
            l_rank: Vec::with_capacity(n_tenants),
            l_mstart: Vec::with_capacity(n_tenants),
            l_mlen: Vec::with_capacity(n_tenants),
            l_flops: Vec::with_capacity(n_tenants),
            l_bytes: Vec::with_capacity(n_tenants),
            l_ctas: Vec::with_capacity(n_tenants),
            l_fused: Vec::with_capacity(n_tenants),
            l_solo: Vec::with_capacity(n_tenants),
            l_lane: Vec::with_capacity(n_tenants),
            lane_load: Vec::with_capacity(max_lanes),
            lane_cursor: Vec::with_capacity(max_lanes),
            l_dur: Vec::with_capacity(n_tenants),
            exec_seq: Vec::with_capacity(n_tenants),
            steal_q: (0..max_lanes).map(|_| Vec::with_capacity(n_tenants)).collect(),
            q_head: Vec::with_capacity(max_lanes),
            lane_rem: Vec::with_capacity(max_lanes),
            lane_sim: Vec::with_capacity(max_lanes),
            lane_done: Vec::with_capacity(max_lanes),
        }
    }
}

fn run_space_time(
    cfg: &SimConfig,
    workloads: &[TenantWorkload],
    max_batch: u32,
    mode: LaneMode,
) -> SimReport {
    assert!(max_batch >= 1);
    let spec = &cfg.spec;
    let (static_lanes, mut controller) = match mode {
        LaneMode::Static(l) => (l.max(1), None),
        LaneMode::Adaptive { max_lanes } => (
            1,
            Some(AdaptiveController::new(
                ControllerParams {
                    max_lanes: max_lanes as usize,
                    max_depth: 1, // the simulator has no pipeline to deepen
                    dwell_rounds: ADAPTIVE_DWELL_ROUNDS,
                    improvement: 0.05,
                    slo_target: 0.99,
                },
                Decision { lanes: 1, depth: 1 },
            )),
        ),
    };
    let max_lanes_possible = match mode {
        LaneMode::Static(l) => l.max(1) as usize,
        LaneMode::Adaptive { max_lanes } => max_lanes.max(1) as usize,
    };
    let mut tracker = SignalTracker::default();
    let n = workloads.len();
    let (soa, table) = KernelSoA::build(spec, workloads);
    let mut cursors = CursorSoA::new(workloads);
    let mut report = SimReport {
        tenants: sized_tenant_reports(workloads),
        trace: Trace::new(cfg.capture_trace),
        ..Default::default()
    };
    report.trace.reserve(est_events(workloads));
    let mut scratch = RoundScratch::new(n, table.len(), max_lanes_possible);
    let mut probe = CostProbe::new();
    space_time_rounds(
        spec,
        workloads,
        &soa,
        &table,
        &mut cursors,
        max_batch,
        static_lanes,
        cfg.steal,
        &mut controller,
        &mut tracker,
        &mut scratch,
        &mut probe,
        &mut report,
    );
    report
}

/// Work-conserving replay of a round's plan ([`SimConfig::steal`] mode):
/// lanes drain their queues front-to-back in virtual time; a lane that
/// runs dry steals the back of the queue holding the most remaining
/// overlapped work — the coordinator deque's victim rule, ties to the
/// lowest lane. Overwrites `l_lane` with the lane each launch actually
/// executes on, records the execution order in `exec_seq`, and returns
/// the steal count. Deterministic throughout (first-minimum lane pick,
/// first-maximum victim pick), so stealing runs replay bitwise.
// lint: hot-path
fn steal_rebalance(scratch: &mut RoundScratch, active: usize, n_launches: usize) -> u64 {
    for l in 0..active {
        scratch.steal_q[l].clear();
    }
    scratch.q_head.clear();
    scratch.q_head.resize(active, 0);
    scratch.lane_rem.clear();
    scratch.lane_rem.resize(active, 0.0);
    scratch.lane_sim.clear();
    scratch.lane_sim.resize(active, 0.0);
    scratch.lane_done.clear();
    scratch.lane_done.resize(active, false);
    for i in 0..n_launches {
        let l = scratch.l_lane[i];
        scratch.steal_q[l].push(i);
        scratch.lane_rem[l] += scratch.l_dur[i];
    }
    scratch.exec_seq.clear();
    let mut steals = 0u64;
    let mut remaining = n_launches;
    while remaining > 0 {
        // The next lane to act is the idle-soonest one still in play.
        // `remaining > 0` guarantees some lane has queued work, and a
        // lane with queued work is never marked done, so `l` resolves.
        let mut l = usize::MAX;
        for c in 0..active {
            if !scratch.lane_done[c]
                && (l == usize::MAX || scratch.lane_sim[c] < scratch.lane_sim[l])
            {
                l = c;
            }
        }
        let i = if scratch.q_head[l] < scratch.steal_q[l].len() {
            let i = scratch.steal_q[l][scratch.q_head[l]];
            scratch.q_head[l] += 1;
            i
        } else {
            let mut victim = usize::MAX;
            for v in 0..active {
                if scratch.steal_q[v].len() > scratch.q_head[v]
                    && (victim == usize::MAX || scratch.lane_rem[v] > scratch.lane_rem[victim])
                {
                    victim = v;
                }
            }
            if victim == usize::MAX {
                // Nothing queued anywhere: this lane is done for the round.
                scratch.lane_done[l] = true;
                continue;
            }
            steals += 1;
            scratch.steal_q[victim].pop().expect("victim has pending work")
        };
        let owner = scratch.l_lane[i];
        scratch.lane_rem[owner] -= scratch.l_dur[i];
        scratch.l_lane[i] = l;
        scratch.exec_seq.push(i);
        scratch.lane_sim[l] += scratch.l_dur[i];
        remaining -= 1;
    }
    steals
}

#[allow(clippy::too_many_arguments)]
// lint: hot-path
fn space_time_rounds(
    spec: &DeviceSpec,
    workloads: &[TenantWorkload],
    soa: &KernelSoA,
    table: &ClassTable,
    cursors: &mut CursorSoA,
    max_batch: u32,
    static_lanes: u32,
    steal: bool,
    controller: &mut Option<AdaptiveController>,
    tracker: &mut SignalTracker,
    scratch: &mut RoundScratch,
    probe: &mut CostProbe,
    report: &mut SimReport,
) {
    let n = workloads.len();
    let excl = CostCtx::exclusive(spec);
    let mut clock = 0.0f64;
    let mut round: u64 = 0;
    let (mut warmed, mut snap, mut grows) = (false, [0usize; 5], 0u64);

    loop {
        // Bucket the heads of all live tenants into the class calendar.
        // Iterating tenants ascending keeps each bucket in ascending tenant
        // order; sorting the touched ranks replays the legacy BTreeMap's
        // key order (ClassTable ranks ARE that order).
        for &r in &scratch.touched {
            scratch.buckets[r].clear();
        }
        scratch.touched.clear();
        let mut live = 0usize;
        for t in 0..n {
            if cursors.done[t] {
                continue;
            }
            live += 1;
            let rank = soa.class[soa.offsets[t] + cursors.kidx[t]].rank();
            if scratch.buckets[rank].is_empty() {
                scratch.touched.push(rank);
            }
            scratch.buckets[rank].push(t);
        }
        if live == 0 {
            break;
        }
        scratch.touched.sort_unstable();

        // Plan the round's launches: each class in chunks of max_batch.
        // Merged work sums are seeded from the first member and accumulated
        // in member order — bitwise identical to both legacy merge paths
        // (KernelDesc::superkernel's `sum()` folds from 0.0, and
        // `0.0 + x == x` for these positive magnitudes).
        scratch.members.clear();
        scratch.l_rank.clear();
        scratch.l_mstart.clear();
        scratch.l_mlen.clear();
        scratch.l_flops.clear();
        scratch.l_bytes.clear();
        scratch.l_ctas.clear();
        scratch.l_fused.clear();
        scratch.l_solo.clear();
        for &rank in &scratch.touched {
            let bucket_len = scratch.buckets[rank].len();
            let mut c0 = 0usize;
            while c0 < bucket_len {
                let clen = (bucket_len - c0).min(max_batch as usize);
                let first = scratch.buckets[rank][c0];
                let ki0 = soa.offsets[first] + cursors.kidx[first];
                let mut flops = soa.flops[ki0];
                let mut bytes = soa.bytes[ki0];
                let mut ctas = soa.ctas[ki0];
                let mut fused = soa.fused[ki0];
                let mstart = scratch.members.len();
                scratch.members.push(first);
                for j in 1..clen {
                    let t = scratch.buckets[rank][c0 + j];
                    let ki = soa.offsets[t] + cursors.kidx[t];
                    flops += soa.flops[ki];
                    bytes += soa.bytes[ki];
                    ctas += soa.ctas[ki];
                    fused += soa.fused[ki];
                    scratch.members.push(t);
                }
                let solo = spec.launch_overhead_s + probe.time(spec, flops, bytes, ctas, &excl);
                scratch.l_rank.push(rank);
                scratch.l_mstart.push(mstart);
                scratch.l_mlen.push(clen);
                scratch.l_flops.push(flops);
                scratch.l_bytes.push(bytes);
                scratch.l_ctas.push(ctas);
                scratch.l_fused.push(fused);
                scratch.l_solo.push(solo);
                c0 += clen;
            }
        }
        let n_launches = scratch.l_rank.len();

        // Adaptive mode: at each dwell boundary hand the controller the
        // tracker's signals — round width, exclusive-time launch duration
        // EWMA, and the measured overlapped/solo stretch (seeded from the
        // device spec before any overlapped round ran) — and take its
        // decision for this round. Static mode uses the configured count.
        let lanes_now = match controller.as_mut() {
            Some(ctl) => {
                if ctl.tick() {
                    let max_lanes = ctl.params().max_lanes;
                    let stretch =
                        tracker.stretch_table(max_lanes, |n| spec.lane_stretch(n as u32));
                    let signals = ControlSignals {
                        backlog: 0, // closed loop: the heads ARE the demand
                        arrival_rate: 0.0,
                        launches_per_round: tracker.launches_per_round(),
                        requests_per_round: tracker.requests_per_round(),
                        mean_launch_s: tracker.mean_launch_s(),
                        plan_s: 0.0,
                        stretch,
                        slo_attainment: None,
                        min_slo_s: 0.0,
                        steal_rate: 0.0,
                    };
                    ctl.decide(&signals);
                }
                ctl.decision().lanes as u32
            }
            None => static_lanes,
        };
        // Assign launches to spatial lanes: greedy makespan balancing by
        // exclusive-time weight, in plan order (mirrors the coordinator's
        // lane assignment). The strict `<` scan picks the first minimum,
        // like the legacy `Iterator::min_by`. With one lane (or one launch)
        // this degenerates to the classic serial round.
        let active = (lanes_now as usize).min(n_launches).max(1);
        scratch.lane_load.clear();
        scratch.lane_load.resize(active, 0.0);
        scratch.l_lane.clear();
        for i in 0..n_launches {
            let mut best = 0usize;
            let mut best_load = scratch.lane_load[0];
            for (l, &load) in scratch.lane_load.iter().enumerate().skip(1) {
                if load < best_load {
                    best = l;
                    best_load = load;
                }
            }
            scratch.l_lane.push(best);
            scratch.lane_load[best] += scratch.l_solo[i];
        }
        // Concurrently-resident lanes each execute on a static SM fraction
        // with the deterministic interference derate — planned spatial
        // sharing, not the MPS anomaly lottery (the explicit interference
        // model replaces the anomaly table on this path).
        let ctx = CostCtx {
            sms: spec.sms as f64 / active as f64,
            concurrency: active as u32,
            static_bw_partition: false,
        };
        // Steal mode: replay the plan work-conservingly on the overlapped
        // durations (what the lanes actually experience — the planner
        // balanced on exclusive-time weights, so memory- vs compute-bound
        // class mixes skew under partitioning) and execute in the replay's
        // order on the replay's lanes. With stealing off this block is
        // never entered and the round is bit-for-bit the pre-stealing plan.
        let stealing = steal && active > 1 && n_launches > 1;
        if stealing {
            scratch.l_dur.clear();
            for i in 0..n_launches {
                scratch.l_dur.push(
                    spec.launch_overhead_s
                        + probe.time(
                            spec,
                            scratch.l_flops[i],
                            scratch.l_bytes[i],
                            scratch.l_ctas[i],
                            &ctx,
                        ),
                );
            }
            report.steals += steal_rebalance(scratch, active, n_launches);
        }
        scratch.lane_cursor.clear();
        scratch.lane_cursor.resize(active, 0.0);
        let mut problems_this_round = 0usize;
        for step in 0..n_launches {
            let i = if stealing { scratch.exec_seq[step] } else { step };
            let lane = scratch.l_lane[i];
            let dur = spec.launch_overhead_s
                + probe.time(spec, scratch.l_flops[i], scratch.l_bytes[i], scratch.l_ctas[i], &ctx);
            if controller.is_some() {
                // Simulated measurement feedback: solo-equivalent launch
                // duration, and (overlapped rounds only) the ground-truth
                // stretch the controller's utility model calibrates from.
                let solo = scratch.l_solo[i];
                tracker.observe_launch(solo);
                if active > 1 {
                    tracker.observe_stretch(active, dur / solo.max(1e-12));
                }
                problems_this_round += scratch.l_mlen[i];
            }
            let t_start = clock + scratch.lane_cursor[lane];
            let t_end = t_start + dur;
            scratch.lane_cursor[lane] += dur;
            let mem = &scratch.members[scratch.l_mstart[i]..scratch.l_mstart[i] + scratch.l_mlen[i]];
            // Round-tagged completion: every member of this round's plan
            // carries the planning round it belongs to, matching the
            // coordinator driver's pipelined attribution.
            record_merged(
                &mut report.trace,
                table,
                workloads,
                cursors,
                mem,
                scratch.l_rank[i],
                scratch.l_fused[i],
                t_start,
                t_end,
                lane,
                (scratch.l_ctas[i] as f64).min(ctx.sms),
                round,
            );
            report.kernel_launches += 1;
            if scratch.l_fused[i] > 1 {
                report.superkernel_launches += 1;
                report.fused_problems += scratch.l_fused[i] as u64;
            }
            for &t in mem {
                report.tenants[t].flops += soa.flops[soa.offsets[t] + cursors.kidx[t]];
            }
            // Members complete at their launch's end on its lane.
            for &t in mem {
                cursors.kidx[t] += 1;
                if cursors.kidx[t] == workloads[t].kernels.len() {
                    cursors.kidx[t] = 0;
                    cursors.iter[t] += 1;
                    report.tenants[t].latencies.push(t_end - cursors.inf_start[t]);
                    report.tenants[t].completed += 1;
                    cursors.inf_start[t] = t_end;
                    if cursors.iter[t] == workloads[t].iterations {
                        cursors.done[t] = true;
                    }
                }
            }
        }
        if controller.is_some() {
            tracker.observe_round(n_launches, problems_this_round, 0.0);
        }
        // The round barrier: the next round plans once every lane drains.
        clock += scratch.lane_cursor.iter().copied().fold(0.0, f64::max);
        round += 1;
        let mut bucket_cap = 0usize;
        for b in &scratch.buckets {
            bucket_cap += b.capacity();
        }
        // The steal scratch rides in the bucket-cap slot: pre-sized like
        // everything else, so its steady-state growth must also be zero
        // (and with stealing off the capacities are constants).
        for q in &scratch.steal_q {
            bucket_cap += q.capacity();
        }
        bucket_cap += scratch.l_dur.capacity() + scratch.exec_seq.capacity();
        watch_caps(
            &mut warmed,
            &mut snap,
            &mut grows,
            [
                scratch.members.capacity(),
                scratch.l_rank.capacity(),
                scratch.touched.capacity(),
                scratch.lane_load.capacity(),
                bucket_cap,
            ],
        );
    }
    report.rounds = round;
    report.makespan = clock;
    report.scratch_grows = grows;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::kernel::GemmShape;

    fn sgemm_workloads(n: usize, iters: u32, shape: GemmShape) -> Vec<TenantWorkload> {
        (0..n)
            .map(|t| TenantWorkload::new(vec![KernelDesc::sgemm(t, shape)], iters))
            .collect()
    }

    fn cfg(policy: Policy) -> SimConfig {
        SimConfig::new(DeviceSpec::v100(), policy)
    }

    #[test]
    fn all_policies_complete_all_work() {
        let w = sgemm_workloads(6, 5, GemmShape::RESNET18_CONV2_2);
        for policy in [
            Policy::Exclusive,
            Policy::TimeMux,
            Policy::SpaceMuxMps { anomaly_seed: 1 },
            Policy::SpaceMuxStreams,
            Policy::SpaceTime { max_batch: 64 },
            Policy::SpaceTimeLanes { max_batch: 64, lanes: 2 },
        ] {
            let r = run(&cfg(policy.clone()), &w);
            assert_eq!(
                r.total_completed(),
                30,
                "policy {policy:?} must complete all inferences"
            );
            for t in &r.tenants {
                assert_eq!(t.completed, 5);
                assert_eq!(t.latencies.len(), 5);
                assert!(t.latencies.iter().all(|&l| l > 0.0));
            }
            assert!(r.makespan > 0.0);
        }
    }

    #[test]
    fn exclusive_latency_flat_in_tenant_count() {
        // Private GPUs: more tenants must not slow each other down.
        let l1 = run(&cfg(Policy::Exclusive), &sgemm_workloads(1, 10, GemmShape::SQUARE_256))
            .mean_latency();
        let l8 = run(&cfg(Policy::Exclusive), &sgemm_workloads(8, 10, GemmShape::SQUARE_256))
            .mean_latency();
        assert!((l1 - l8).abs() / l1 < 1e-9);
    }

    #[test]
    fn time_mux_latency_grows_linearly() {
        // Paper Fig 3: "linear-slowdown as the number of replicas grows".
        let shape = GemmShape::RESNET18_CONV2_2;
        let l2 = run(&cfg(Policy::TimeMux), &sgemm_workloads(2, 20, shape)).mean_latency();
        let l8 = run(&cfg(Policy::TimeMux), &sgemm_workloads(8, 20, shape)).mean_latency();
        let ratio = l8 / l2;
        assert!(
            (2.5..6.5).contains(&ratio),
            "8 vs 2 tenants should be ~4x slower, got {ratio}"
        );
    }

    #[test]
    fn space_mux_beats_time_mux_for_conv() {
        // Paper Fig 3: spatial multiplexing delivers better latency than
        // time multiplexing.
        let shape = GemmShape::RESNET18_CONV2_2;
        let w = sgemm_workloads(8, 20, shape);
        let t = run(&cfg(Policy::TimeMux), &w);
        let s = run(&cfg(Policy::SpaceMuxMps { anomaly_seed: 3 }), &w);
        assert!(
            s.mean_latency() < t.mean_latency(),
            "space {} should beat time {}",
            s.mean_latency(),
            t.mean_latency()
        );
        assert!(s.throughput_flops() > t.throughput_flops());
    }

    #[test]
    fn space_time_beats_both_for_conv() {
        // Paper Fig 7 / Table 1 direction.
        let shape = GemmShape::RESNET18_CONV2_2;
        let w = sgemm_workloads(20, 10, shape);
        let time = run(&cfg(Policy::TimeMux), &w).throughput_flops();
        let space = run(&cfg(Policy::SpaceMuxMps { anomaly_seed: 3 }), &w).throughput_flops();
        let st = run(&cfg(Policy::SpaceTime { max_batch: 128 }), &w).throughput_flops();
        assert!(st > space * 1.5, "space-time {st} vs space {space}");
        assert!(st > time * 3.0, "space-time {st} vs time {time}");
    }

    #[test]
    fn space_time_counts_superkernels() {
        let w = sgemm_workloads(10, 4, GemmShape::SQUARE_256);
        let r = run(&cfg(Policy::SpaceTime { max_batch: 64 }), &w);
        assert_eq!(r.superkernel_launches, 4, "one super-kernel per round");
        assert_eq!(r.fused_problems, 40);
        assert_eq!(r.kernel_launches, 4);
    }

    #[test]
    fn space_time_respects_max_batch() {
        let w = sgemm_workloads(10, 1, GemmShape::SQUARE_256);
        let r = run(&cfg(Policy::SpaceTime { max_batch: 4 }), &w);
        // 10 problems in chunks of 4 → 3 launches (4+4+2).
        assert_eq!(r.kernel_launches, 3);
        assert_eq!(r.fused_problems, 10);
    }

    /// Two distinct shape classes — each round plans one super-kernel per
    /// class, so a multi-lane round has real concurrent work to overlap.
    fn two_class_workloads(per_class: usize, iters: u32) -> Vec<TenantWorkload> {
        let a = GemmShape::RESNET18_CONV2_2; // 256x128x1152, 32 CTAs
        let b = GemmShape::new(128, 256, 1152); // same work, distinct class
        (0..2 * per_class)
            .map(|t| {
                let shape = if t < per_class { a } else { b };
                TenantWorkload::new(vec![KernelDesc::sgemm(t, shape)], iters)
            })
            .collect()
    }

    /// One compute-bound tenant (occupancy saturated at 60 CTAs/SM, so
    /// halving the SM pool roughly doubles its duration) plus seven
    /// memory-bound tenants (40 SMs still reach full HBM bandwidth, so
    /// they barely stretch). The planner balances *exclusive-time*
    /// weights, so under two lanes the memory lane drains early while the
    /// compute lane still holds queued work — the imbalance work stealing
    /// exists to absorb. Class names are chosen so the compute class
    /// sorts (and therefore plans) first.
    fn skewed_workloads(iters: u32) -> Vec<TenantWorkload> {
        let mut w = vec![TenantWorkload::new(
            vec![KernelDesc::other(0, "compute_heavy", 2.5e10, 1e6, 4800)],
            iters,
        )];
        for t in 1..8 {
            w.push(TenantWorkload::new(
                vec![KernelDesc::other(t, "mem_stream", 1e9, 450e6, 4800)],
                iters,
            ));
        }
        w
    }

    #[test]
    fn stealing_rebalances_a_skewed_round() {
        let w = skewed_workloads(4);
        let base = cfg(Policy::SpaceTimeLanes { max_batch: 1, lanes: 2 });
        let off = run(&base.clone(), &w);
        let on = run(&base.with_steal(true), &w);
        assert_eq!(off.steals, 0, "stealing is opt-in");
        assert!(on.steals > 0, "the skewed round must trigger steals");
        assert_eq!(on.total_completed(), off.total_completed(), "no lost work");
        assert!(
            (on.total_flops() - off.total_flops()).abs() < 1e-3,
            "FLOPs must be conserved under stealing"
        );
        assert!(
            on.makespan < off.makespan * 0.95,
            "work conservation must shorten the round barrier: {} vs {}",
            on.makespan,
            off.makespan
        );
    }

    #[test]
    fn stealing_keeps_round_tags_and_both_lanes_busy() {
        let w = skewed_workloads(3);
        let r = run(
            &cfg(Policy::SpaceTimeLanes { max_batch: 1, lanes: 2 })
                .with_steal(true)
                .with_trace(),
            &w,
        );
        assert!(r.steals > 0);
        let max_lane = r.trace.events.iter().map(|e| e.lane).max().unwrap();
        assert_eq!(max_lane, 1, "both lanes carry launches");
        // Completions keep their *planned* round tag even when executed
        // on a thief lane: tags ascend with time and cover every round.
        let mut last = 0u64;
        for e in &r.trace.events {
            assert!(e.round >= last, "round tags must ascend with time");
            last = e.round;
        }
        assert_eq!(last, r.rounds - 1, "every round appears in the trace");
    }

    #[test]
    fn steal_is_inert_on_one_lane() {
        let w = two_class_workloads(4, 6);
        let off = run(&cfg(Policy::SpaceTimeLanes { max_batch: 64, lanes: 1 }), &w);
        let on = run(
            &cfg(Policy::SpaceTimeLanes { max_batch: 64, lanes: 1 }).with_steal(true),
            &w,
        );
        assert_eq!(on.steals, 0);
        assert_eq!(off.makespan.to_bits(), on.makespan.to_bits());
        assert_eq!(off.kernel_launches, on.kernel_launches);
    }

    #[test]
    fn one_lane_equals_plain_space_time() {
        let w = two_class_workloads(4, 6);
        let plain = run(&cfg(Policy::SpaceTime { max_batch: 64 }), &w);
        let lanes1 = run(&cfg(Policy::SpaceTimeLanes { max_batch: 64, lanes: 1 }), &w);
        assert!((plain.makespan - lanes1.makespan).abs() < 1e-12 * plain.makespan);
        assert_eq!(plain.kernel_launches, lanes1.kernel_launches);
        assert_eq!(plain.total_completed(), lanes1.total_completed());
    }

    #[test]
    fn concurrent_lanes_beat_serial_rounds_when_launches_underfill() {
        // Each round has two 128-CTA super-kernels: alone, either leaves
        // the 80-SM device at ~1.6 CTAs/SM (occupancy ~21%); two lanes at
        // 40 SMs each run at 3.2 CTAs/SM (~35%) and overlap — the concave
        // occupancy curve makes planned spatial sharing a strict win even
        // after the interference derate.
        let w = two_class_workloads(4, 10);
        let serial = run(&cfg(Policy::SpaceTime { max_batch: 64 }), &w);
        let lanes = run(&cfg(Policy::SpaceTimeLanes { max_batch: 64, lanes: 2 }), &w);
        assert!(
            lanes.throughput_flops() > serial.throughput_flops() * 1.2,
            "2 lanes {} should beat 1 lane {} by >20%",
            lanes.throughput_flops(),
            serial.throughput_flops()
        );
        assert_eq!(lanes.total_completed(), serial.total_completed());
    }

    #[test]
    fn lane_trace_shows_overlap() {
        let w = two_class_workloads(3, 2);
        let r = run(
            &cfg(Policy::SpaceTimeLanes { max_batch: 64, lanes: 2 }).with_trace(),
            &w,
        );
        let max_lane = r.trace.events.iter().map(|e| e.lane).max().unwrap();
        assert_eq!(max_lane, 1, "two lanes should both carry launches");
        // Some pair of events on distinct lanes overlaps in time.
        let overlapped = r.trace.events.iter().any(|a| {
            r.trace.events.iter().any(|b| {
                a.lane != b.lane && a.t_start < b.t_end && b.t_start < a.t_end
            })
        });
        assert!(overlapped, "concurrent lanes must overlap in the trace");
    }

    #[test]
    fn adaptive_policy_converges_to_profitable_lanes() {
        // Two shape classes -> every saturated round plans two launches
        // that underfill the device: static 2-lane rounds beat serial by
        // >20% (`concurrent_lanes_beat_serial_...` above). The adaptive
        // controller, fed only simulated signals, must discover that on
        // its own: strictly beat plain space-time and land within reach of
        // the best static setting despite its 1-lane warmup rounds.
        let w = two_class_workloads(4, 30);
        let serial = run(&cfg(Policy::SpaceTime { max_batch: 64 }), &w);
        let static2 = run(&cfg(Policy::SpaceTimeLanes { max_batch: 64, lanes: 2 }), &w);
        let adaptive = run(
            &cfg(Policy::SpaceTimeAdaptive { max_batch: 64, max_lanes: 4 }).with_trace(),
            &w,
        );
        assert_eq!(adaptive.total_completed(), serial.total_completed());
        assert!(
            (adaptive.total_flops() - serial.total_flops()).abs() < 1e-3,
            "adaptive control must not lose work"
        );
        assert!(
            adaptive.throughput_flops() > serial.throughput_flops() * 1.05,
            "adaptive {} must beat serial {} (controller never engaged?)",
            adaptive.throughput_flops(),
            serial.throughput_flops()
        );
        assert!(
            adaptive.throughput_flops() > static2.throughput_flops() * 0.8,
            "adaptive {} should approach the best static {}",
            adaptive.throughput_flops(),
            static2.throughput_flops()
        );
        // Ground truth in the trace: later rounds actually overlap lanes,
        // and the lane cap is respected.
        let max_lane = adaptive.trace.events.iter().map(|e| e.lane).max().unwrap();
        assert!(max_lane >= 1, "controller never left serial rounds");
        assert!(max_lane < 4, "lane cap violated");
    }

    #[test]
    fn adaptive_with_max_lanes_one_matches_plain_space_time() {
        let w = two_class_workloads(3, 8);
        let plain = run(&cfg(Policy::SpaceTime { max_batch: 64 }), &w);
        let capped =
            run(&cfg(Policy::SpaceTimeAdaptive { max_batch: 64, max_lanes: 1 }), &w);
        assert!((plain.makespan - capped.makespan).abs() < 1e-12 * plain.makespan);
        assert_eq!(plain.kernel_launches, capped.kernel_launches);
        assert_eq!(plain.total_completed(), capped.total_completed());
        assert_eq!(plain.rounds, capped.rounds);
    }

    #[test]
    fn adaptive_stays_serial_for_single_class_rounds() {
        // One shape class -> one launch per round: nothing to overlap, so
        // the controller must keep serial rounds (identical makespan).
        let w = sgemm_workloads(8, 10, GemmShape::RESNET18_CONV2_2);
        let plain = run(&cfg(Policy::SpaceTime { max_batch: 64 }), &w);
        let adaptive =
            run(&cfg(Policy::SpaceTimeAdaptive { max_batch: 64, max_lanes: 4 }), &w);
        assert!((plain.makespan - adaptive.makespan).abs() < 1e-9 * plain.makespan);
    }

    #[test]
    fn space_time_completions_are_round_tagged() {
        // Every completion event carries the planning round it belongs
        // to: tags ascend with time, every round in [0, rounds) appears,
        // and a saturated 10-tenant/4-iteration run spans several rounds.
        let w = sgemm_workloads(10, 4, GemmShape::SQUARE_256);
        let r = run(&cfg(Policy::SpaceTime { max_batch: 64 }).with_trace(), &w);
        assert!(r.rounds >= 4, "expected one planning round per iteration");
        assert_eq!(r.trace.rounds(), r.rounds);
        let mut last_start = 0.0f64;
        let mut seen = vec![false; r.rounds as usize];
        let mut events = r.trace.events.clone();
        events.sort_by(|a, b| a.t_start.partial_cmp(&b.t_start).unwrap());
        let mut last_round = 0u64;
        for e in &events {
            assert!(e.round < r.rounds);
            assert!(e.round >= last_round, "round tags must ascend with time");
            assert!(e.t_start >= last_start);
            seen[e.round as usize] = true;
            last_round = e.round;
            last_start = e.t_start;
        }
        assert!(seen.iter().all(|&s| s), "every round must carry a launch");
        // The quantum-structured baseline is tagged too.
        let tm = run(&cfg(Policy::TimeMux).with_trace(), &w);
        assert_eq!(tm.trace.rounds(), tm.rounds);
        assert!(tm.rounds > 0);
    }

    #[test]
    fn mps_anomaly_creates_straggler_gap() {
        let w = sgemm_workloads(9, 30, GemmShape::RESNET18_CONV2_2);
        let r = run(&cfg(Policy::SpaceMuxMps { anomaly_seed: 11 }), &w);
        assert!(
            r.straggler_gap() > 0.02,
            "MPS run should show a visible straggler gap, got {}",
            r.straggler_gap()
        );
        // Explicit streams have no anomaly; gap should be (near) zero.
        let r2 = run(&cfg(Policy::SpaceMuxStreams), &w);
        assert!(r2.straggler_gap() < r.straggler_gap());
    }

    #[test]
    fn flops_conserved_across_policies() {
        let w = sgemm_workloads(5, 7, GemmShape::SQUARE_256);
        let expected: f64 = w.iter().map(|x| x.total_flops()).sum();
        for policy in [
            Policy::Exclusive,
            Policy::TimeMux,
            Policy::SpaceMuxMps { anomaly_seed: 5 },
            Policy::SpaceMuxStreams,
            Policy::SpaceTime { max_batch: 8 },
            Policy::SpaceTimeLanes { max_batch: 8, lanes: 3 },
        ] {
            let r = run(&cfg(policy), &w);
            assert!(
                (r.total_flops() - expected).abs() < 1e-3,
                "FLOPs must be conserved"
            );
        }
    }

    #[test]
    fn trace_capture_respects_flag() {
        let w = sgemm_workloads(2, 2, GemmShape::SQUARE_256);
        let with = run(&cfg(Policy::TimeMux).with_trace(), &w);
        let without = run(&cfg(Policy::TimeMux), &w);
        assert!(with.trace.launches() > 0);
        assert_eq!(without.trace.launches(), 0);
    }

    #[test]
    fn empty_and_zero_iteration_workloads() {
        let w = vec![
            TenantWorkload::new(vec![KernelDesc::sgemm(0, GemmShape::SQUARE_256)], 0),
            TenantWorkload::new(vec![], 3),
            TenantWorkload::new(vec![KernelDesc::sgemm(2, GemmShape::SQUARE_256)], 2),
        ];
        for policy in [
            Policy::Exclusive,
            Policy::TimeMux,
            Policy::SpaceMuxMps { anomaly_seed: 1 },
            Policy::SpaceMuxStreams,
            Policy::SpaceTime { max_batch: 8 },
        ] {
            let r = run(&cfg(policy.clone()), &w);
            assert_eq!(r.total_completed(), 2, "{policy:?}");
            assert_eq!(r.tenants[0].completed, 0);
            assert_eq!(r.tenants[1].completed, 0);
            assert_eq!(r.tenants[2].completed, 2);
        }
    }

    #[test]
    fn multi_layer_inference_latency_spans_all_layers() {
        // A 3-kernel inference must have latency >= sum of its own kernels.
        let kernels: Vec<KernelDesc> = (0..3)
            .map(|_| KernelDesc::sgemm(0, GemmShape::SQUARE_256))
            .collect();
        let w = vec![TenantWorkload::new(kernels.clone(), 4)];
        let spec = DeviceSpec::v100();
        let per_kernel: f64 = kernels
            .iter()
            .map(|k| kernel_service_time(&spec, k, &CostCtx::exclusive(&spec)))
            .sum();
        let r = run(&cfg(Policy::SpaceMuxStreams), &w);
        for &l in &r.tenants[0].latencies {
            assert!(l >= per_kernel * 0.99, "latency {l} < service {per_kernel}");
        }
    }

    // -----------------------------------------------------------------------
    // Vectorized == legacy oracle.
    // -----------------------------------------------------------------------

    fn all_policies() -> Vec<Policy> {
        vec![
            Policy::Exclusive,
            Policy::TimeMux,
            Policy::SpaceMuxMps { anomaly_seed: 7 },
            Policy::SpaceMuxStreams,
            Policy::SpaceTime { max_batch: 8 },
            Policy::SpaceTimeLanes { max_batch: 8, lanes: 3 },
            Policy::SpaceTimeAdaptive { max_batch: 8, max_lanes: 4 },
        ]
    }

    /// Bitwise report equality: every float compared by bits, every trace
    /// event by value (event-for-event). `scratch_grows` is intentionally
    /// excluded — it is the one field the engines legitimately differ on.
    fn assert_bitwise_equal(a: &SimReport, b: &SimReport, what: &str) {
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{what}: makespan");
        assert_eq!(a.kernel_launches, b.kernel_launches, "{what}: launches");
        assert_eq!(
            a.superkernel_launches, b.superkernel_launches,
            "{what}: superkernels"
        );
        assert_eq!(a.fused_problems, b.fused_problems, "{what}: fused problems");
        assert_eq!(a.rounds, b.rounds, "{what}: rounds");
        assert_eq!(a.tenants.len(), b.tenants.len(), "{what}: tenant count");
        for (i, (x, y)) in a.tenants.iter().zip(&b.tenants).enumerate() {
            assert_eq!(x.completed, y.completed, "{what}: tenant {i} completed");
            assert_eq!(x.flops.to_bits(), y.flops.to_bits(), "{what}: tenant {i} flops");
            assert_eq!(
                x.latencies.len(),
                y.latencies.len(),
                "{what}: tenant {i} latency count"
            );
            for (j, (p, q)) in x.latencies.iter().zip(&y.latencies).enumerate() {
                assert_eq!(p.to_bits(), q.to_bits(), "{what}: tenant {i} latency {j}");
            }
        }
        assert_eq!(a.trace.events, b.trace.events, "{what}: trace events");
    }

    fn fixtures() -> Vec<(&'static str, Vec<TenantWorkload>)> {
        vec![
            ("uniform conv", sgemm_workloads(6, 5, GemmShape::RESNET18_CONV2_2)),
            ("two classes", two_class_workloads(4, 6)),
            ("square", sgemm_workloads(10, 4, GemmShape::SQUARE_256)),
            ("matvec", sgemm_workloads(5, 7, GemmShape::RNN_MATVEC)),
            (
                "ragged mixed",
                vec![
                    TenantWorkload::new(vec![KernelDesc::sgemm(0, GemmShape::SQUARE_256)], 0),
                    TenantWorkload::new(vec![], 3),
                    TenantWorkload::new(
                        vec![
                            KernelDesc::sgemm(2, GemmShape::SQUARE_256),
                            KernelDesc::other(2, "relu", 1e7, 4e6, 8),
                        ],
                        2,
                    ),
                    TenantWorkload::new(vec![KernelDesc::other(3, "relu", 1e7, 4e6, 8)], 3),
                    TenantWorkload::new(vec![KernelDesc::other(4, "layernorm", 2e7, 9e6, 12)], 4),
                ],
            ),
        ]
    }

    #[test]
    fn vectorized_matches_legacy_on_fixtures() {
        // The acceptance fixture set: the existing fig3/fig10/adaptive sim
        // shapes plus a ragged mixed-kernel workload, every policy, traces
        // on — both engines must agree bit for bit, event for event.
        for (name, w) in &fixtures() {
            for policy in all_policies() {
                let fast = run(&cfg(policy.clone()).with_trace(), w);
                let oracle = run(
                    &cfg(policy.clone()).with_trace().with_engine(Engine::Legacy),
                    w,
                );
                assert_bitwise_equal(&fast, &oracle, &format!("{name} / {policy:?}"));
            }
        }
    }

    #[test]
    fn vectorized_matches_legacy_property() {
        use crate::util::prng::Rng;
        let shapes = [
            GemmShape::SQUARE_256,
            GemmShape::RESNET18_CONV2_2,
            GemmShape::RNN_MATVEC,
            GemmShape::new(64, 64, 512),
        ];
        let names = ["relu", "layernorm", "softmax"];
        crate::util::prop::run_prop("engine_equivalence", 0x00E1152, 48, |rng: &mut Rng| {
            let n = rng.gen_range_inclusive(1, 9) as usize;
            let w: Vec<TenantWorkload> = (0..n)
                .map(|t| {
                    let n_kernels = rng.gen_range(4) as usize;
                    let kernels = (0..n_kernels)
                        .map(|_| {
                            if rng.gen_bool(0.7) {
                                let s = shapes[rng.gen_range(shapes.len() as u64) as usize];
                                KernelDesc::sgemm(t, s)
                            } else {
                                let name = names[rng.gen_range(names.len() as u64) as usize];
                                KernelDesc::other(
                                    t,
                                    name,
                                    1e6 + rng.gen_f64_range(0.0, 1e8),
                                    1e5 + rng.gen_f64_range(0.0, 1e7),
                                    1 + rng.gen_range(64) as u32,
                                )
                            }
                        })
                        .collect();
                    TenantWorkload::new(kernels, rng.gen_range(5) as u32)
                })
                .collect();
            let max_batch = 1 + rng.gen_range(8) as u32;
            let lanes = 1 + rng.gen_range(4) as u32;
            let policies = [
                Policy::Exclusive,
                Policy::TimeMux,
                Policy::SpaceMuxMps { anomaly_seed: rng.next_u64() },
                Policy::SpaceMuxStreams,
                Policy::SpaceTime { max_batch },
                Policy::SpaceTimeLanes { max_batch, lanes },
                Policy::SpaceTimeAdaptive { max_batch, max_lanes: lanes },
            ];
            for policy in policies {
                let fast = run(&cfg(policy.clone()).with_trace(), &w);
                let oracle = run(
                    &cfg(policy.clone()).with_trace().with_engine(Engine::Legacy),
                    &w,
                );
                assert_bitwise_equal(&fast, &oracle, &format!("{policy:?}"));
            }
        });
    }

    #[test]
    fn no_trace_run_allocates_nothing_per_event() {
        // The zero-alloc regression (grows-counter idiom): the SoA engine's
        // scratch buffers are sized at setup, so the capacity watchdog must
        // see zero post-warmup growth, and a run without --trace must never
        // materialize a TraceEvent (the label-cloning closure is never
        // called, so the events vector never even allocates).
        let mut w = two_class_workloads(4, 20);
        w.push(TenantWorkload::new(
            vec![KernelDesc::other(8, "fused_layernorm_gelu", 5e7, 2e7, 16)],
            20,
        ));
        for policy in all_policies() {
            let r = run(&cfg(policy.clone()), &w);
            assert_eq!(r.scratch_grows, 0, "{policy:?}: steady-state scratch grew");
            assert_eq!(
                r.trace.events.capacity(),
                0,
                "{policy:?}: trace allocated while disabled"
            );
            assert!(r.total_completed() > 0, "{policy:?}");
        }
    }

    #[test]
    fn engine_parse_round_trips() {
        assert_eq!(Engine::parse("vectorized"), Some(Engine::Vectorized));
        assert_eq!(Engine::parse("soa"), Some(Engine::Vectorized));
        assert_eq!(Engine::parse("legacy"), Some(Engine::Legacy));
        assert_eq!(Engine::parse("reference"), Some(Engine::Legacy));
        assert_eq!(Engine::parse("warp-drive"), None);
        assert_eq!(Engine::default(), Engine::Vectorized);
        assert_eq!(Engine::Legacy.label(), "legacy");
    }
}
