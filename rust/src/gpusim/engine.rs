//! Discrete-event execution engine: runs multi-tenant kernel workloads under
//! each of the paper's multiplexing policies and reports per-tenant latency,
//! throughput, launch counts and a schedule trace.
//!
//! Policies (paper §3):
//! * [`Policy::Exclusive`] — every tenant gets a *private* GPU (the paper's
//!   single-tenant lower bound; simulated as independent devices).
//! * [`Policy::TimeMux`] — one device, one resident CUDA context at a time,
//!   round-robin quanta with context-switch penalties.
//! * [`Policy::SpaceMuxMps`] — implicit spatial sharing through the MPS
//!   proxy: concurrent kernels, static BW partitioning, straggler anomalies.
//! * [`Policy::SpaceMuxStreams`] — explicit CUDA streams in one process:
//!   concurrent kernels, demand-shared bandwidth, no MPS proxy overhead.
//! * [`Policy::SpaceTime`] — the paper's contribution: per-round inter-model
//!   batching of same-shape GEMMs into super-kernels that fill the device.

use crate::gpusim::cost::{kernel_service_time, CostCtx};
use crate::gpusim::device::DeviceSpec;
use crate::gpusim::kernel::{KernelDesc, TenantId};
use crate::gpusim::mps::MpsAnomaly;
use crate::gpusim::trace::{Trace, TraceEvent};

/// One tenant's closed-loop workload: `iterations` repetitions of the kernel
/// sequence (one sequence = one inference / forward pass).
#[derive(Debug, Clone)]
pub struct TenantWorkload {
    pub kernels: Vec<KernelDesc>,
    pub iterations: u32,
}

/// Placement class of a workload: workloads sharing a class can fuse, so
/// the device pool keeps them on one shard when load allows (see
/// [`crate::gpusim::pool`]).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum WorkloadClass {
    /// Head kernel is a batchable GEMM of this (M, N, K).
    Gemm(u32, u32, u32),
    /// Head kernel is a non-GEMM kernel, keyed by name.
    Other(String),
    /// No kernels.
    Empty,
}

impl TenantWorkload {
    pub fn new(kernels: Vec<KernelDesc>, iterations: u32) -> Self {
        Self { kernels, iterations }
    }

    pub fn total_flops(&self) -> f64 {
        self.kernels.iter().map(|k| k.flops).sum::<f64>() * self.iterations as f64
    }

    /// Fusion/placement class (head-kernel shape — paper §2: same
    /// architecture tenants have aligned kernel streams).
    pub fn class_key(&self) -> WorkloadClass {
        match self.kernels.first() {
            Some(k) => match k.shape {
                Some(s) => WorkloadClass::Gemm(s.m, s.n, s.k),
                None => WorkloadClass::Other(k.name.clone()),
            },
            None => WorkloadClass::Empty,
        }
    }
}

/// Multiplexing policy under simulation.
#[derive(Debug, Clone, PartialEq)]
pub enum Policy {
    Exclusive,
    TimeMux,
    SpaceMuxMps { anomaly_seed: u64 },
    SpaceMuxStreams,
    SpaceTime { max_batch: u32 },
    /// Space-time with `lanes` concurrent spatial execution lanes: each
    /// round's super-kernels are balanced across lanes that execute
    /// concurrently, each on a static `sms / lanes` SM fraction with the
    /// deterministic interference derate of [`DeviceSpec::interference`] —
    /// planned spatial sharing replaces the MPS anomaly table on this path
    /// (the scheduler owns the interference model; DARIS, arXiv:2504.08795).
    /// `lanes = 1` degenerates to [`Policy::SpaceTime`].
    SpaceTimeLanes { max_batch: u32, lanes: u32 },
    /// Space-time with the **adaptive controller** choosing the resident
    /// lane count online — the same
    /// [`crate::coordinator::controller::AdaptiveController`] the serving
    /// driver runs, fed simulated signals (round width, exclusive-time
    /// launch durations, measured `dur_overlapped / dur_solo` stretch),
    /// so the control loop can be validated against the simulator's
    /// ground-truth cost model (`stgpu simulate/trace --adaptive`).
    /// `max_lanes = 1` degenerates to [`Policy::SpaceTime`].
    SpaceTimeAdaptive { max_batch: u32, max_lanes: u32 },
}

impl Policy {
    pub fn label(&self) -> &'static str {
        match self {
            Policy::Exclusive => "exclusive",
            Policy::TimeMux => "time-mux",
            Policy::SpaceMuxMps { .. } => "space-mux (MPS)",
            Policy::SpaceMuxStreams => "space-mux (streams)",
            Policy::SpaceTime { .. } => "space-time",
            Policy::SpaceTimeLanes { .. } => "space-time (lanes)",
            Policy::SpaceTimeAdaptive { .. } => "space-time (adaptive)",
        }
    }
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    pub spec: DeviceSpec,
    pub policy: Policy,
    pub capture_trace: bool,
}

impl SimConfig {
    pub fn new(spec: DeviceSpec, policy: Policy) -> Self {
        Self {
            spec,
            policy,
            capture_trace: false,
        }
    }

    pub fn with_trace(mut self) -> Self {
        self.capture_trace = true;
        self
    }
}

/// Per-tenant results.
#[derive(Debug, Clone, Default)]
pub struct TenantReport {
    /// Wall-clock latency of each completed inference, seconds.
    pub latencies: Vec<f64>,
    pub completed: u64,
    pub flops: f64,
}

impl TenantReport {
    pub fn mean_latency(&self) -> f64 {
        crate::util::stats::mean(&self.latencies)
    }
}

/// Whole-run results.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    pub tenants: Vec<TenantReport>,
    pub makespan: f64,
    pub kernel_launches: u64,
    pub superkernel_launches: u64,
    /// Total problems executed inside super-kernels.
    pub fused_problems: u64,
    /// Scheduling rounds executed: planning rounds for the space-time
    /// policies, context quanta for time-mux, 0 for the round-less
    /// policies. Completion events carry their round in
    /// [`TraceEvent::round`].
    pub rounds: u64,
    pub trace: Trace,
}

impl SimReport {
    pub fn total_flops(&self) -> f64 {
        self.tenants.iter().map(|t| t.flops).sum()
    }

    pub fn total_completed(&self) -> u64 {
        self.tenants.iter().map(|t| t.completed).sum()
    }

    pub fn throughput_flops(&self) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            self.total_flops() / self.makespan
        }
    }

    pub fn mean_latency(&self) -> f64 {
        let all: Vec<f64> = self
            .tenants
            .iter()
            .flat_map(|t| t.latencies.iter().copied())
            .collect();
        crate::util::stats::mean(&all)
    }

    /// Fastest vs slowest tenant mean-latency gap (Figure 4 metric).
    pub fn straggler_gap(&self) -> f64 {
        let means: Vec<f64> = self
            .tenants
            .iter()
            .filter(|t| !t.latencies.is_empty())
            .map(|t| t.mean_latency())
            .collect();
        if means.len() < 2 {
            return 0.0;
        }
        let fast = means.iter().cloned().fold(f64::INFINITY, f64::min);
        let slow = means.iter().cloned().fold(0.0, f64::max);
        if fast <= 0.0 {
            0.0
        } else {
            slow / fast - 1.0
        }
    }
}

/// Run `workloads` under `cfg`.
pub fn run(cfg: &SimConfig, workloads: &[TenantWorkload]) -> SimReport {
    match &cfg.policy {
        Policy::Exclusive => run_exclusive(cfg, workloads),
        Policy::TimeMux => run_time_mux(cfg, workloads),
        Policy::SpaceMuxMps { anomaly_seed } => {
            let anomaly = MpsAnomaly::new(*anomaly_seed, workloads.len());
            run_space_mux(cfg, workloads, &anomaly, true, cfg.spec.mps_launch_overhead_s)
        }
        Policy::SpaceMuxStreams => {
            let anomaly = MpsAnomaly::none(workloads.len());
            run_space_mux(
                cfg,
                workloads,
                &anomaly,
                false,
                cfg.spec.dispatch_serialization_s,
            )
        }
        Policy::SpaceTime { max_batch } => {
            run_space_time(cfg, workloads, *max_batch, LaneMode::Static(1))
        }
        Policy::SpaceTimeLanes { max_batch, lanes } => {
            run_space_time(cfg, workloads, *max_batch, LaneMode::Static((*lanes).max(1)))
        }
        Policy::SpaceTimeAdaptive { max_batch, max_lanes } => run_space_time(
            cfg,
            workloads,
            *max_batch,
            LaneMode::Adaptive { max_lanes: (*max_lanes).max(1) },
        ),
    }
}

// ---------------------------------------------------------------------------
// Exclusive: each tenant on a private device.
// ---------------------------------------------------------------------------

fn run_exclusive(cfg: &SimConfig, workloads: &[TenantWorkload]) -> SimReport {
    let spec = &cfg.spec;
    let mut report = SimReport {
        trace: Trace::new(cfg.capture_trace),
        ..Default::default()
    };
    let ctx = CostCtx::exclusive(spec);
    let mut makespan: f64 = 0.0;
    for (tid, w) in workloads.iter().enumerate() {
        let mut t = 0.0;
        let mut tr = TenantReport::default();
        if w.kernels.is_empty() {
            report.tenants.push(tr);
            continue;
        }
        for iter in 0..w.iterations {
            let start = t;
            for k in &w.kernels {
                let dur = spec.launch_overhead_s + kernel_service_time(spec, k, &ctx);
                report.trace.record(TraceEvent {
                    t_start: t,
                    t_end: t + dur,
                    lane: tid,
                    tenant: tid,
                    label: k.name.clone(),
                    sms: (k.ctas as f64).min(spec.sms as f64),
                    fused: k.fused,
                    round: iter as u64,
                });
                t += dur;
                report.kernel_launches += 1;
                tr.flops += k.flops;
            }
            tr.latencies.push(t - start);
            tr.completed += 1;
        }
        makespan = makespan.max(t);
        // Exclusive "rounds" are inference iterations (events are tagged
        // with theirs); the run spans the longest tenant's count.
        if !w.kernels.is_empty() {
            report.rounds = report.rounds.max(w.iterations as u64);
        }
        report.tenants.push(tr);
    }
    report.makespan = makespan;
    report
}

// ---------------------------------------------------------------------------
// Time multiplexing: one resident context, round-robin quanta.
// ---------------------------------------------------------------------------

fn run_time_mux(cfg: &SimConfig, workloads: &[TenantWorkload]) -> SimReport {
    let spec = &cfg.spec;
    let n = workloads.len();
    let mut report = SimReport {
        tenants: vec![TenantReport::default(); n],
        trace: Trace::new(cfg.capture_trace),
        ..Default::default()
    };
    // Per-tenant cursor. `inf_start` is the *submission* time of the
    // in-flight inference: in the saturated closed loop every tenant's
    // first inference is submitted at t=0 and each completion immediately
    // submits the next, so waiting for other tenants' quanta is part of the
    // measured latency (this is what makes time-mux latency grow linearly
    // with the tenant count — paper Fig 3).
    struct Cursor {
        iter: u32,
        kidx: usize,
        inf_start: f64,
    }
    let mut cursors: Vec<Cursor> = workloads
        .iter()
        .map(|_| Cursor {
            iter: 0,
            kidx: 0,
            inf_start: 0.0,
        })
        .collect();
    let ctx = CostCtx::exclusive(spec);
    let mut clock = 0.0f64;
    let pending = |c: &Cursor, w: &TenantWorkload| c.iter < w.iterations && !w.kernels.is_empty();
    let mut current = 0usize;
    // Number of tenants with work left.
    let mut live: usize = workloads
        .iter()
        .zip(cursors.iter())
        .filter(|(w, c)| pending(c, w))
        .count();
    let multi = live > 1;
    let mut quantum: u64 = 0;
    while live > 0 {
        // Find next tenant with pending work.
        let mut hops = 0;
        while !pending(&cursors[current], &workloads[current]) {
            current = (current + 1) % n;
            hops += 1;
            debug_assert!(hops <= n, "live>0 but no pending tenant");
        }
        // Context switch cost applies when more than one context exists.
        if multi {
            clock += spec.ctx_switch_s;
        }
        // Run this tenant's kernels until the quantum is spent (kernels are
        // non-preemptible: always finish the one we started).
        let mut quantum_left = spec.timeslice_quantum_s;
        let w = &workloads[current];
        while quantum_left > 0.0 && pending(&cursors[current], w) {
            let c = &mut cursors[current];
            let k = &w.kernels[c.kidx];
            let dur = spec.launch_overhead_s + kernel_service_time(spec, k, &ctx);
            report.trace.record(TraceEvent {
                t_start: clock,
                t_end: clock + dur,
                lane: current,
                tenant: current,
                label: k.name.clone(),
                sms: (k.ctas as f64).min(spec.sms as f64),
                fused: k.fused,
                round: quantum,
            });
            clock += dur;
            quantum_left -= dur;
            report.kernel_launches += 1;
            report.tenants[current].flops += k.flops;
            c.kidx += 1;
            if c.kidx == w.kernels.len() {
                c.kidx = 0;
                c.iter += 1;
                report.tenants[current].latencies.push(clock - c.inf_start);
                report.tenants[current].completed += 1;
                c.inf_start = clock; // next inference submitted immediately
                if c.iter == w.iterations {
                    live -= 1;
                }
            }
        }
        quantum += 1;
        current = (current + 1) % n;
    }
    report.rounds = quantum;
    report.makespan = clock;
    report
}

// ---------------------------------------------------------------------------
// Spatial multiplexing: event-driven processor sharing over SMs.
// ---------------------------------------------------------------------------

fn run_space_mux(
    cfg: &SimConfig,
    workloads: &[TenantWorkload],
    anomaly: &MpsAnomaly,
    static_bw: bool,
    per_kernel_overhead: f64,
) -> SimReport {
    let spec = &cfg.spec;
    let n = workloads.len();
    let mut report = SimReport {
        tenants: vec![TenantReport::default(); n],
        trace: Trace::new(cfg.capture_trace),
        ..Default::default()
    };

    /// In-flight kernel state: a dispatch phase of absolute duration followed
    /// by an execution phase tracked as a remaining fraction (the service
    /// time is re-evaluated whenever the resident set changes).
    struct Flight {
        tenant: TenantId,
        dispatch_left: f64,
        exec_frac_left: f64,
        started_at: f64,
    }
    struct Cursor {
        iter: u32,
        kidx: usize,
        /// Submission time of the in-flight inference (saturated closed
        /// loop: t=0, then each completion submits the next).
        inf_start: f64,
        done: bool,
    }

    let mut cursors: Vec<Cursor> = workloads
        .iter()
        .map(|w| Cursor {
            iter: 0,
            kidx: 0,
            inf_start: 0.0,
            done: w.iterations == 0 || w.kernels.is_empty(),
        })
        .collect();

    let max_resident = spec.max_concurrent_kernels as usize;
    let mut resident: Vec<Flight> = Vec::with_capacity(max_resident);
    // Tenants whose next kernel is ready but waiting for a hardware queue.
    let mut waiting: std::collections::VecDeque<TenantId> = (0..n)
        .filter(|&t| !cursors[t].done)
        .collect();
    let mut clock = 0.0f64;

    // Admit from the waiting queue into the resident set.
    fn admit(
        resident: &mut Vec<Flight>,
        waiting: &mut std::collections::VecDeque<TenantId>,
        cursors: &mut [Cursor],
        clock: f64,
        max_resident: usize,
        overhead: f64,
    ) {
        while resident.len() < max_resident {
            let Some(t) = waiting.pop_front() else { break };
            debug_assert!(!cursors[t].done);
            resident.push(Flight {
                tenant: t,
                dispatch_left: overhead,
                exec_frac_left: 1.0,
                started_at: clock,
            });
        }
    }

    admit(
        &mut resident,
        &mut waiting,
        &mut cursors,
        clock,
        max_resident,
        per_kernel_overhead,
    );

    while !resident.is_empty() {
        let conc = resident.len() as u32;
        // SM allocation proportional to CTA demand, capped by each kernel's
        // own CTA count; one redistribution round picks up the slack.
        let total_ctas: f64 = resident
            .iter()
            .map(|f| workloads[f.tenant].kernels[cursors[f.tenant].kidx].ctas as f64)
            .sum();
        let total_sms = spec.sms as f64;
        let mut allocs: Vec<f64> = resident
            .iter()
            .map(|f| {
                let ctas = workloads[f.tenant].kernels[cursors[f.tenant].kidx].ctas as f64;
                (total_sms * ctas / total_ctas.max(1.0)).min(ctas)
            })
            .collect();
        let used: f64 = allocs.iter().sum();
        let slack = (total_sms - used).max(0.0);
        if slack > 0.0 {
            // Give slack to kernels that can still use it (ctas > alloc).
            let extra_demand: f64 = resident
                .iter()
                .zip(allocs.iter())
                .map(|(f, &a)| {
                    (workloads[f.tenant].kernels[cursors[f.tenant].kidx].ctas as f64 - a).max(0.0)
                })
                .sum();
            if extra_demand > 0.0 {
                for (i, f) in resident.iter().enumerate() {
                    let ctas = workloads[f.tenant].kernels[cursors[f.tenant].kidx].ctas as f64;
                    let want = (ctas - allocs[i]).max(0.0);
                    allocs[i] += slack * want / extra_demand;
                    allocs[i] = allocs[i].min(ctas);
                }
            }
        }

        // Time to next completion.
        let mut dt = f64::INFINITY;
        let mut times: Vec<f64> = Vec::with_capacity(resident.len());
        for (i, f) in resident.iter().enumerate() {
            let k = &workloads[f.tenant].kernels[cursors[f.tenant].kidx];
            let t_exec = kernel_service_time(
                spec,
                k,
                &CostCtx {
                    sms: allocs[i].max(1e-9),
                    concurrency: conc,
                    static_bw_partition: static_bw,
                },
            ) * anomaly.multiplier(f.tenant);
            times.push(t_exec);
            let remaining = f.dispatch_left + f.exec_frac_left * t_exec;
            dt = dt.min(remaining);
        }
        debug_assert!(dt.is_finite() && dt >= 0.0);

        clock += dt;
        // Advance all flights by dt; collect completions.
        let mut completed_idx: Vec<usize> = Vec::new();
        for (i, f) in resident.iter_mut().enumerate() {
            let mut step = dt;
            if f.dispatch_left > 0.0 {
                let d = f.dispatch_left.min(step);
                f.dispatch_left -= d;
                step -= d;
            }
            if step > 0.0 && f.exec_frac_left > 0.0 {
                f.exec_frac_left -= step / times[i];
            }
            if f.dispatch_left <= 1e-15 && f.exec_frac_left <= 1e-9 {
                completed_idx.push(i);
            }
        }

        // Process completions (highest index first so removals are stable).
        for &i in completed_idx.iter().rev() {
            let f = resident.swap_remove(i);
            let t = f.tenant;
            let c = &mut cursors[t];
            let k = &workloads[t].kernels[c.kidx];
            report.kernel_launches += 1;
            report.tenants[t].flops += k.flops;
            report.trace.record(TraceEvent {
                t_start: f.started_at,
                t_end: clock,
                lane: t % max_resident.max(1),
                tenant: t,
                label: k.name.clone(),
                sms: (k.ctas as f64).min(spec.sms as f64 / (conc as f64)),
                fused: k.fused,
                // Event-driven path: no round structure to tag.
                round: 0,
            });
            c.kidx += 1;
            if c.kidx == workloads[t].kernels.len() {
                c.kidx = 0;
                c.iter += 1;
                report.tenants[t].latencies.push(clock - c.inf_start);
                report.tenants[t].completed += 1;
                c.inf_start = clock;
                if c.iter == workloads[t].iterations {
                    c.done = true;
                }
            }
            if !c.done {
                waiting.push_back(t);
            }
        }
        admit(
            &mut resident,
            &mut waiting,
            &mut cursors,
            clock,
            max_resident,
            per_kernel_overhead,
        );
    }
    report.makespan = clock;
    report
}

// ---------------------------------------------------------------------------
// Space-time: per-round inter-model super-kernel batching (the contribution),
// optionally spread over concurrent spatial lanes — statically or under the
// adaptive controller.
// ---------------------------------------------------------------------------

/// How the space-time round loop picks its lane count.
enum LaneMode {
    /// Fixed lane count for the whole run.
    Static(u32),
    /// The coordinator's
    /// [`crate::coordinator::controller::AdaptiveController`] re-decides
    /// the lane count every [`ADAPTIVE_DWELL_ROUNDS`] rounds from
    /// simulated signals.
    Adaptive { max_lanes: u32 },
}

/// Decision cadence of the simulated controller. Short on purpose:
/// simulated workloads run tens of rounds, and the point of the policy is
/// validating the control loop against ground truth, not modeling dwell
/// economics (the serving default is 32).
const ADAPTIVE_DWELL_ROUNDS: u32 = 2;

fn run_space_time(
    cfg: &SimConfig,
    workloads: &[TenantWorkload],
    max_batch: u32,
    mode: LaneMode,
) -> SimReport {
    use crate::coordinator::controller::{
        AdaptiveController, ControlSignals, ControllerParams, Decision, SignalTracker,
    };
    assert!(max_batch >= 1);
    let spec = &cfg.spec;
    let (static_lanes, mut controller) = match mode {
        LaneMode::Static(l) => (l.max(1), None),
        LaneMode::Adaptive { max_lanes } => (
            1,
            Some(AdaptiveController::new(
                ControllerParams {
                    max_lanes: max_lanes as usize,
                    max_depth: 1, // the simulator has no pipeline to deepen
                    dwell_rounds: ADAPTIVE_DWELL_ROUNDS,
                    improvement: 0.05,
                    slo_target: 0.99,
                },
                Decision { lanes: 1, depth: 1 },
            )),
        ),
    };
    let mut tracker = SignalTracker::default();
    let n = workloads.len();
    let mut report = SimReport {
        tenants: vec![TenantReport::default(); n],
        trace: Trace::new(cfg.capture_trace),
        ..Default::default()
    };
    struct Cursor {
        iter: u32,
        kidx: usize,
        inf_start: f64,
        done: bool,
    }
    let mut cursors: Vec<Cursor> = workloads
        .iter()
        .map(|w| Cursor {
            iter: 0,
            kidx: 0,
            inf_start: 0.0,
            done: w.iterations == 0 || w.kernels.is_empty(),
        })
        .collect();
    let mut clock = 0.0f64;
    let mut round: u64 = 0;

    loop {
        // Heads of all live tenants this round.
        let live: Vec<TenantId> = (0..n).filter(|&t| !cursors[t].done).collect();
        if live.is_empty() {
            break;
        }
        // Group heads: GEMMs by shape class, others by kernel name (the
        // same-architecture assumption of paper §2 makes names align).
        use std::collections::BTreeMap;
        #[derive(PartialEq, Eq, PartialOrd, Ord)]
        enum GroupKey {
            Gemm(u32, u32, u32),
            Other(String),
        }
        let mut groups: BTreeMap<GroupKey, Vec<TenantId>> = BTreeMap::new();
        for &t in &live {
            let k = &workloads[t].kernels[cursors[t].kidx];
            let key = match k.shape {
                Some(s) => GroupKey::Gemm(s.m, s.n, s.k),
                None => GroupKey::Other(k.name.clone()),
            };
            groups.entry(key).or_default().push(t);
        }

        // Plan the round's launches: each group in chunks of max_batch.
        let mut launches: Vec<(KernelDesc, Vec<TenantId>)> = Vec::new();
        for (key, members) in groups {
            for chunk in members.chunks(max_batch as usize) {
                let kernels: Vec<KernelDesc> = chunk
                    .iter()
                    .map(|&t| workloads[t].kernels[cursors[t].kidx].clone())
                    .collect();
                let merged = match key {
                    GroupKey::Gemm(..) if kernels.len() > 1 => {
                        KernelDesc::superkernel(&kernels)
                    }
                    _ => {
                        // Non-GEMM heads (or a singleton): pack grids by
                        // concatenation — same cost structure, summed work.
                        let mut k = kernels[0].clone();
                        for extra in &kernels[1..] {
                            k.flops += extra.flops;
                            k.bytes += extra.bytes;
                            k.ctas += extra.ctas;
                            k.fused += extra.fused;
                        }
                        k
                    }
                };
                launches.push((merged, chunk.to_vec()));
            }
        }

        // Adaptive mode: at each dwell boundary hand the controller the
        // tracker's signals — round width, exclusive-time launch duration
        // EWMA, and the measured overlapped/solo stretch (seeded from the
        // device spec before any overlapped round ran) — and take its
        // decision for this round. Static mode uses the configured count.
        let lanes_now = match &mut controller {
            Some(ctl) => {
                if ctl.tick() {
                    let max_lanes = ctl.params().max_lanes;
                    let stretch =
                        tracker.stretch_table(max_lanes, |n| spec.lane_stretch(n as u32));
                    let signals = ControlSignals {
                        backlog: 0, // closed loop: the heads ARE the demand
                        arrival_rate: 0.0,
                        launches_per_round: tracker.launches_per_round(),
                        requests_per_round: tracker.requests_per_round(),
                        mean_launch_s: tracker.mean_launch_s(),
                        plan_s: 0.0,
                        stretch,
                        slo_attainment: None,
                        min_slo_s: 0.0,
                    };
                    ctl.decide(&signals);
                }
                ctl.decision().lanes as u32
            }
            None => static_lanes,
        };
        // Assign launches to spatial lanes: greedy makespan balancing by
        // exclusive-time weight, in plan order (mirrors the coordinator's
        // lane assignment). With one lane (or one launch) this degenerates
        // to the classic serial round.
        let active = (lanes_now as usize).min(launches.len()).max(1);
        let mut lane_of: Vec<usize> = Vec::with_capacity(launches.len());
        let mut lane_load = vec![0.0f64; active];
        let excl = CostCtx::exclusive(spec);
        for (merged, _) in &launches {
            let w = spec.launch_overhead_s + kernel_service_time(spec, merged, &excl);
            let lane = (0..active)
                .min_by(|&a, &b| lane_load[a].partial_cmp(&lane_load[b]).unwrap())
                .unwrap();
            lane_of.push(lane);
            lane_load[lane] += w;
        }
        // Concurrently-resident lanes each execute on a static SM fraction
        // with the deterministic interference derate — planned spatial
        // sharing, not the MPS anomaly lottery (the explicit interference
        // model replaces the anomaly table on this path).
        let ctx = CostCtx {
            sms: spec.sms as f64 / active as f64,
            concurrency: active as u32,
            static_bw_partition: false,
        };
        let mut lane_cursor = vec![0.0f64; active];
        let mut problems_this_round = 0usize;
        for (i, (merged, chunk)) in launches.iter().enumerate() {
            let lane = lane_of[i];
            let dur = spec.launch_overhead_s + kernel_service_time(spec, merged, &ctx);
            if controller.is_some() {
                // Simulated measurement feedback: solo-equivalent launch
                // duration, and (overlapped rounds only) the ground-truth
                // stretch the controller's utility model calibrates from.
                let solo = spec.launch_overhead_s + kernel_service_time(spec, merged, &excl);
                tracker.observe_launch(solo);
                if active > 1 {
                    tracker.observe_stretch(active, dur / solo.max(1e-12));
                }
                problems_this_round += chunk.len();
            }
            let t_start = clock + lane_cursor[lane];
            let t_end = t_start + dur;
            lane_cursor[lane] += dur;
            report.trace.record(TraceEvent {
                t_start,
                t_end,
                lane,
                tenant: if chunk.len() == 1 { chunk[0] } else { usize::MAX },
                label: merged.name.clone(),
                sms: (merged.ctas as f64).min(ctx.sms),
                fused: merged.fused,
                // Round-tagged completion: every member of this round's
                // plan carries the planning round it belongs to, matching
                // the coordinator driver's pipelined attribution.
                round,
            });
            report.kernel_launches += 1;
            if merged.fused > 1 {
                report.superkernel_launches += 1;
                report.fused_problems += merged.fused as u64;
            }
            for &t in chunk {
                let k = &workloads[t].kernels[cursors[t].kidx];
                report.tenants[t].flops += k.flops;
            }
            // Members complete at their launch's end on its lane.
            for &t in chunk {
                let c = &mut cursors[t];
                c.kidx += 1;
                if c.kidx == workloads[t].kernels.len() {
                    c.kidx = 0;
                    c.iter += 1;
                    report.tenants[t].latencies.push(t_end - c.inf_start);
                    report.tenants[t].completed += 1;
                    c.inf_start = t_end;
                    if c.iter == workloads[t].iterations {
                        c.done = true;
                    }
                }
            }
        }
        if controller.is_some() {
            tracker.observe_round(launches.len(), problems_this_round, 0.0);
        }
        // The round barrier: the next round plans once every lane drains.
        clock += lane_cursor.iter().cloned().fold(0.0, f64::max);
        round += 1;
    }
    report.rounds = round;
    report.makespan = clock;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::kernel::GemmShape;

    fn sgemm_workloads(n: usize, iters: u32, shape: GemmShape) -> Vec<TenantWorkload> {
        (0..n)
            .map(|t| TenantWorkload::new(vec![KernelDesc::sgemm(t, shape)], iters))
            .collect()
    }

    fn cfg(policy: Policy) -> SimConfig {
        SimConfig::new(DeviceSpec::v100(), policy)
    }

    #[test]
    fn all_policies_complete_all_work() {
        let w = sgemm_workloads(6, 5, GemmShape::RESNET18_CONV2_2);
        for policy in [
            Policy::Exclusive,
            Policy::TimeMux,
            Policy::SpaceMuxMps { anomaly_seed: 1 },
            Policy::SpaceMuxStreams,
            Policy::SpaceTime { max_batch: 64 },
            Policy::SpaceTimeLanes { max_batch: 64, lanes: 2 },
        ] {
            let r = run(&cfg(policy.clone()), &w);
            assert_eq!(
                r.total_completed(),
                30,
                "policy {policy:?} must complete all inferences"
            );
            for t in &r.tenants {
                assert_eq!(t.completed, 5);
                assert_eq!(t.latencies.len(), 5);
                assert!(t.latencies.iter().all(|&l| l > 0.0));
            }
            assert!(r.makespan > 0.0);
        }
    }

    #[test]
    fn exclusive_latency_flat_in_tenant_count() {
        // Private GPUs: more tenants must not slow each other down.
        let l1 = run(&cfg(Policy::Exclusive), &sgemm_workloads(1, 10, GemmShape::SQUARE_256))
            .mean_latency();
        let l8 = run(&cfg(Policy::Exclusive), &sgemm_workloads(8, 10, GemmShape::SQUARE_256))
            .mean_latency();
        assert!((l1 - l8).abs() / l1 < 1e-9);
    }

    #[test]
    fn time_mux_latency_grows_linearly() {
        // Paper Fig 3: "linear-slowdown as the number of replicas grows".
        let shape = GemmShape::RESNET18_CONV2_2;
        let l2 = run(&cfg(Policy::TimeMux), &sgemm_workloads(2, 20, shape)).mean_latency();
        let l8 = run(&cfg(Policy::TimeMux), &sgemm_workloads(8, 20, shape)).mean_latency();
        let ratio = l8 / l2;
        assert!(
            (2.5..6.5).contains(&ratio),
            "8 vs 2 tenants should be ~4x slower, got {ratio}"
        );
    }

    #[test]
    fn space_mux_beats_time_mux_for_conv() {
        // Paper Fig 3: spatial multiplexing delivers better latency than
        // time multiplexing.
        let shape = GemmShape::RESNET18_CONV2_2;
        let w = sgemm_workloads(8, 20, shape);
        let t = run(&cfg(Policy::TimeMux), &w);
        let s = run(&cfg(Policy::SpaceMuxMps { anomaly_seed: 3 }), &w);
        assert!(
            s.mean_latency() < t.mean_latency(),
            "space {} should beat time {}",
            s.mean_latency(),
            t.mean_latency()
        );
        assert!(s.throughput_flops() > t.throughput_flops());
    }

    #[test]
    fn space_time_beats_both_for_conv() {
        // Paper Fig 7 / Table 1 direction.
        let shape = GemmShape::RESNET18_CONV2_2;
        let w = sgemm_workloads(20, 10, shape);
        let time = run(&cfg(Policy::TimeMux), &w).throughput_flops();
        let space = run(&cfg(Policy::SpaceMuxMps { anomaly_seed: 3 }), &w).throughput_flops();
        let st = run(&cfg(Policy::SpaceTime { max_batch: 128 }), &w).throughput_flops();
        assert!(st > space * 1.5, "space-time {st} vs space {space}");
        assert!(st > time * 3.0, "space-time {st} vs time {time}");
    }

    #[test]
    fn space_time_counts_superkernels() {
        let w = sgemm_workloads(10, 4, GemmShape::SQUARE_256);
        let r = run(&cfg(Policy::SpaceTime { max_batch: 64 }), &w);
        assert_eq!(r.superkernel_launches, 4, "one super-kernel per round");
        assert_eq!(r.fused_problems, 40);
        assert_eq!(r.kernel_launches, 4);
    }

    #[test]
    fn space_time_respects_max_batch() {
        let w = sgemm_workloads(10, 1, GemmShape::SQUARE_256);
        let r = run(&cfg(Policy::SpaceTime { max_batch: 4 }), &w);
        // 10 problems in chunks of 4 → 3 launches (4+4+2).
        assert_eq!(r.kernel_launches, 3);
        assert_eq!(r.fused_problems, 10);
    }

    /// Two distinct shape classes — each round plans one super-kernel per
    /// class, so a multi-lane round has real concurrent work to overlap.
    fn two_class_workloads(per_class: usize, iters: u32) -> Vec<TenantWorkload> {
        let a = GemmShape::RESNET18_CONV2_2; // 256x128x1152, 32 CTAs
        let b = GemmShape::new(128, 256, 1152); // same work, distinct class
        (0..2 * per_class)
            .map(|t| {
                let shape = if t < per_class { a } else { b };
                TenantWorkload::new(vec![KernelDesc::sgemm(t, shape)], iters)
            })
            .collect()
    }

    #[test]
    fn one_lane_equals_plain_space_time() {
        let w = two_class_workloads(4, 6);
        let plain = run(&cfg(Policy::SpaceTime { max_batch: 64 }), &w);
        let lanes1 = run(&cfg(Policy::SpaceTimeLanes { max_batch: 64, lanes: 1 }), &w);
        assert!((plain.makespan - lanes1.makespan).abs() < 1e-12 * plain.makespan);
        assert_eq!(plain.kernel_launches, lanes1.kernel_launches);
        assert_eq!(plain.total_completed(), lanes1.total_completed());
    }

    #[test]
    fn concurrent_lanes_beat_serial_rounds_when_launches_underfill() {
        // Each round has two 128-CTA super-kernels: alone, either leaves
        // the 80-SM device at ~1.6 CTAs/SM (occupancy ~21%); two lanes at
        // 40 SMs each run at 3.2 CTAs/SM (~35%) and overlap — the concave
        // occupancy curve makes planned spatial sharing a strict win even
        // after the interference derate.
        let w = two_class_workloads(4, 10);
        let serial = run(&cfg(Policy::SpaceTime { max_batch: 64 }), &w);
        let lanes = run(&cfg(Policy::SpaceTimeLanes { max_batch: 64, lanes: 2 }), &w);
        assert!(
            lanes.throughput_flops() > serial.throughput_flops() * 1.2,
            "2 lanes {} should beat 1 lane {} by >20%",
            lanes.throughput_flops(),
            serial.throughput_flops()
        );
        assert_eq!(lanes.total_completed(), serial.total_completed());
    }

    #[test]
    fn lane_trace_shows_overlap() {
        let w = two_class_workloads(3, 2);
        let r = run(
            &cfg(Policy::SpaceTimeLanes { max_batch: 64, lanes: 2 }).with_trace(),
            &w,
        );
        let max_lane = r.trace.events.iter().map(|e| e.lane).max().unwrap();
        assert_eq!(max_lane, 1, "two lanes should both carry launches");
        // Some pair of events on distinct lanes overlaps in time.
        let overlapped = r.trace.events.iter().any(|a| {
            r.trace.events.iter().any(|b| {
                a.lane != b.lane && a.t_start < b.t_end && b.t_start < a.t_end
            })
        });
        assert!(overlapped, "concurrent lanes must overlap in the trace");
    }

    #[test]
    fn adaptive_policy_converges_to_profitable_lanes() {
        // Two shape classes -> every saturated round plans two launches
        // that underfill the device: static 2-lane rounds beat serial by
        // >20% (`concurrent_lanes_beat_serial_...` above). The adaptive
        // controller, fed only simulated signals, must discover that on
        // its own: strictly beat plain space-time and land within reach of
        // the best static setting despite its 1-lane warmup rounds.
        let w = two_class_workloads(4, 30);
        let serial = run(&cfg(Policy::SpaceTime { max_batch: 64 }), &w);
        let static2 = run(&cfg(Policy::SpaceTimeLanes { max_batch: 64, lanes: 2 }), &w);
        let adaptive = run(
            &cfg(Policy::SpaceTimeAdaptive { max_batch: 64, max_lanes: 4 }).with_trace(),
            &w,
        );
        assert_eq!(adaptive.total_completed(), serial.total_completed());
        assert!(
            (adaptive.total_flops() - serial.total_flops()).abs() < 1e-3,
            "adaptive control must not lose work"
        );
        assert!(
            adaptive.throughput_flops() > serial.throughput_flops() * 1.05,
            "adaptive {} must beat serial {} (controller never engaged?)",
            adaptive.throughput_flops(),
            serial.throughput_flops()
        );
        assert!(
            adaptive.throughput_flops() > static2.throughput_flops() * 0.8,
            "adaptive {} should approach the best static {}",
            adaptive.throughput_flops(),
            static2.throughput_flops()
        );
        // Ground truth in the trace: later rounds actually overlap lanes,
        // and the lane cap is respected.
        let max_lane = adaptive.trace.events.iter().map(|e| e.lane).max().unwrap();
        assert!(max_lane >= 1, "controller never left serial rounds");
        assert!(max_lane < 4, "lane cap violated");
    }

    #[test]
    fn adaptive_with_max_lanes_one_matches_plain_space_time() {
        let w = two_class_workloads(3, 8);
        let plain = run(&cfg(Policy::SpaceTime { max_batch: 64 }), &w);
        let capped =
            run(&cfg(Policy::SpaceTimeAdaptive { max_batch: 64, max_lanes: 1 }), &w);
        assert!((plain.makespan - capped.makespan).abs() < 1e-12 * plain.makespan);
        assert_eq!(plain.kernel_launches, capped.kernel_launches);
        assert_eq!(plain.total_completed(), capped.total_completed());
        assert_eq!(plain.rounds, capped.rounds);
    }

    #[test]
    fn adaptive_stays_serial_for_single_class_rounds() {
        // One shape class -> one launch per round: nothing to overlap, so
        // the controller must keep serial rounds (identical makespan).
        let w = sgemm_workloads(8, 10, GemmShape::RESNET18_CONV2_2);
        let plain = run(&cfg(Policy::SpaceTime { max_batch: 64 }), &w);
        let adaptive =
            run(&cfg(Policy::SpaceTimeAdaptive { max_batch: 64, max_lanes: 4 }), &w);
        assert!((plain.makespan - adaptive.makespan).abs() < 1e-9 * plain.makespan);
    }

    #[test]
    fn space_time_completions_are_round_tagged() {
        // Every completion event carries the planning round it belongs
        // to: tags ascend with time, every round in [0, rounds) appears,
        // and a saturated 10-tenant/4-iteration run spans several rounds.
        let w = sgemm_workloads(10, 4, GemmShape::SQUARE_256);
        let r = run(&cfg(Policy::SpaceTime { max_batch: 64 }).with_trace(), &w);
        assert!(r.rounds >= 4, "expected one planning round per iteration");
        assert_eq!(r.trace.rounds(), r.rounds);
        let mut last_start = 0.0f64;
        let mut seen = vec![false; r.rounds as usize];
        let mut events = r.trace.events.clone();
        events.sort_by(|a, b| a.t_start.partial_cmp(&b.t_start).unwrap());
        let mut last_round = 0u64;
        for e in &events {
            assert!(e.round < r.rounds);
            assert!(e.round >= last_round, "round tags must ascend with time");
            assert!(e.t_start >= last_start);
            seen[e.round as usize] = true;
            last_round = e.round;
            last_start = e.t_start;
        }
        assert!(seen.iter().all(|&s| s), "every round must carry a launch");
        // The quantum-structured baseline is tagged too.
        let tm = run(&cfg(Policy::TimeMux).with_trace(), &w);
        assert_eq!(tm.trace.rounds(), tm.rounds);
        assert!(tm.rounds > 0);
    }

    #[test]
    fn mps_anomaly_creates_straggler_gap() {
        let w = sgemm_workloads(9, 30, GemmShape::RESNET18_CONV2_2);
        let r = run(&cfg(Policy::SpaceMuxMps { anomaly_seed: 11 }), &w);
        assert!(
            r.straggler_gap() > 0.02,
            "MPS run should show a visible straggler gap, got {}",
            r.straggler_gap()
        );
        // Explicit streams have no anomaly; gap should be (near) zero.
        let r2 = run(&cfg(Policy::SpaceMuxStreams), &w);
        assert!(r2.straggler_gap() < r.straggler_gap());
    }

    #[test]
    fn flops_conserved_across_policies() {
        let w = sgemm_workloads(5, 7, GemmShape::SQUARE_256);
        let expected: f64 = w.iter().map(|x| x.total_flops()).sum();
        for policy in [
            Policy::Exclusive,
            Policy::TimeMux,
            Policy::SpaceMuxMps { anomaly_seed: 5 },
            Policy::SpaceMuxStreams,
            Policy::SpaceTime { max_batch: 8 },
            Policy::SpaceTimeLanes { max_batch: 8, lanes: 3 },
        ] {
            let r = run(&cfg(policy), &w);
            assert!(
                (r.total_flops() - expected).abs() < 1e-3,
                "FLOPs must be conserved"
            );
        }
    }

    #[test]
    fn trace_capture_respects_flag() {
        let w = sgemm_workloads(2, 2, GemmShape::SQUARE_256);
        let with = run(&cfg(Policy::TimeMux).with_trace(), &w);
        let without = run(&cfg(Policy::TimeMux), &w);
        assert!(with.trace.launches() > 0);
        assert_eq!(without.trace.launches(), 0);
    }

    #[test]
    fn empty_and_zero_iteration_workloads() {
        let w = vec![
            TenantWorkload::new(vec![KernelDesc::sgemm(0, GemmShape::SQUARE_256)], 0),
            TenantWorkload::new(vec![], 3),
            TenantWorkload::new(vec![KernelDesc::sgemm(2, GemmShape::SQUARE_256)], 2),
        ];
        for policy in [
            Policy::Exclusive,
            Policy::TimeMux,
            Policy::SpaceMuxMps { anomaly_seed: 1 },
            Policy::SpaceMuxStreams,
            Policy::SpaceTime { max_batch: 8 },
        ] {
            let r = run(&cfg(policy.clone()), &w);
            assert_eq!(r.total_completed(), 2, "{policy:?}");
            assert_eq!(r.tenants[0].completed, 0);
            assert_eq!(r.tenants[1].completed, 0);
            assert_eq!(r.tenants[2].completed, 2);
        }
    }

    #[test]
    fn multi_layer_inference_latency_spans_all_layers() {
        // A 3-kernel inference must have latency >= sum of its own kernels.
        let kernels: Vec<KernelDesc> = (0..3)
            .map(|_| KernelDesc::sgemm(0, GemmShape::SQUARE_256))
            .collect();
        let w = vec![TenantWorkload::new(kernels.clone(), 4)];
        let spec = DeviceSpec::v100();
        let per_kernel: f64 = kernels
            .iter()
            .map(|k| kernel_service_time(&spec, k, &CostCtx::exclusive(&spec)))
            .sum();
        let r = run(&cfg(Policy::SpaceMuxStreams), &w);
        for &l in &r.tenants[0].latencies {
            assert!(l >= per_kernel * 0.99, "latency {l} < service {per_kernel}");
        }
    }
}
