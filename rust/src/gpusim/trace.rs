//! Schedule trace capture and rendering (paper Figure 6).
//!
//! Every simulated kernel execution appends a [`TraceEvent`]; the renderer
//! draws an ASCII Gantt chart of device occupancy per lane (stream/context),
//! which is the reproduction of the paper's Figure 6 illustration.

use crate::gpusim::kernel::TenantId;

#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub t_start: f64,
    pub t_end: f64,
    /// Lane: stream id / context id — one row in the Gantt chart.
    pub lane: usize,
    pub tenant: TenantId,
    pub label: String,
    /// SMs occupied during execution.
    pub sms: f64,
    /// Problems fused into this launch (R for a super-kernel).
    pub fused: u32,
    /// Scheduling round this completion belongs to: the planning round
    /// for space-time policies, the quantum index for time-mux, the
    /// inference iteration for exclusive devices, 0 for the event-driven
    /// space-mux path (which has no round structure). Mirrors the
    /// coordinator driver's round-tagged completions, so pipelined-round
    /// attribution can be checked against simulator ground truth.
    pub round: u64,
}

/// An append-only trace. Capture can be disabled for long simulations.
#[derive(Debug, Default, Clone)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    pub enabled: bool,
}

impl Trace {
    pub fn new(enabled: bool) -> Self {
        Self {
            events: Vec::new(),
            enabled,
        }
    }

    pub fn record(&mut self, ev: TraceEvent) {
        if self.enabled {
            debug_assert!(ev.t_end >= ev.t_start, "trace event must not be reversed");
            self.events.push(ev);
        }
    }

    /// Record an event built lazily: the closure — and any label clone or
    /// allocation inside it — runs only when capture is enabled. This is
    /// the hot-path entry point: with tracing off, a simulation that only
    /// calls `record_with` performs zero per-event allocations (the
    /// `events` vector never even allocates).
    pub fn record_with(&mut self, f: impl FnOnce() -> TraceEvent) {
        if self.enabled {
            let ev = f();
            debug_assert!(ev.t_end >= ev.t_start, "trace event must not be reversed");
            self.events.push(ev);
        }
    }

    /// Pre-size the event buffer for `n` additional events. No-op (and no
    /// allocation) when capture is disabled.
    pub fn reserve(&mut self, n: usize) {
        if self.enabled {
            self.events.reserve(n);
        }
    }

    pub fn makespan(&self) -> f64 {
        self.events.iter().map(|e| e.t_end).fold(0.0, f64::max)
    }

    /// Number of kernel launches recorded.
    pub fn launches(&self) -> usize {
        self.events.len()
    }

    /// Render an ASCII Gantt chart with `width` columns covering the span.
    /// Each lane is one row; cells show the tenant id (or '#' for fused
    /// super-kernels spanning many tenants).
    pub fn render_gantt(&self, width: usize) -> String {
        if self.events.is_empty() {
            return String::from("(empty trace)\n");
        }
        let span = self.makespan();
        if span <= 0.0 {
            return String::from("(zero-length trace)\n");
        }
        let nlanes = self.events.iter().map(|e| e.lane).max().unwrap() + 1;
        let mut rows = vec![vec![b'.'; width]; nlanes];
        for ev in &self.events {
            let c0 = ((ev.t_start / span) * width as f64).floor() as usize;
            let c1 = (((ev.t_end / span) * width as f64).ceil() as usize).min(width);
            let glyph = if ev.fused > 1 {
                b'#'
            } else {
                // Tenant id modulo 10 for readability.
                b'0' + (ev.tenant % 10) as u8
            };
            for c in c0..c1.max(c0 + 1).min(width) {
                rows[ev.lane][c] = glyph;
            }
        }
        let mut out = String::new();
        out.push_str(&format!(
            "time → ({} total, {} launches)\n",
            crate::util::bench::fmt_secs(span),
            self.events.len()
        ));
        for (i, row) in rows.iter().enumerate() {
            out.push_str(&format!("lane {i:>2} |"));
            out.push_str(std::str::from_utf8(row).unwrap());
            out.push_str("|\n");
        }
        out
    }

    /// CSV dump (t_start, t_end, lane, tenant, label, sms, fused, round).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("t_start,t_end,lane,tenant,label,sms,fused,round\n");
        for e in &self.events {
            out.push_str(&format!(
                "{:.9},{:.9},{},{},{},{:.1},{},{}\n",
                e.t_start,
                e.t_end,
                e.lane,
                e.tenant,
                e.label.replace(',', ";"),
                e.sms,
                e.fused,
                e.round
            ));
        }
        out
    }

    /// Highest round tag recorded plus one (0 for an empty trace). NB: a
    /// non-empty trace from a round-less policy (space-mux tags every
    /// event 0) reports 1 here while `SimReport::rounds` stays 0 — use
    /// the report for "how many rounds ran", this for "how far the tags
    /// span".
    pub fn rounds(&self) -> u64 {
        self.events.iter().map(|e| e.round + 1).max().unwrap_or(0)
    }

    /// Device occupancy integral: Σ (duration · sms) / (makespan · total_sms).
    pub fn occupancy(&self, total_sms: f64) -> f64 {
        let span = self.makespan();
        if span <= 0.0 {
            return 0.0;
        }
        let busy: f64 = self
            .events
            .iter()
            .map(|e| (e.t_end - e.t_start) * e.sms)
            .sum();
        busy / (span * total_sms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t0: f64, t1: f64, lane: usize, tenant: usize, fused: u32) -> TraceEvent {
        TraceEvent {
            t_start: t0,
            t_end: t1,
            lane,
            tenant,
            label: "k".into(),
            sms: 80.0,
            fused,
            round: 0,
        }
    }

    #[test]
    fn disabled_trace_records_nothing() {
        let mut t = Trace::new(false);
        t.record(ev(0.0, 1.0, 0, 0, 1));
        assert_eq!(t.launches(), 0);
    }

    #[test]
    fn record_with_skips_the_closure_when_disabled() {
        let mut t = Trace::new(false);
        let mut built = 0u32;
        t.record_with(|| {
            built += 1;
            ev(0.0, 1.0, 0, 0, 1)
        });
        t.reserve(1024);
        assert_eq!(built, 0, "closure must not run while disabled");
        assert_eq!(t.launches(), 0);
        assert_eq!(t.events.capacity(), 0, "disabled trace must not allocate");

        let mut on = Trace::new(true);
        on.reserve(2);
        let cap = on.events.capacity();
        assert!(cap >= 2);
        on.record_with(|| {
            built += 1;
            ev(0.0, 1.0, 0, 0, 1)
        });
        assert_eq!(built, 1);
        assert_eq!(on.launches(), 1);
        assert_eq!(on.events.capacity(), cap, "reserve must pre-size the push");
    }

    #[test]
    fn makespan_is_max_end() {
        let mut t = Trace::new(true);
        t.record(ev(0.0, 1.0, 0, 0, 1));
        t.record(ev(0.5, 3.0, 1, 1, 1));
        assert_eq!(t.makespan(), 3.0);
    }

    #[test]
    fn gantt_renders_lanes_and_fused_glyphs() {
        let mut t = Trace::new(true);
        t.record(ev(0.0, 1.0, 0, 3, 1));
        t.record(ev(1.0, 2.0, 1, 7, 4));
        let g = t.render_gantt(40);
        assert!(g.contains("lane  0"));
        assert!(g.contains("lane  1"));
        assert!(g.contains('3'));
        assert!(g.contains('#'));
    }

    #[test]
    fn occupancy_full_device() {
        let mut t = Trace::new(true);
        t.record(ev(0.0, 2.0, 0, 0, 1)); // 80 SMs for whole span
        assert!((t.occupancy(80.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn occupancy_half_device() {
        let mut t = Trace::new(true);
        t.record(TraceEvent {
            t_start: 0.0,
            t_end: 2.0,
            lane: 0,
            tenant: 0,
            label: "k".into(),
            sms: 40.0,
            fused: 1,
            round: 0,
        });
        assert!((t.occupancy(80.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut t = Trace::new(true);
        t.record(ev(0.0, 1.0, 0, 0, 1));
        let csv = t.to_csv();
        assert!(csv.starts_with("t_start,"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn empty_trace_renders_placeholder() {
        let t = Trace::new(true);
        assert!(t.render_gantt(10).contains("empty"));
        assert_eq!(t.occupancy(80.0), 0.0);
        assert_eq!(t.rounds(), 0);
    }

    #[test]
    fn round_tags_ride_events_and_csv() {
        let mut t = Trace::new(true);
        let mut e0 = ev(0.0, 1.0, 0, 0, 1);
        e0.round = 0;
        let mut e1 = ev(1.0, 2.0, 1, 1, 2);
        e1.round = 3;
        t.record(e0);
        t.record(e1);
        assert_eq!(t.rounds(), 4, "max tag + 1");
        let csv = t.to_csv();
        assert!(csv.starts_with("t_start,") && csv.contains(",round"));
        assert!(csv.lines().nth(2).unwrap().ends_with(",3"));
    }
}
