//! The pre-vectorization reference engine, kept verbatim as the
//! bit-for-bit oracle for [`crate::gpusim::engine`].
//!
//! Every policy loop in this module is the original per-event
//! implementation: per-round `BTreeMap` grouping with owned `String` keys,
//! `Vec<KernelDesc>` chasing, unconditional [`TraceEvent`] construction
//! (label clones even with tracing disabled), and fresh scratch `Vec`s per
//! event. That is exactly the allocation profile the struct-of-arrays
//! engine removes — and exactly why this copy must stay: the equivalence
//! property test and `benches/fig13_sim_scale.rs` replay both engines on
//! identical workloads and require *bitwise* identical reports, so any
//! semantic drift in the fast path is caught against this one.
//!
//! Reachable at runtime via [`crate::gpusim::Engine::Legacy`]
//! (`stgpu simulate --engine legacy`), not only under `#[cfg(test)]`: the
//! fig13 bench measures the speedup ratio between the two engines in a
//! release build.

use crate::gpusim::cost::{kernel_service_time, CostCtx};
use crate::gpusim::engine::{
    LaneMode, Policy, SimConfig, SimReport, TenantReport, TenantWorkload, ADAPTIVE_DWELL_ROUNDS,
};
use crate::gpusim::kernel::{KernelDesc, TenantId};
use crate::gpusim::mps::MpsAnomaly;
use crate::gpusim::trace::{Trace, TraceEvent};

/// Run `workloads` under `cfg` on the reference engine. Dispatch mirrors
/// [`crate::gpusim::engine::run`] exactly.
pub(crate) fn run_legacy(cfg: &SimConfig, workloads: &[TenantWorkload]) -> SimReport {
    match &cfg.policy {
        Policy::Exclusive => run_exclusive(cfg, workloads),
        Policy::TimeMux => run_time_mux(cfg, workloads),
        Policy::SpaceMuxMps { anomaly_seed } => {
            let anomaly = MpsAnomaly::new(*anomaly_seed, workloads.len());
            run_space_mux(cfg, workloads, &anomaly, true, cfg.spec.mps_launch_overhead_s)
        }
        Policy::SpaceMuxStreams => {
            let anomaly = MpsAnomaly::none(workloads.len());
            run_space_mux(
                cfg,
                workloads,
                &anomaly,
                false,
                cfg.spec.dispatch_serialization_s,
            )
        }
        Policy::SpaceTime { max_batch } => {
            run_space_time(cfg, workloads, *max_batch, LaneMode::Static(1))
        }
        Policy::SpaceTimeLanes { max_batch, lanes } => {
            run_space_time(cfg, workloads, *max_batch, LaneMode::Static((*lanes).max(1)))
        }
        Policy::SpaceTimeAdaptive { max_batch, max_lanes } => run_space_time(
            cfg,
            workloads,
            *max_batch,
            LaneMode::Adaptive { max_lanes: (*max_lanes).max(1) },
        ),
    }
}

// ---------------------------------------------------------------------------
// Exclusive: each tenant on a private device.
// ---------------------------------------------------------------------------

fn run_exclusive(cfg: &SimConfig, workloads: &[TenantWorkload]) -> SimReport {
    let spec = &cfg.spec;
    let mut report = SimReport {
        trace: Trace::new(cfg.capture_trace),
        ..Default::default()
    };
    let ctx = CostCtx::exclusive(spec);
    let mut makespan: f64 = 0.0;
    for (tid, w) in workloads.iter().enumerate() {
        let mut t = 0.0;
        let mut tr = TenantReport::default();
        if w.kernels.is_empty() {
            report.tenants.push(tr);
            continue;
        }
        for iter in 0..w.iterations {
            let start = t;
            for k in &w.kernels {
                let dur = spec.launch_overhead_s + kernel_service_time(spec, k, &ctx);
                report.trace.record(TraceEvent {
                    t_start: t,
                    t_end: t + dur,
                    lane: tid,
                    tenant: tid,
                    label: k.name.clone(),
                    sms: (k.ctas as f64).min(spec.sms as f64),
                    fused: k.fused,
                    round: iter as u64,
                });
                t += dur;
                report.kernel_launches += 1;
                tr.flops += k.flops;
            }
            tr.latencies.push(t - start);
            tr.completed += 1;
        }
        makespan = makespan.max(t);
        // Exclusive "rounds" are inference iterations (events are tagged
        // with theirs); the run spans the longest tenant's count.
        if !w.kernels.is_empty() {
            report.rounds = report.rounds.max(w.iterations as u64);
        }
        report.tenants.push(tr);
    }
    report.makespan = makespan;
    report
}

// ---------------------------------------------------------------------------
// Time multiplexing: one resident context, round-robin quanta.
// ---------------------------------------------------------------------------

fn run_time_mux(cfg: &SimConfig, workloads: &[TenantWorkload]) -> SimReport {
    let spec = &cfg.spec;
    let n = workloads.len();
    let mut report = SimReport {
        tenants: vec![TenantReport::default(); n],
        trace: Trace::new(cfg.capture_trace),
        ..Default::default()
    };
    // Per-tenant cursor. `inf_start` is the *submission* time of the
    // in-flight inference: in the saturated closed loop every tenant's
    // first inference is submitted at t=0 and each completion immediately
    // submits the next, so waiting for other tenants' quanta is part of the
    // measured latency (this is what makes time-mux latency grow linearly
    // with the tenant count — paper Fig 3).
    struct Cursor {
        iter: u32,
        kidx: usize,
        inf_start: f64,
    }
    let mut cursors: Vec<Cursor> = workloads
        .iter()
        .map(|_| Cursor {
            iter: 0,
            kidx: 0,
            inf_start: 0.0,
        })
        .collect();
    let ctx = CostCtx::exclusive(spec);
    let mut clock = 0.0f64;
    let pending = |c: &Cursor, w: &TenantWorkload| c.iter < w.iterations && !w.kernels.is_empty();
    let mut current = 0usize;
    // Number of tenants with work left.
    let mut live: usize = workloads
        .iter()
        .zip(cursors.iter())
        .filter(|(w, c)| pending(c, w))
        .count();
    let multi = live > 1;
    let mut quantum: u64 = 0;
    while live > 0 {
        // Find next tenant with pending work.
        let mut hops = 0;
        while !pending(&cursors[current], &workloads[current]) {
            current = (current + 1) % n;
            hops += 1;
            debug_assert!(hops <= n, "live>0 but no pending tenant");
        }
        // Context switch cost applies when more than one context exists.
        if multi {
            clock += spec.ctx_switch_s;
        }
        // Run this tenant's kernels until the quantum is spent (kernels are
        // non-preemptible: always finish the one we started).
        let mut quantum_left = spec.timeslice_quantum_s;
        let w = &workloads[current];
        while quantum_left > 0.0 && pending(&cursors[current], w) {
            let c = &mut cursors[current];
            let k = &w.kernels[c.kidx];
            let dur = spec.launch_overhead_s + kernel_service_time(spec, k, &ctx);
            report.trace.record(TraceEvent {
                t_start: clock,
                t_end: clock + dur,
                lane: current,
                tenant: current,
                label: k.name.clone(),
                sms: (k.ctas as f64).min(spec.sms as f64),
                fused: k.fused,
                round: quantum,
            });
            clock += dur;
            quantum_left -= dur;
            report.kernel_launches += 1;
            report.tenants[current].flops += k.flops;
            c.kidx += 1;
            if c.kidx == w.kernels.len() {
                c.kidx = 0;
                c.iter += 1;
                report.tenants[current].latencies.push(clock - c.inf_start);
                report.tenants[current].completed += 1;
                c.inf_start = clock; // next inference submitted immediately
                if c.iter == w.iterations {
                    live -= 1;
                }
            }
        }
        quantum += 1;
        current = (current + 1) % n;
    }
    report.rounds = quantum;
    report.makespan = clock;
    report
}

// ---------------------------------------------------------------------------
// Spatial multiplexing: event-driven processor sharing over SMs.
// ---------------------------------------------------------------------------

fn run_space_mux(
    cfg: &SimConfig,
    workloads: &[TenantWorkload],
    anomaly: &MpsAnomaly,
    static_bw: bool,
    per_kernel_overhead: f64,
) -> SimReport {
    let spec = &cfg.spec;
    let n = workloads.len();
    let mut report = SimReport {
        tenants: vec![TenantReport::default(); n],
        trace: Trace::new(cfg.capture_trace),
        ..Default::default()
    };

    /// In-flight kernel state: a dispatch phase of absolute duration followed
    /// by an execution phase tracked as a remaining fraction (the service
    /// time is re-evaluated whenever the resident set changes).
    struct Flight {
        tenant: TenantId,
        dispatch_left: f64,
        exec_frac_left: f64,
        started_at: f64,
    }
    struct Cursor {
        iter: u32,
        kidx: usize,
        /// Submission time of the in-flight inference (saturated closed
        /// loop: t=0, then each completion submits the next).
        inf_start: f64,
        done: bool,
    }

    let mut cursors: Vec<Cursor> = workloads
        .iter()
        .map(|w| Cursor {
            iter: 0,
            kidx: 0,
            inf_start: 0.0,
            done: w.iterations == 0 || w.kernels.is_empty(),
        })
        .collect();

    let max_resident = spec.max_concurrent_kernels as usize;
    let mut resident: Vec<Flight> = Vec::with_capacity(max_resident);
    // Tenants whose next kernel is ready but waiting for a hardware queue.
    let mut waiting: std::collections::VecDeque<TenantId> = (0..n)
        .filter(|&t| !cursors[t].done)
        .collect();
    let mut clock = 0.0f64;

    // Admit from the waiting queue into the resident set.
    fn admit(
        resident: &mut Vec<Flight>,
        waiting: &mut std::collections::VecDeque<TenantId>,
        cursors: &mut [Cursor],
        clock: f64,
        max_resident: usize,
        overhead: f64,
    ) {
        while resident.len() < max_resident {
            let Some(t) = waiting.pop_front() else { break };
            debug_assert!(!cursors[t].done);
            resident.push(Flight {
                tenant: t,
                dispatch_left: overhead,
                exec_frac_left: 1.0,
                started_at: clock,
            });
        }
    }

    admit(
        &mut resident,
        &mut waiting,
        &mut cursors,
        clock,
        max_resident,
        per_kernel_overhead,
    );

    while !resident.is_empty() {
        let conc = resident.len() as u32;
        // SM allocation proportional to CTA demand, capped by each kernel's
        // own CTA count; one redistribution round picks up the slack.
        let total_ctas: f64 = resident
            .iter()
            .map(|f| workloads[f.tenant].kernels[cursors[f.tenant].kidx].ctas as f64)
            .sum();
        let total_sms = spec.sms as f64;
        let mut allocs: Vec<f64> = resident
            .iter()
            .map(|f| {
                let ctas = workloads[f.tenant].kernels[cursors[f.tenant].kidx].ctas as f64;
                (total_sms * ctas / total_ctas.max(1.0)).min(ctas)
            })
            .collect();
        let used: f64 = allocs.iter().sum();
        let slack = (total_sms - used).max(0.0);
        if slack > 0.0 {
            // Give slack to kernels that can still use it (ctas > alloc).
            let extra_demand: f64 = resident
                .iter()
                .zip(allocs.iter())
                .map(|(f, &a)| {
                    (workloads[f.tenant].kernels[cursors[f.tenant].kidx].ctas as f64 - a).max(0.0)
                })
                .sum();
            if extra_demand > 0.0 {
                for (i, f) in resident.iter().enumerate() {
                    let ctas = workloads[f.tenant].kernels[cursors[f.tenant].kidx].ctas as f64;
                    let want = (ctas - allocs[i]).max(0.0);
                    allocs[i] += slack * want / extra_demand;
                    allocs[i] = allocs[i].min(ctas);
                }
            }
        }

        // Time to next completion.
        let mut dt = f64::INFINITY;
        let mut times: Vec<f64> = Vec::with_capacity(resident.len());
        for (i, f) in resident.iter().enumerate() {
            let k = &workloads[f.tenant].kernels[cursors[f.tenant].kidx];
            let t_exec = kernel_service_time(
                spec,
                k,
                &CostCtx {
                    sms: allocs[i].max(1e-9),
                    concurrency: conc,
                    static_bw_partition: static_bw,
                },
            ) * anomaly.multiplier(f.tenant);
            times.push(t_exec);
            let remaining = f.dispatch_left + f.exec_frac_left * t_exec;
            dt = dt.min(remaining);
        }
        debug_assert!(dt.is_finite() && dt >= 0.0);

        clock += dt;
        // Advance all flights by dt; collect completions.
        let mut completed_idx: Vec<usize> = Vec::new();
        for (i, f) in resident.iter_mut().enumerate() {
            let mut step = dt;
            if f.dispatch_left > 0.0 {
                let d = f.dispatch_left.min(step);
                f.dispatch_left -= d;
                step -= d;
            }
            if step > 0.0 && f.exec_frac_left > 0.0 {
                f.exec_frac_left -= step / times[i];
            }
            if f.dispatch_left <= 1e-15 && f.exec_frac_left <= 1e-9 {
                completed_idx.push(i);
            }
        }

        // Process completions (highest index first so removals are stable).
        for &i in completed_idx.iter().rev() {
            let f = resident.swap_remove(i);
            let t = f.tenant;
            let c = &mut cursors[t];
            let k = &workloads[t].kernels[c.kidx];
            report.kernel_launches += 1;
            report.tenants[t].flops += k.flops;
            report.trace.record(TraceEvent {
                t_start: f.started_at,
                t_end: clock,
                lane: t % max_resident.max(1),
                tenant: t,
                label: k.name.clone(),
                sms: (k.ctas as f64).min(spec.sms as f64 / (conc as f64)),
                fused: k.fused,
                // Event-driven path: no round structure to tag.
                round: 0,
            });
            c.kidx += 1;
            if c.kidx == workloads[t].kernels.len() {
                c.kidx = 0;
                c.iter += 1;
                report.tenants[t].latencies.push(clock - c.inf_start);
                report.tenants[t].completed += 1;
                c.inf_start = clock;
                if c.iter == workloads[t].iterations {
                    c.done = true;
                }
            }
            if !c.done {
                waiting.push_back(t);
            }
        }
        admit(
            &mut resident,
            &mut waiting,
            &mut cursors,
            clock,
            max_resident,
            per_kernel_overhead,
        );
    }
    report.makespan = clock;
    report
}

// ---------------------------------------------------------------------------
// Space-time: per-round inter-model super-kernel batching (the contribution),
// optionally spread over concurrent spatial lanes — statically or under the
// adaptive controller.
// ---------------------------------------------------------------------------

fn run_space_time(
    cfg: &SimConfig,
    workloads: &[TenantWorkload],
    max_batch: u32,
    mode: LaneMode,
) -> SimReport {
    use crate::coordinator::controller::{
        AdaptiveController, ControlSignals, ControllerParams, Decision, SignalTracker,
    };
    assert!(max_batch >= 1);
    let spec = &cfg.spec;
    let (static_lanes, mut controller) = match mode {
        LaneMode::Static(l) => (l.max(1), None),
        LaneMode::Adaptive { max_lanes } => (
            1,
            Some(AdaptiveController::new(
                ControllerParams {
                    max_lanes: max_lanes as usize,
                    max_depth: 1, // the simulator has no pipeline to deepen
                    dwell_rounds: ADAPTIVE_DWELL_ROUNDS,
                    improvement: 0.05,
                    slo_target: 0.99,
                },
                Decision { lanes: 1, depth: 1 },
            )),
        ),
    };
    let mut tracker = SignalTracker::default();
    let n = workloads.len();
    let mut report = SimReport {
        tenants: vec![TenantReport::default(); n],
        trace: Trace::new(cfg.capture_trace),
        ..Default::default()
    };
    struct Cursor {
        iter: u32,
        kidx: usize,
        inf_start: f64,
        done: bool,
    }
    let mut cursors: Vec<Cursor> = workloads
        .iter()
        .map(|w| Cursor {
            iter: 0,
            kidx: 0,
            inf_start: 0.0,
            done: w.iterations == 0 || w.kernels.is_empty(),
        })
        .collect();
    let mut clock = 0.0f64;
    let mut round: u64 = 0;

    loop {
        // Heads of all live tenants this round.
        let live: Vec<TenantId> = (0..n).filter(|&t| !cursors[t].done).collect();
        if live.is_empty() {
            break;
        }
        // Group heads: GEMMs by shape class, others by kernel name (the
        // same-architecture assumption of paper §2 makes names align).
        use std::collections::BTreeMap;
        #[derive(PartialEq, Eq, PartialOrd, Ord)]
        enum GroupKey {
            Gemm(u32, u32, u32),
            Other(String),
        }
        let mut groups: BTreeMap<GroupKey, Vec<TenantId>> = BTreeMap::new();
        for &t in &live {
            let k = &workloads[t].kernels[cursors[t].kidx];
            let key = match k.shape {
                Some(s) => GroupKey::Gemm(s.m, s.n, s.k),
                None => GroupKey::Other(k.name.clone()),
            };
            groups.entry(key).or_default().push(t);
        }

        // Plan the round's launches: each group in chunks of max_batch.
        let mut launches: Vec<(KernelDesc, Vec<TenantId>)> = Vec::new();
        for (key, members) in groups {
            for chunk in members.chunks(max_batch as usize) {
                let kernels: Vec<KernelDesc> = chunk
                    .iter()
                    .map(|&t| workloads[t].kernels[cursors[t].kidx].clone())
                    .collect();
                let merged = match key {
                    GroupKey::Gemm(..) if kernels.len() > 1 => {
                        KernelDesc::superkernel(&kernels)
                    }
                    _ => {
                        // Non-GEMM heads (or a singleton): pack grids by
                        // concatenation — same cost structure, summed work.
                        let mut k = kernels[0].clone();
                        for extra in &kernels[1..] {
                            k.flops += extra.flops;
                            k.bytes += extra.bytes;
                            k.ctas += extra.ctas;
                            k.fused += extra.fused;
                        }
                        k
                    }
                };
                launches.push((merged, chunk.to_vec()));
            }
        }

        // Adaptive mode: at each dwell boundary hand the controller the
        // tracker's signals — round width, exclusive-time launch duration
        // EWMA, and the measured overlapped/solo stretch (seeded from the
        // device spec before any overlapped round ran) — and take its
        // decision for this round. Static mode uses the configured count.
        let lanes_now = match &mut controller {
            Some(ctl) => {
                if ctl.tick() {
                    let max_lanes = ctl.params().max_lanes;
                    let stretch =
                        tracker.stretch_table(max_lanes, |n| spec.lane_stretch(n as u32));
                    let signals = ControlSignals {
                        backlog: 0, // closed loop: the heads ARE the demand
                        arrival_rate: 0.0,
                        launches_per_round: tracker.launches_per_round(),
                        requests_per_round: tracker.requests_per_round(),
                        mean_launch_s: tracker.mean_launch_s(),
                        plan_s: 0.0,
                        stretch,
                        slo_attainment: None,
                        min_slo_s: 0.0,
                        steal_rate: 0.0,
                    };
                    ctl.decide(&signals);
                }
                ctl.decision().lanes as u32
            }
            None => static_lanes,
        };
        // Assign launches to spatial lanes: greedy makespan balancing by
        // exclusive-time weight, in plan order (mirrors the coordinator's
        // lane assignment). With one lane (or one launch) this degenerates
        // to the classic serial round.
        let active = (lanes_now as usize).min(launches.len()).max(1);
        let mut lane_of: Vec<usize> = Vec::with_capacity(launches.len());
        let mut lane_load = vec![0.0f64; active];
        let excl = CostCtx::exclusive(spec);
        for (merged, _) in &launches {
            let w = spec.launch_overhead_s + kernel_service_time(spec, merged, &excl);
            let lane = (0..active)
                .min_by(|&a, &b| lane_load[a].partial_cmp(&lane_load[b]).unwrap())
                .unwrap();
            lane_of.push(lane);
            lane_load[lane] += w;
        }
        // Concurrently-resident lanes each execute on a static SM fraction
        // with the deterministic interference derate — planned spatial
        // sharing, not the MPS anomaly lottery (the explicit interference
        // model replaces the anomaly table on this path).
        let ctx = CostCtx {
            sms: spec.sms as f64 / active as f64,
            concurrency: active as u32,
            static_bw_partition: false,
        };
        let mut lane_cursor = vec![0.0f64; active];
        let mut problems_this_round = 0usize;
        for (i, (merged, chunk)) in launches.iter().enumerate() {
            let lane = lane_of[i];
            let dur = spec.launch_overhead_s + kernel_service_time(spec, merged, &ctx);
            if controller.is_some() {
                // Simulated measurement feedback: solo-equivalent launch
                // duration, and (overlapped rounds only) the ground-truth
                // stretch the controller's utility model calibrates from.
                let solo = spec.launch_overhead_s + kernel_service_time(spec, merged, &excl);
                tracker.observe_launch(solo);
                if active > 1 {
                    tracker.observe_stretch(active, dur / solo.max(1e-12));
                }
                problems_this_round += chunk.len();
            }
            let t_start = clock + lane_cursor[lane];
            let t_end = t_start + dur;
            lane_cursor[lane] += dur;
            report.trace.record(TraceEvent {
                t_start,
                t_end,
                lane,
                tenant: if chunk.len() == 1 { chunk[0] } else { usize::MAX },
                label: merged.name.clone(),
                sms: (merged.ctas as f64).min(ctx.sms),
                fused: merged.fused,
                // Round-tagged completion: every member of this round's
                // plan carries the planning round it belongs to, matching
                // the coordinator driver's pipelined attribution.
                round,
            });
            report.kernel_launches += 1;
            if merged.fused > 1 {
                report.superkernel_launches += 1;
                report.fused_problems += merged.fused as u64;
            }
            for &t in chunk {
                let k = &workloads[t].kernels[cursors[t].kidx];
                report.tenants[t].flops += k.flops;
            }
            // Members complete at their launch's end on its lane.
            for &t in chunk {
                let c = &mut cursors[t];
                c.kidx += 1;
                if c.kidx == workloads[t].kernels.len() {
                    c.kidx = 0;
                    c.iter += 1;
                    report.tenants[t].latencies.push(t_end - c.inf_start);
                    report.tenants[t].completed += 1;
                    c.inf_start = t_end;
                    if c.iter == workloads[t].iterations {
                        c.done = true;
                    }
                }
            }
        }
        if controller.is_some() {
            tracker.observe_round(launches.len(), problems_this_round, 0.0);
        }
        // The round barrier: the next round plans once every lane drains.
        clock += lane_cursor.iter().cloned().fold(0.0, f64::max);
        round += 1;
    }
    report.rounds = round;
    report.makespan = clock;
    report
}
