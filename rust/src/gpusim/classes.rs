//! Interned workload/kernel classes for the vectorized engine.
//!
//! The legacy round loop re-derived every kernel's fusion-group key on
//! every round — and the [`WorkloadClass::Other`] variant carries an owned
//! `String`, so each derivation *cloned the kernel name* (one heap
//! allocation per live tenant per round, plus another per `class_key()`
//! call on the pool placement path). This module fixes both:
//!
//! * [`ClassTable`] interns every distinct group key once at simulation
//!   setup and hands the hot loop a dense [`ClassId`] per kernel. Rank
//!   order is **exactly** the legacy `BTreeMap` iteration order (GEMM
//!   `(m, n, k)` tuples ascending, then non-GEMM names in byte order), so
//!   the vectorized engine can bucket heads by integer rank and still
//!   replay launches bit-for-bit in the legacy plan order.
//! * [`WorkloadClassRef`] is the borrowed, `Copy` view of a workload's
//!   placement class: what [`crate::gpusim::pool`] feeds the generic
//!   [`crate::coordinator::placement::place`] without cloning names.

use crate::gpusim::engine::{TenantWorkload, WorkloadClass};
use crate::gpusim::kernel::KernelDesc;

/// Borrowed placement class of a workload — the allocation-free twin of
/// [`WorkloadClass`] (same variant order, so `Ord` agrees and placement
/// groups identically).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum WorkloadClassRef<'a> {
    /// Head kernel is a batchable GEMM of this (M, N, K).
    Gemm(u32, u32, u32),
    /// Head kernel is a non-GEMM kernel, keyed by (borrowed) name.
    Other(&'a str),
    /// No kernels.
    Empty,
}

impl WorkloadClassRef<'_> {
    /// Owned copy, for callers that need to store the class.
    pub fn to_class(self) -> WorkloadClass {
        match self {
            WorkloadClassRef::Gemm(m, n, k) => WorkloadClass::Gemm(m, n, k),
            WorkloadClassRef::Other(name) => WorkloadClass::Other(name.to_string()),
            WorkloadClassRef::Empty => WorkloadClass::Empty,
        }
    }
}

/// Dense interned id of a kernel's fusion-group class. The numeric value
/// is the class's *rank* in legacy group order: iterating ranks ascending
/// visits groups exactly as the legacy `BTreeMap<GroupKey, _>` did.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ClassId(pub u32);

impl ClassId {
    #[inline]
    pub fn rank(self) -> usize {
        self.0 as usize
    }
}

/// Owned interning key. Variant and field order mirror the legacy round
/// loop's `GroupKey`, so the derived `Ord` reproduces its `BTreeMap`
/// iteration order exactly.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub(crate) enum ClassKey {
    Gemm(u32, u32, u32),
    Other(String),
}

impl ClassKey {
    pub(crate) fn of(k: &KernelDesc) -> Self {
        match k.shape {
            Some(s) => ClassKey::Gemm(s.m, s.n, s.k),
            None => ClassKey::Other(k.name.clone()),
        }
    }
}

/// The interner: every distinct fusion-group class across a workload set,
/// sorted so that `ClassId(i)` is the i-th class in legacy group order.
/// Built once at simulation setup; the hot loop never touches a string.
#[derive(Debug, Clone)]
pub struct ClassTable {
    keys: Vec<ClassKey>,
}

impl ClassTable {
    /// Intern every kernel's group class across `workloads`. Returns the
    /// table plus, per tenant, the `ClassId` of each of its kernels (the
    /// class of kernel `i` of tenant `t` is `ids[t][i]`).
    pub(crate) fn build(workloads: &[TenantWorkload]) -> (Self, Vec<Vec<ClassId>>) {
        use std::collections::BTreeMap;
        let mut ranks: BTreeMap<ClassKey, u32> = BTreeMap::new();
        for w in workloads {
            for k in &w.kernels {
                ranks.entry(ClassKey::of(k)).or_insert(0);
            }
        }
        // BTreeMap iteration is ascending, which IS the rank order.
        for (rank, (_, slot)) in ranks.iter_mut().enumerate() {
            *slot = rank as u32;
        }
        let ids = workloads
            .iter()
            .map(|w| {
                w.kernels
                    .iter()
                    .map(|k| ClassId(ranks[&ClassKey::of(k)]))
                    .collect()
            })
            .collect();
        let keys = ranks.into_keys().collect();
        (Self { keys }, ids)
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub(crate) fn key(&self, id: ClassId) -> &ClassKey {
        &self.keys[id.rank()]
    }

    /// Whether this class is a batchable GEMM (super-kernel eligible).
    pub fn is_gemm(&self, id: ClassId) -> bool {
        matches!(self.keys[id.rank()], ClassKey::Gemm(..))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::kernel::GemmShape;

    fn gemm(t: usize, m: u32, n: u32, k: u32) -> KernelDesc {
        KernelDesc::sgemm(t, GemmShape::new(m, n, k))
    }

    #[test]
    fn rank_order_matches_legacy_btreemap_group_order() {
        // Deliberately interleaved insertion order; ranks must come out
        // GEMM-tuples-ascending first, then names in byte order.
        let w = vec![
            TenantWorkload::new(vec![KernelDesc::other(0, "relu", 1e6, 1e3, 4)], 1),
            TenantWorkload::new(vec![gemm(1, 256, 128, 64)], 1),
            TenantWorkload::new(vec![KernelDesc::other(2, "attn", 1e6, 1e3, 4)], 1),
            TenantWorkload::new(vec![gemm(3, 128, 256, 64)], 1),
            TenantWorkload::new(vec![gemm(4, 128, 256, 32)], 1),
        ];
        let (table, ids) = ClassTable::build(&w);
        assert_eq!(table.len(), 5);
        let rank_of = |t: usize| ids[t][0].rank();
        // Gemm(128,256,32) < Gemm(128,256,64) < Gemm(256,128,64)
        //   < Other("attn") < Other("relu").
        assert_eq!(rank_of(4), 0);
        assert_eq!(rank_of(3), 1);
        assert_eq!(rank_of(1), 2);
        assert_eq!(rank_of(2), 3);
        assert_eq!(rank_of(0), 4);
        assert!(table.is_gemm(ids[1][0]));
        assert!(!table.is_gemm(ids[0][0]));
    }

    #[test]
    fn duplicate_classes_intern_to_one_id() {
        let w = vec![
            TenantWorkload::new(vec![gemm(0, 64, 64, 64)], 1),
            TenantWorkload::new(vec![gemm(1, 64, 64, 64)], 1),
            TenantWorkload::new(
                vec![gemm(2, 64, 64, 64), KernelDesc::other(2, "ln", 1.0, 1.0, 1)],
                1,
            ),
        ];
        let (table, ids) = ClassTable::build(&w);
        assert_eq!(table.len(), 2);
        assert_eq!(ids[0][0], ids[1][0]);
        assert_eq!(ids[0][0], ids[2][0]);
        assert_ne!(ids[2][0], ids[2][1]);
    }

    #[test]
    fn class_ref_borrows_the_kernel_name_without_cloning() {
        let w = TenantWorkload::new(vec![KernelDesc::other(0, "fused_ln", 1.0, 1.0, 1)], 1);
        match w.class_ref() {
            WorkloadClassRef::Other(name) => {
                // Same allocation as the kernel's own name — no clone.
                assert_eq!(name.as_ptr(), w.kernels[0].name.as_ptr());
            }
            other => panic!("expected Other, got {other:?}"),
        }
    }

    #[test]
    fn class_ref_agrees_with_owned_class_key() {
        let cases = vec![
            TenantWorkload::new(vec![gemm(0, 8, 9, 10)], 1),
            TenantWorkload::new(vec![KernelDesc::other(1, "k", 1.0, 1.0, 1)], 1),
            TenantWorkload::new(vec![], 1),
        ];
        for w in &cases {
            assert_eq!(w.class_ref().to_class(), w.class_key());
        }
    }
}
