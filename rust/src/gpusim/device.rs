//! Device specifications for the discrete-event GPU simulator.
//!
//! The simulator replaces the paper's AWS p3 V100 testbed (see DESIGN.md §1).
//! All constants are grounded in the V100 datasheet where public, and
//! calibrated against the paper's measured ratios where not (each calibrated
//! constant is marked `CALIBRATED`).

/// A simulated accelerator (or CPU, for the Figure 1 baseline).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Number of streaming multiprocessors.
    pub sms: u32,
    /// Peak FP32 FLOP/s per SM. V100: 14 TFLOP/s over 80 SMs = 175 GFLOP/s.
    pub flops_per_sm: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Device memory capacity, bytes.
    pub hbm_capacity: u64,
    /// Hardware queue count (Hyper-Q): max kernels co-resident on device.
    pub max_concurrent_kernels: u32,
    /// Kernel launch overhead for an in-context launch, seconds.
    pub launch_overhead_s: f64,
    /// Extra per-kernel overhead when dispatched through the MPS proxy,
    /// seconds. CALIBRATED: MPS adds client→server IPC on the launch path.
    pub mps_launch_overhead_s: f64,
    /// Device-wide grid dispatch serialization: two kernels cannot begin
    /// occupying SMs in the same instant, seconds per dispatch.
    pub dispatch_serialization_s: f64,
    /// CUDA context switch penalty (time multiplexing), seconds.
    pub ctx_switch_s: f64,
    /// Time-multiplexing scheduler quantum, seconds.
    pub timeslice_quantum_s: f64,
    /// Fixed device-memory overhead per CUDA context (runtime + workspace).
    /// CALIBRATED so 18 ResNet-50 replicas exhaust 16 GB (paper Fig 5).
    pub per_context_mem: u64,
    /// cuDNN/cuBLAS per-process workspace reservation, bytes.
    pub per_process_workspace: u64,
    /// Occupancy half-saturation constant: per-SM efficiency is
    /// `cpsm / (cpsm + occupancy_half_sat)` where cpsm = CTAs per used SM.
    /// CALIBRATED: one 64x64 SGEMM CTA per SM reaches ~14% of per-SM peak
    /// (matches a ~35 us cuBLAS conv2_2-shaped SGEMM on V100).
    pub occupancy_half_sat: f64,
    /// Inter-stream interference: concurrent kernels from distinct clients
    /// derate each other's per-SM efficiency by `1/(1 + coeff*(n-1))`.
    /// CALIBRATED against the paper's space-only-vs-batched gap (Table 1).
    pub interference_coeff: f64,
    /// Number of SMs whose combined demand saturates HBM bandwidth: a kernel
    /// occupying s SMs can draw at most `min(1, s/bw_saturation_sms)` of BW.
    pub bw_saturation_sms: f64,
}

impl DeviceSpec {
    /// NVIDIA V100 (SXM2 16 GB) — the paper's testbed GPU.
    pub fn v100() -> Self {
        Self {
            name: "V100-SXM2-16GB",
            sms: 80,
            flops_per_sm: 175e9, // 14 TFLOP/s FP32 / 80 SMs
            hbm_bw: 900e9,
            hbm_capacity: 16 * (1 << 30),
            max_concurrent_kernels: 32, // Hyper-Q hardware queues
            launch_overhead_s: 5e-6,
            mps_launch_overhead_s: 9e-6,
            dispatch_serialization_s: 2e-6,
            ctx_switch_s: 100e-6,
            timeslice_quantum_s: 1e-3,
            // CALIBRATED (Fig 5): CUDA context + cuDNN workspace sized so a
            // ResNet-50 replica (batch 26: 91 MB weights + 167 MB acts)
            // costs ~955 MB per process — the paper's 16 GB wall lands at
            // exactly 18 process-per-replica deployments while a shared
            // process reaches 60+.
            per_context_mem: 400 * (1 << 20),
            per_process_workspace: 250 * (1 << 20),
            occupancy_half_sat: 6.0,
            interference_coeff: 0.08,
            bw_saturation_sms: 20.0,
        }
    }

    /// A Skylake-class server CPU, used only for the Figure 1 CPU-latency
    /// trend. Modeled as a single "SM".
    ///
    /// CALIBRATED: `flops_per_sm` is the *effective* serving-path FP32
    /// throughput of a latency-oriented (small-batch, framework-overhead-
    /// dominated) CPU inference stack circa 2018, set so SENet's ~20.7
    /// GFLOP forward pass lands at the paper's quoted ~4.1 s (Figure 1) —
    /// not the socket's peak.
    pub fn cpu_xeon() -> Self {
        Self {
            name: "Xeon-8175M (CPU, serving-path)",
            sms: 1,
            flops_per_sm: 5.1e9,
            hbm_bw: 20e9,
            hbm_capacity: 256 * (1 << 30),
            max_concurrent_kernels: 1,
            launch_overhead_s: 1e-6, // function call, not a device launch
            mps_launch_overhead_s: 0.0,
            dispatch_serialization_s: 0.0,
            ctx_switch_s: 10e-6,
            timeslice_quantum_s: 10e-3,
            per_context_mem: 0,
            per_process_workspace: 0,
            occupancy_half_sat: 0.05, // CPUs do not need CTA oversubscription
            interference_coeff: 0.0,
            bw_saturation_sms: 1.0,
        }
    }

    /// Peak FP32 throughput of the whole device.
    pub fn peak_flops(&self) -> f64 {
        self.sms as f64 * self.flops_per_sm
    }

    /// Occupancy efficiency for `cpsm` CTAs per used SM (saturating curve).
    pub fn occupancy_eff(&self, cpsm: f64) -> f64 {
        debug_assert!(cpsm >= 0.0);
        if cpsm <= 0.0 {
            return 0.0;
        }
        cpsm / (cpsm + self.occupancy_half_sat)
    }

    /// Interference derate with `n` concurrently-resident kernels from
    /// distinct clients (n >= 1).
    pub fn interference(&self, n: u32) -> f64 {
        1.0 / (1.0 + self.interference_coeff * (n.saturating_sub(1)) as f64)
    }

    /// Latency *stretch* of a kernel co-resident with `lanes - 1` other
    /// spatial lanes: the reciprocal of [`DeviceSpec::interference`], i.e.
    /// `1 + coeff * (lanes - 1)`. This is the analytic seed of the
    /// coordinator cost model's co-location interference term (the
    /// measured-EWMA correction lives in
    /// [`crate::coordinator::costmodel::CostModel`]).
    pub fn lane_stretch(&self, lanes: u32) -> f64 {
        1.0 + self.interference_coeff * (lanes.saturating_sub(1)) as f64
    }

    /// Fraction of HBM bandwidth reachable from `sms` SMs.
    pub fn bw_fraction(&self, sms: f64) -> f64 {
        (sms / self.bw_saturation_sms).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v100_peak_matches_datasheet() {
        let d = DeviceSpec::v100();
        assert!((d.peak_flops() - 14e12).abs() < 1e9);
        assert_eq!(d.sms, 80);
        assert_eq!(d.hbm_capacity, 16 * (1 << 30));
    }

    #[test]
    fn occupancy_curve_saturates() {
        let d = DeviceSpec::v100();
        assert!(d.occupancy_eff(1.0) < 0.2);
        assert!(d.occupancy_eff(6.0) == 0.5);
        assert!(d.occupancy_eff(64.0) > 0.9);
        assert!(d.occupancy_eff(0.0) == 0.0);
        // monotone
        let mut last = 0.0;
        for i in 1..100 {
            let e = d.occupancy_eff(i as f64);
            assert!(e > last);
            last = e;
        }
    }

    #[test]
    fn interference_decreases_with_concurrency() {
        let d = DeviceSpec::v100();
        assert_eq!(d.interference(1), 1.0);
        assert!(d.interference(2) < 1.0);
        assert!(d.interference(32) < d.interference(2));
    }

    #[test]
    fn bw_fraction_caps_at_one() {
        let d = DeviceSpec::v100();
        assert!(d.bw_fraction(5.0) < 1.0);
        assert_eq!(d.bw_fraction(40.0), 1.0);
    }

    #[test]
    fn lane_stretch_is_inverse_interference() {
        let d = DeviceSpec::v100();
        assert_eq!(d.lane_stretch(1), 1.0);
        for n in 1..8u32 {
            let prod = d.lane_stretch(n) * d.interference(n);
            assert!((prod - 1.0).abs() < 1e-12, "lanes {n}: {prod}");
        }
        assert!(d.lane_stretch(4) > d.lane_stretch(2));
    }
}
