//! Kernel descriptors: the unit of work the simulator schedules.
//!
//! A kernel is characterized by its FLOP count, HBM traffic, and CTA
//! (threadblock) count — everything the roofline cost model in
//! [`crate::gpusim::cost`] needs. GEMM kernels additionally carry their
//! problem shape so the space-time batcher can merge same-shape work.

/// Identifies a tenant (a deployed model instance) inside the simulator.
pub type TenantId = usize;

/// An SGEMM problem shape: C[M,N] += A[M,K] · B[K,N], fp32.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmShape {
    pub m: u32,
    pub n: u32,
    pub k: u32,
}

impl GemmShape {
    pub const fn new(m: u32, n: u32, k: u32) -> Self {
        Self { m, n, k }
    }

    /// The paper's three Table 1 shapes.
    pub const RNN_MATVEC: GemmShape = GemmShape::new(512, 1, 512);
    pub const RESNET18_CONV2_2: GemmShape = GemmShape::new(256, 128, 1152);
    pub const SQUARE_256: GemmShape = GemmShape::new(256, 256, 256);

    /// Multiply-accumulate FLOPs (2·M·N·K).
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }

    /// Minimum HBM traffic in bytes (read A, B; write C), fp32.
    pub fn min_bytes(&self) -> f64 {
        4.0 * (self.m as f64 * self.k as f64
            + self.k as f64 * self.n as f64
            + self.m as f64 * self.n as f64)
    }

    /// Tile selection heuristic mirroring cuBLAS behaviour qualitatively:
    /// large tiles for large outputs, split-K parallelism when the output is
    /// small but K is deep, narrow tiles for matrix-vector shapes.
    /// Returns (tile_m, tile_n, split_k).
    pub fn tiling(&self) -> (u32, u32, u32) {
        if self.n <= 4 {
            // GEMV-like: one CTA per 64 rows, no N tiling.
            return (64, self.n.max(1), 1);
        }
        let tm = if self.m >= 128 { 128 } else { 64.min(self.m.next_power_of_two()) };
        let tn = if self.n >= 128 { 64 } else { 32.min(self.n.next_power_of_two()) };
        // Split-K: aim for at least 32 CTAs so a lone kernel can spread over
        // a meaningful fraction of the machine (cuBLAS splitK heuristic).
        let base_ctas = self.m.div_ceil(tm) * self.n.div_ceil(tn);
        let split_k = if base_ctas < 32 && self.k >= 256 {
            (32 / base_ctas).clamp(1, 8)
        } else {
            1
        };
        (tm, tn, split_k)
    }

    /// CTA count under the tiling heuristic.
    pub fn ctas(&self) -> u32 {
        let (tm, tn, split_k) = self.tiling();
        self.m.div_ceil(tm) * self.n.div_ceil(tn) * split_k
    }

    /// Actual HBM traffic under the tiling (tiles re-read panels of A and B
    /// once per opposing tile; split-K adds a partial-sum reduction pass).
    pub fn tiled_bytes(&self) -> f64 {
        let (tm, tn, split_k) = self.tiling();
        let m = self.m as f64;
        let n = self.n as f64;
        let k = self.k as f64;
        let n_tiles = (self.n.div_ceil(tn)) as f64;
        let m_tiles = (self.m.div_ceil(tm)) as f64;
        let a_traffic = m * k * n_tiles;
        let b_traffic = k * n * m_tiles;
        let c_traffic = m * n * if split_k > 1 { 2.0 * split_k as f64 } else { 1.0 };
        4.0 * (a_traffic + b_traffic + c_traffic)
    }

    /// Shape-class key used by the dynamic batcher: problems with identical
    /// (M, N, K) may be merged into one batched super-kernel (the
    /// `cublasSgemmBatched` constraint; variable-size batching is emulated by
    /// bucketing + padding at the coordinator level).
    pub fn class_key(&self) -> (u32, u32, u32) {
        (self.m, self.n, self.k)
    }
}

/// A single schedulable kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelDesc {
    /// Human-readable label (layer name or "sgemm MxNxK").
    pub name: String,
    pub tenant: TenantId,
    pub flops: f64,
    /// HBM bytes moved.
    pub bytes: f64,
    /// Threadblock count.
    pub ctas: u32,
    /// GEMM shape when this kernel is a (batchable) matrix multiply.
    pub shape: Option<GemmShape>,
    /// Problems already fused inside this kernel (1 for a plain kernel,
    /// R for a super-kernel formed by the space-time batcher).
    pub fused: u32,
}

impl KernelDesc {
    /// A plain SGEMM kernel for one tenant.
    pub fn sgemm(tenant: TenantId, shape: GemmShape) -> Self {
        Self {
            name: format!("sgemm {}x{}x{}", shape.m, shape.n, shape.k),
            tenant,
            flops: shape.flops(),
            bytes: shape.tiled_bytes(),
            ctas: shape.ctas(),
            shape: Some(shape),
            fused: 1,
        }
    }

    /// A non-GEMM kernel (elementwise, pooling, normalization...).
    pub fn other(tenant: TenantId, name: impl Into<String>, flops: f64, bytes: f64, ctas: u32) -> Self {
        Self {
            name: name.into(),
            tenant,
            flops,
            bytes,
            ctas: ctas.max(1),
            shape: None,
            fused: 1,
        }
    }

    /// Merge `R` same-shape GEMM kernels into one batched super-kernel.
    /// Panics if shapes differ (the batcher guarantees shape-class purity —
    /// enforced again here as a defense-in-depth invariant).
    pub fn superkernel(kernels: &[KernelDesc]) -> Self {
        assert!(!kernels.is_empty(), "superkernel of zero kernels");
        let shape = kernels[0]
            .shape
            .expect("superkernel requires GEMM kernels");
        for k in kernels {
            assert_eq!(
                k.shape,
                Some(shape),
                "superkernel requires identical shapes (batcher invariant)"
            );
        }
        let r: u32 = kernels.iter().map(|k| k.fused).sum();
        Self {
            name: format!("sgemm_batched R={r} {}x{}x{}", shape.m, shape.n, shape.k),
            tenant: usize::MAX, // belongs to no single tenant
            flops: kernels.iter().map(|k| k.flops).sum(),
            bytes: kernels.iter().map(|k| k.bytes).sum(),
            ctas: kernels.iter().map(|k| k.ctas).sum(),
            shape: Some(shape),
            fused: r,
        }
    }

    /// Arithmetic intensity (FLOP per byte).
    pub fn intensity(&self) -> f64 {
        if self.bytes <= 0.0 {
            f64::INFINITY
        } else {
            self.flops / self.bytes
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_shapes_flops() {
        assert_eq!(GemmShape::RNN_MATVEC.flops(), 2.0 * 512.0 * 512.0);
        assert_eq!(
            GemmShape::RESNET18_CONV2_2.flops(),
            2.0 * 256.0 * 128.0 * 1152.0
        );
        assert_eq!(GemmShape::SQUARE_256.flops(), 2.0 * 256.0f64.powi(3));
    }

    #[test]
    fn matvec_uses_gemv_tiling() {
        let (tm, tn, sk) = GemmShape::RNN_MATVEC.tiling();
        assert_eq!((tm, tn, sk), (64, 1, 1));
        assert_eq!(GemmShape::RNN_MATVEC.ctas(), 8);
    }

    #[test]
    fn conv_shape_gets_split_k() {
        let shape = GemmShape::RESNET18_CONV2_2;
        let (_, _, sk) = shape.tiling();
        assert!(sk > 1, "deep-K small-output shape should split K");
        assert!(shape.ctas() >= 32, "split-K should give >= 32 CTAs");
    }

    #[test]
    fn tiled_bytes_at_least_min_bytes() {
        for shape in [
            GemmShape::RNN_MATVEC,
            GemmShape::RESNET18_CONV2_2,
            GemmShape::SQUARE_256,
            GemmShape::new(1024, 1024, 1024),
        ] {
            assert!(
                shape.tiled_bytes() >= shape.min_bytes() * 0.99,
                "tiling can only add traffic: {shape:?}"
            );
        }
    }

    #[test]
    fn superkernel_sums_work() {
        let a = KernelDesc::sgemm(0, GemmShape::SQUARE_256);
        let b = KernelDesc::sgemm(1, GemmShape::SQUARE_256);
        let s = KernelDesc::superkernel(&[a.clone(), b.clone()]);
        assert_eq!(s.fused, 2);
        assert_eq!(s.flops, a.flops + b.flops);
        assert_eq!(s.ctas, a.ctas + b.ctas);
    }

    #[test]
    #[should_panic(expected = "identical shapes")]
    fn superkernel_rejects_mixed_shapes() {
        let a = KernelDesc::sgemm(0, GemmShape::SQUARE_256);
        let b = KernelDesc::sgemm(1, GemmShape::RNN_MATVEC);
        let _ = KernelDesc::superkernel(&[a, b]);
    }

    #[test]
    fn superkernel_of_superkernels_accumulates_fused() {
        let a = KernelDesc::sgemm(0, GemmShape::SQUARE_256);
        let b = KernelDesc::sgemm(1, GemmShape::SQUARE_256);
        let s1 = KernelDesc::superkernel(&[a, b]);
        let c = KernelDesc::sgemm(2, GemmShape::SQUARE_256);
        let s2 = KernelDesc::superkernel(&[s1, c]);
        assert_eq!(s2.fused, 3);
    }

    #[test]
    fn intensity_is_flops_over_bytes() {
        let k = KernelDesc::other(0, "relu", 100.0, 400.0, 1);
        assert_eq!(k.intensity(), 0.25);
    }
}
