//! V100-class GPU simulator — the substrate substituting for the paper's AWS
//! p3 testbed (DESIGN.md §1, §6).
//!
//! * [`device`] — device specifications (V100, CPU baseline) and the
//!   calibrated efficiency curves.
//! * [`kernel`] — kernel descriptors and GEMM shape/tiling math.
//! * [`cost`] — the roofline cost model.
//! * [`engine`] — the discrete-event executor for each multiplexing policy.
//! * [`mps`] — the MPS straggler-anomaly model (paper Figure 4).
//! * [`memory`] — device memory accounting (paper Figure 5) + allocator.
//! * [`trace`] — schedule trace capture and Gantt rendering (Figure 6).
//! * [`pool`] — multi-device pools: shard tenants across N devices
//!   (least-loaded, class-affine) and aggregate throughput; a multi-node
//!   mode stacks the same sharding one level up for cluster benches.
//! * [`classes`] — interned fusion-group classes for the vectorized engine.
//!
//! [`engine`] ships two implementations behind one [`run`] entry point: the
//! default struct-of-arrays engine and the original per-event reference
//! engine (module `engine_legacy`), selectable via [`Engine`] — kept as the
//! bit-for-bit oracle for the equivalence tests and the fig13 bench.

pub mod classes;
pub mod cost;
pub mod device;
pub mod engine;
pub(crate) mod engine_legacy;
pub mod kernel;
pub mod memory;
pub mod mps;
pub mod pool;
pub mod trace;

pub use classes::{ClassId, ClassTable, WorkloadClassRef};
pub use device::DeviceSpec;
pub use engine::{run, Engine, Policy, SimConfig, SimReport, TenantWorkload, WorkloadClass};
pub use kernel::{GemmShape, KernelDesc, TenantId};
pub use pool::{run_multinode, run_pool, MultiNodeReport, PoolReport};
pub use trace::{Trace, TraceEvent};
