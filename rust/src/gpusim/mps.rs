//! Model of the MPS / Hyper-Q scheduling anomalies the paper observes.
//!
//! Paper §3.2 and Figure 4: under NVIDIA MPS, per-tenant latency is
//! *unpredictable* — up to a 25 % gap between the fastest tenant and the
//! slowest straggler, and the discrepancy is "exacerbated when an odd number
//! of processes runs concurrently". This module encodes that observation as
//! an explicit, seeded noise process (DESIGN.md §6: this is a model of the
//! paper's measured anomaly, not discovered physics). Keeping it
//! deterministic per (seed, tenant-count, tenant) makes every figure
//! reproducible bit-for-bit.

use crate::gpusim::kernel::TenantId;
use crate::util::prng::Rng;

/// Per-tenant service-time multipliers under MPS spatial multiplexing.
#[derive(Debug, Clone)]
pub struct MpsAnomaly {
    multipliers: Vec<f64>,
}

impl MpsAnomaly {
    /// Maximum straggler stretch the paper reports (~25 %).
    pub const MAX_GAP: f64 = 0.25;

    /// Build the multiplier table for `n_tenants` under seed `seed`.
    ///
    /// Mechanism: Hyper-Q maps client queues onto hardware queues; an
    /// unlucky mapping leaves one (occasionally two) client(s) sharing a
    /// dispatch path, stretching their kernels. Odd client counts make the
    /// unlucky mapping more likely and more severe (paper's observation).
    pub fn new(seed: u64, n_tenants: usize) -> Self {
        let mut rng = Rng::new(seed ^ (n_tenants as u64).wrapping_mul(0xA5A5_5A5A_DEAD_BEEF));
        let mut multipliers = vec![1.0; n_tenants];
        if n_tenants < 2 {
            return Self { multipliers };
        }
        let odd = n_tenants % 2 == 1;
        // Base jitter: every tenant wobbles a little (±2 %).
        for m in multipliers.iter_mut() {
            *m = 1.0 + rng.gen_f64_range(-0.02, 0.02);
        }
        // Straggler(s): one always; a second one sometimes when odd.
        // Victims are sampled WITHOUT replacement: drawing the same tenant
        // twice would make the second stretch a no-op `max` and silently
        // produce one straggler where two were intended (regression test
        // `two_stragglers_hit_distinct_victims`).
        let n_stragglers = if odd && rng.gen_bool(0.6) { 2 } else { 1 };
        let severity_hi = if odd { 0.23 } else { 0.15 };
        let mut victims: Vec<usize> = Vec::with_capacity(2);
        for _ in 0..n_stragglers.min(n_tenants) {
            let victim = loop {
                let v = rng.gen_range(n_tenants as u64) as usize;
                if !victims.contains(&v) {
                    break v;
                }
            };
            victims.push(victim);
            let stretch = 1.0 + rng.gen_f64_range(severity_hi * 0.6, severity_hi);
            multipliers[victim] = multipliers[victim].max(stretch);
        }
        Self { multipliers }
    }

    /// No anomaly (used by the explicit-streams path and by the space-time
    /// scheduler, which bypasses per-client hardware queues entirely).
    pub fn none(n_tenants: usize) -> Self {
        Self {
            multipliers: vec![1.0; n_tenants],
        }
    }

    #[inline]
    pub fn multiplier(&self, tenant: TenantId) -> f64 {
        self.multipliers.get(tenant).copied().unwrap_or(1.0)
    }

    /// Index of the slowest tenant.
    pub fn worst(&self) -> Option<TenantId> {
        self.multipliers
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
    }

    /// Fastest-vs-slowest gap (e.g. 0.25 for a 25 % straggler).
    pub fn gap(&self) -> f64 {
        let min = self.multipliers.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = self.multipliers.iter().cloned().fold(0.0, f64::max);
        if min <= 0.0 || !min.is_finite() {
            0.0
        } else {
            max / min - 1.0
        }
    }

    pub fn n(&self) -> usize {
        self.multipliers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = MpsAnomaly::new(1, 8);
        let b = MpsAnomaly::new(1, 8);
        let c = MpsAnomaly::new(2, 8);
        assert_eq!(a.multipliers, b.multipliers);
        assert_ne!(a.multipliers, c.multipliers);
    }

    #[test]
    fn single_tenant_has_no_anomaly() {
        let a = MpsAnomaly::new(3, 1);
        assert_eq!(a.multiplier(0), 1.0);
        assert_eq!(a.gap(), 0.0);
    }

    #[test]
    fn gap_bounded_by_paper_observation() {
        for seed in 0..50 {
            for n in 2..16 {
                let a = MpsAnomaly::new(seed, n);
                assert!(
                    a.gap() <= MpsAnomaly::MAX_GAP * 1.1,
                    "gap {} exceeds paper bound for n={n}",
                    a.gap()
                );
                assert!(a.gap() > 0.0, "multi-tenant MPS always has some gap");
            }
        }
    }

    #[test]
    fn odd_counts_are_worse_on_average() {
        let avg_gap = |n: usize| -> f64 {
            (0..200)
                .map(|seed| MpsAnomaly::new(seed, n).gap())
                .sum::<f64>()
                / 200.0
        };
        // Compare neighbouring even/odd tenant counts.
        assert!(
            avg_gap(7) > avg_gap(8),
            "odd tenant counts should straggle harder (paper Fig 4)"
        );
        assert!(avg_gap(5) > avg_gap(6));
    }

    #[test]
    fn none_is_identity() {
        let a = MpsAnomaly::none(5);
        for t in 0..5 {
            assert_eq!(a.multiplier(t), 1.0);
        }
        assert_eq!(a.gap(), 0.0);
    }

    #[test]
    fn worst_returns_straggler() {
        let a = MpsAnomaly::new(7, 9);
        let w = a.worst().unwrap();
        assert!(a.multiplier(w) >= 1.05);
    }

    #[test]
    fn two_stragglers_hit_distinct_victims() {
        // Regression: victims were drawn WITH replacement, so a two-
        // straggler draw could pick the same tenant twice — the second
        // stretch was a no-op `max` and the table showed one straggler
        // where two were intended. Post-fix, a two-straggler draw always
        // yields two distinct stretched tenants, so across many seeds the
        // observed two-straggler fraction matches the 60% draw probability
        // for odd counts instead of being deflated by collisions
        // (for n = 5, collisions deflated it to ~48%).
        let stragglers = |seed: u64, n: usize| -> usize {
            // Base jitter tops out at 1.02; the smallest straggler stretch
            // is 1 + 0.6 * severity_hi >= 1.09, so 1.05 separates them.
            MpsAnomaly::new(seed, n)
                .multipliers
                .iter()
                .filter(|&&m| m > 1.05)
                .count()
        };
        let seeds = 1000u64;
        let mut twos = 0usize;
        for seed in 0..seeds {
            let k = stragglers(seed, 5);
            assert!(
                (1..=2).contains(&k),
                "odd count must produce 1 or 2 stragglers, got {k} (seed {seed})"
            );
            if k == 2 {
                twos += 1;
            }
        }
        let frac = twos as f64 / seeds as f64;
        assert!(
            (0.55..=0.65).contains(&frac),
            "two-straggler fraction {frac} should match the 0.6 draw \
             probability (collisions would deflate it to ~0.48)"
        );
        // Even counts never draw a second straggler.
        for seed in 0..200 {
            assert_eq!(stragglers(seed, 6), 1, "seed {seed}");
        }
    }
}
