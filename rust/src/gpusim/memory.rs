//! Device-memory model (paper Figure 5: the 16 GB memory wall).
//!
//! Three deployment shapes differ in what they replicate per tenant:
//!
//! * **Separate processes** (time multiplexing, implicit MPS): every replica
//!   carries a full CUDA context + framework workspace + weights +
//!   activations. The paper observes the 16 GB wall at 18 ResNet-50
//!   replicas.
//! * **Single process, explicit streams**: one context and one workspace are
//!   shared; each replica adds only weights + activations, so 60+ ResNet-50
//!   replicas fit (paper: "at least 60").

use crate::gpusim::device::DeviceSpec;

/// How replicas share (or don't share) process-level allocations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeploymentShape {
    /// One process (one CUDA context) per replica — time mux & implicit MPS.
    ProcessPerReplica,
    /// One process hosting all replicas on distinct CUDA streams.
    SharedProcessStreams,
}

/// Static memory requirements of one model replica.
#[derive(Debug, Clone, Copy)]
pub struct ModelFootprint {
    /// Weight bytes (fp32).
    pub weights: u64,
    /// Peak activation bytes for one in-flight inference at the batch size
    /// used in serving.
    pub activations: u64,
}

/// Accounting result for a proposed deployment.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryPlan {
    pub replicas: u32,
    pub context_bytes: u64,
    pub workspace_bytes: u64,
    pub weight_bytes: u64,
    pub activation_bytes: u64,
    pub total_bytes: u64,
    pub capacity: u64,
    pub fits: bool,
}

impl MemoryPlan {
    pub fn utilization(&self) -> f64 {
        self.total_bytes as f64 / self.capacity as f64
    }
}

/// Compute the memory plan for `replicas` copies of `model` deployed in
/// `shape` on `spec`.
pub fn plan(
    spec: &DeviceSpec,
    shape: DeploymentShape,
    model: &ModelFootprint,
    replicas: u32,
) -> MemoryPlan {
    let r = replicas as u64;
    let (context_bytes, workspace_bytes) = match shape {
        DeploymentShape::ProcessPerReplica => {
            (spec.per_context_mem * r, spec.per_process_workspace * r)
        }
        DeploymentShape::SharedProcessStreams => {
            (spec.per_context_mem, spec.per_process_workspace)
        }
    };
    let weight_bytes = model.weights * r;
    let activation_bytes = model.activations * r;
    let total = context_bytes + workspace_bytes + weight_bytes + activation_bytes;
    MemoryPlan {
        replicas,
        context_bytes,
        workspace_bytes,
        weight_bytes,
        activation_bytes,
        total_bytes: total,
        capacity: spec.hbm_capacity,
        fits: total <= spec.hbm_capacity,
    }
}

/// Largest replica count that fits device memory (the Figure 5 wall).
pub fn max_replicas(spec: &DeviceSpec, shape: DeploymentShape, model: &ModelFootprint) -> u32 {
    let mut n = 0u32;
    loop {
        let p = plan(spec, shape, model, n + 1);
        if !p.fits {
            return n;
        }
        n += 1;
        if n > 100_000 {
            return n; // effectively unbounded; avoid infinite loop
        }
    }
}

/// A simple first-fit device allocator used by the runtime-facing simulator
/// paths (weights pinned at tenant registration, activations transient).
#[derive(Debug)]
pub struct DeviceAllocator {
    capacity: u64,
    used: u64,
    allocations: std::collections::BTreeMap<u64, (u64, String)>,
    next_id: u64,
    peak: u64,
}

#[derive(Debug, PartialEq)]
pub enum AllocError {
    OutOfMemory {
        requested: u64,
        free: u64,
        capacity: u64,
    },
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::OutOfMemory { requested, free, capacity } => write!(
                f,
                "out of device memory: requested {requested} bytes, {free} free of {capacity}"
            ),
        }
    }
}

impl std::error::Error for AllocError {}

impl DeviceAllocator {
    pub fn new(capacity: u64) -> Self {
        Self {
            capacity,
            used: 0,
            allocations: std::collections::BTreeMap::new(),
            next_id: 1,
            peak: 0,
        }
    }

    pub fn alloc(&mut self, bytes: u64, label: impl Into<String>) -> Result<u64, AllocError> {
        if self.used + bytes > self.capacity {
            return Err(AllocError::OutOfMemory {
                requested: bytes,
                free: self.capacity - self.used,
                capacity: self.capacity,
            });
        }
        let id = self.next_id;
        self.next_id += 1;
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        self.allocations.insert(id, (bytes, label.into()));
        Ok(id)
    }

    pub fn free(&mut self, id: u64) -> bool {
        if let Some((bytes, _)) = self.allocations.remove(&id) {
            self.used -= bytes;
            true
        } else {
            false
        }
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn free_bytes(&self) -> u64 {
        self.capacity - self.used
    }

    pub fn peak(&self) -> u64 {
        self.peak
    }

    pub fn live_allocations(&self) -> usize {
        self.allocations.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::DeviceSpec;

    /// ResNet-50 fp32: 25.6 M params ≈ 102 MB of weights; serving-batch
    /// activations ≈ 64 MB (batch ~8 at 224²).
    fn resnet50() -> ModelFootprint {
        ModelFootprint {
            weights: 102 * (1 << 20),
            activations: 64 * (1 << 20),
        }
    }

    #[test]
    fn process_per_replica_hits_wall_near_18() {
        // Paper Fig 5: "most approaches hit a 16 GB memory wall at 18
        // replicas".
        let spec = DeviceSpec::v100();
        let n = max_replicas(&spec, DeploymentShape::ProcessPerReplica, &resnet50());
        assert!(
            (16..=20).contains(&n),
            "expected the wall near 18 replicas, got {n}"
        );
    }

    #[test]
    fn shared_streams_scale_past_60() {
        // Paper Fig 5: explicit streams "was able to scale up to at least 60
        // ResNet-50 models".
        let spec = DeviceSpec::v100();
        let n = max_replicas(&spec, DeploymentShape::SharedProcessStreams, &resnet50());
        assert!(n >= 60, "expected >= 60 replicas, got {n}");
    }

    #[test]
    fn plan_totals_are_consistent() {
        let spec = DeviceSpec::v100();
        let p = plan(&spec, DeploymentShape::ProcessPerReplica, &resnet50(), 4);
        assert_eq!(
            p.total_bytes,
            p.context_bytes + p.workspace_bytes + p.weight_bytes + p.activation_bytes
        );
        assert!(p.fits);
        assert!(p.utilization() > 0.0 && p.utilization() < 1.0);
    }

    #[test]
    fn shared_shape_amortizes_context() {
        let spec = DeviceSpec::v100();
        let a = plan(&spec, DeploymentShape::ProcessPerReplica, &resnet50(), 10);
        let b = plan(&spec, DeploymentShape::SharedProcessStreams, &resnet50(), 10);
        assert!(b.total_bytes < a.total_bytes);
        assert_eq!(b.context_bytes, spec.per_context_mem);
    }

    #[test]
    fn allocator_allocates_and_frees() {
        let mut a = DeviceAllocator::new(1000);
        let id1 = a.alloc(400, "w").unwrap();
        let _id2 = a.alloc(500, "act").unwrap();
        assert_eq!(a.used(), 900);
        assert_eq!(a.peak(), 900);
        assert!(a.free(id1));
        assert_eq!(a.used(), 500);
        assert!(!a.free(id1), "double free must be rejected");
        assert_eq!(a.peak(), 900, "peak is sticky");
    }

    #[test]
    fn allocator_oom_is_reported_not_panicked() {
        let mut a = DeviceAllocator::new(100);
        a.alloc(80, "w").unwrap();
        let err = a.alloc(40, "x").unwrap_err();
        assert_eq!(
            err,
            AllocError::OutOfMemory {
                requested: 40,
                free: 20,
                capacity: 100
            }
        );
        // State unchanged after failed alloc.
        assert_eq!(a.used(), 80);
        assert_eq!(a.live_allocations(), 1);
    }
}
