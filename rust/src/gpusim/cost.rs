//! Roofline cost model: how long does a kernel take on `s` SMs?
//!
//! `duration = max(compute_time, memory_time)` where
//!   compute_time = flops / (used_sms · flops_per_sm · occupancy_eff · interference)
//!   memory_time  = bytes / (hbm_bw · bw_fraction(used_sms) · interference)
//!
//! plus the launch overhead, which the *engine* accounts separately because
//! it depends on the dispatch path (plain launch, MPS proxy, context switch).
//!
//! The occupancy curve is the load-bearing piece: a kernel with 1 CTA per SM
//! cannot hide memory latency and reaches only ~14 % of per-SM peak; a
//! super-kernel with 16+ CTAs per SM approaches peak. This is exactly the
//! mechanism the paper's Figure 7 exploits (merging R small problems to fill
//! the machine), so the shape of the figure follows from the mechanism, not
//! from curve-fitting the paper's series.

use crate::gpusim::device::DeviceSpec;
use crate::gpusim::kernel::KernelDesc;

/// Execution context for a cost query.
#[derive(Debug, Clone, Copy)]
pub struct CostCtx {
    /// SMs allocated to this kernel (may be fractional under MPS QoS).
    pub sms: f64,
    /// Concurrently-resident kernels from distinct clients (>= 1).
    pub concurrency: u32,
    /// If true, memory bandwidth is statically partitioned `1/concurrency`
    /// (MPS QoS behaviour — non-work-conserving), instead of demand-shared.
    pub static_bw_partition: bool,
}

impl CostCtx {
    /// Whole device, alone.
    pub fn exclusive(spec: &DeviceSpec) -> Self {
        Self {
            sms: spec.sms as f64,
            concurrency: 1,
            static_bw_partition: false,
        }
    }
}

/// Pure service time of `kernel` (seconds), excluding launch overhead.
pub fn kernel_service_time(spec: &DeviceSpec, kernel: &KernelDesc, ctx: &CostCtx) -> f64 {
    debug_assert!(ctx.sms > 0.0, "kernel must be allocated SMs");
    debug_assert!(ctx.concurrency >= 1);

    // A kernel cannot spread over more SMs than it has CTAs.
    let used_sms = ctx.sms.min(kernel.ctas as f64).max(1e-9);
    let cpsm = kernel.ctas as f64 / used_sms;
    let interf = spec.interference(ctx.concurrency);
    let eff = spec.occupancy_eff(cpsm) * interf;

    let compute = kernel.flops / (used_sms * spec.flops_per_sm * eff.max(1e-12));

    let bw_frac = if ctx.static_bw_partition {
        (1.0 / ctx.concurrency as f64).min(spec.bw_fraction(used_sms))
    } else {
        spec.bw_fraction(used_sms)
    };
    let memory = kernel.bytes / (spec.hbm_bw * bw_frac * interf);

    compute.max(memory)
}

/// Service time with the whole device, alone (the exclusive baseline).
pub fn exclusive_time(spec: &DeviceSpec, kernel: &KernelDesc) -> f64 {
    kernel_service_time(spec, kernel, &CostCtx::exclusive(spec))
}

/// Effective FLOP/s a kernel achieves in a context.
pub fn achieved_flops(spec: &DeviceSpec, kernel: &KernelDesc, ctx: &CostCtx) -> f64 {
    kernel.flops / kernel_service_time(spec, kernel, ctx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::kernel::GemmShape;

    fn v100() -> DeviceSpec {
        DeviceSpec::v100()
    }

    #[test]
    fn single_conv_sgemm_matches_cublas_scale() {
        // A lone conv2_2-shaped SGEMM on V100 measures ~35 us with cuBLAS;
        // the model should land in the same decade (20-80 us).
        let spec = v100();
        let k = KernelDesc::sgemm(0, GemmShape::RESNET18_CONV2_2);
        let t = exclusive_time(&spec, &k);
        assert!(
            (15e-6..120e-6).contains(&t),
            "conv2_2 exclusive time {t} outside plausible range"
        );
    }

    #[test]
    fn superkernel_beats_sum_of_parts() {
        // Merging R small GEMMs must be much faster than running them
        // back-to-back: that is the paper's core claim.
        let spec = v100();
        let parts: Vec<KernelDesc> = (0..32)
            .map(|t| KernelDesc::sgemm(t, GemmShape::RESNET18_CONV2_2))
            .collect();
        let serial: f64 = parts.iter().map(|k| exclusive_time(&spec, k)).sum();
        let merged = KernelDesc::superkernel(&parts);
        let fused = exclusive_time(&spec, &merged);
        assert!(
            fused < serial / 3.0,
            "fused {fused} should be >3x faster than serial {serial}"
        );
    }

    #[test]
    fn superkernel_throughput_approaches_peak() {
        let spec = v100();
        let parts: Vec<KernelDesc> = (0..120)
            .map(|t| KernelDesc::sgemm(t, GemmShape::RESNET18_CONV2_2))
            .collect();
        let merged = KernelDesc::superkernel(&parts);
        let ctx = CostCtx::exclusive(&spec);
        let f = achieved_flops(&spec, &merged, &ctx);
        assert!(
            f > 0.6 * spec.peak_flops(),
            "large super-kernel should reach >60% of peak, got {}",
            f / spec.peak_flops()
        );
    }

    #[test]
    fn matvec_is_memory_bound() {
        let spec = v100();
        let k = KernelDesc::sgemm(0, GemmShape::RNN_MATVEC);
        // At full BW the matvec moves ~1 MB; it must be memory-bound: the
        // achieved FLOP/s should be far below compute peak even when batched.
        let parts: Vec<KernelDesc> = (0..64).map(|t| KernelDesc::sgemm(t, GemmShape::RNN_MATVEC)).collect();
        let merged = KernelDesc::superkernel(&parts);
        let f = achieved_flops(&spec, &merged, &CostCtx::exclusive(&spec));
        assert!(f < 0.2 * spec.peak_flops(), "matvec cannot be compute-bound");
        assert!(exclusive_time(&spec, &k) > 0.0);
    }

    #[test]
    fn more_sms_never_slower() {
        let spec = v100();
        let k = KernelDesc::sgemm(0, GemmShape::SQUARE_256);
        let mut last = f64::INFINITY;
        for sms in [1.0, 2.0, 4.0, 8.0, 16.0, 40.0, 80.0] {
            let t = kernel_service_time(
                &spec,
                &k,
                &CostCtx {
                    sms,
                    concurrency: 1,
                    static_bw_partition: false,
                },
            );
            assert!(t <= last * 1.0000001, "monotonic in SMs: {sms} -> {t}");
            last = t;
        }
    }

    #[test]
    fn interference_slows_kernels() {
        let spec = v100();
        let k = KernelDesc::sgemm(0, GemmShape::SQUARE_256);
        let alone = kernel_service_time(
            &spec,
            &k,
            &CostCtx {
                sms: 10.0,
                concurrency: 1,
                static_bw_partition: false,
            },
        );
        let crowded = kernel_service_time(
            &spec,
            &k,
            &CostCtx {
                sms: 10.0,
                concurrency: 16,
                static_bw_partition: false,
            },
        );
        assert!(crowded > alone * 1.5);
    }

    #[test]
    fn static_bw_partition_hurts_memory_bound_kernels() {
        let spec = v100();
        let k = KernelDesc::sgemm(0, GemmShape::RNN_MATVEC);
        let shared = kernel_service_time(
            &spec,
            &k,
            &CostCtx {
                sms: 80.0,
                concurrency: 8,
                static_bw_partition: false,
            },
        );
        let partitioned = kernel_service_time(
            &spec,
            &k,
            &CostCtx {
                sms: 80.0,
                concurrency: 8,
                static_bw_partition: true,
            },
        );
        assert!(partitioned > shared);
    }
}
