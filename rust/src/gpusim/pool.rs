//! Device-pool simulation: shard a multi-tenant workload across N
//! simulated devices and run each shard under the configured policy.
//!
//! This is the simulator-side mirror of the sharded coordinator
//! ([`crate::coordinator::driver`]): tenants are assigned to devices by the
//! same placement rule (least-loaded with class affinity, via
//! [`crate::coordinator::placement::place`]), each device runs its shard
//! independently (devices do not contend — they are separate GPUs), and the
//! pool's makespan is the slowest device's makespan. D-STACK
//! (arXiv:2304.13541) demonstrates the throughput-multiplying effect this
//! models; `benches/fig8_multidevice_scaling.rs` reproduces the scaling
//! curve for the paper's conv2_2 workload.

use crate::coordinator::placement::place;
use crate::gpusim::engine::{run, SimConfig, SimReport, TenantWorkload};

/// Result of a device-pool run: per-device reports plus the tenant→device
/// assignment (global tenant index → device id).
#[derive(Debug, Clone)]
pub struct PoolReport {
    pub assignment: Vec<usize>,
    pub per_device: Vec<SimReport>,
}

impl PoolReport {
    pub fn n_devices(&self) -> usize {
        self.per_device.len()
    }

    /// Pool makespan: devices run concurrently, so the pool finishes when
    /// the slowest device does.
    pub fn makespan(&self) -> f64 {
        self.per_device
            .iter()
            .map(|r| r.makespan)
            .fold(0.0, f64::max)
    }

    pub fn total_flops(&self) -> f64 {
        self.per_device.iter().map(SimReport::total_flops).sum()
    }

    pub fn total_completed(&self) -> u64 {
        self.per_device.iter().map(SimReport::total_completed).sum()
    }

    pub fn kernel_launches(&self) -> u64 {
        self.per_device.iter().map(|r| r.kernel_launches).sum()
    }

    pub fn superkernel_launches(&self) -> u64 {
        self.per_device.iter().map(|r| r.superkernel_launches).sum()
    }

    /// Aggregate FLOP throughput of the whole pool.
    pub fn throughput_flops(&self) -> f64 {
        let span = self.makespan();
        if span <= 0.0 {
            0.0
        } else {
            self.total_flops() / span
        }
    }

    /// Throughput of one device over its own makespan.
    pub fn device_throughput(&self, device: usize) -> f64 {
        self.per_device[device].throughput_flops()
    }

    /// Mean inference latency across every completed inference in the pool.
    pub fn mean_latency(&self) -> f64 {
        let all: Vec<f64> = self
            .per_device
            .iter()
            .flat_map(|r| r.tenants.iter())
            .flat_map(|t| t.latencies.iter().copied())
            .collect();
        crate::util::stats::mean(&all)
    }
}

/// Result of a multi-node run: one [`PoolReport`] per node plus the
/// tenant→node assignment (global tenant index → node id). Nodes are
/// independent machines — they share no devices — so cluster makespan is
/// the slowest node's makespan, mirroring [`PoolReport::makespan`] one
/// level up. `benches/fig14_cluster_scaleout.rs` uses this as the
/// simulator-side ground truth for the cluster tier
/// ([`crate::coordinator::cluster`]).
#[derive(Debug, Clone)]
pub struct MultiNodeReport {
    pub node_of: Vec<usize>,
    pub per_node: Vec<PoolReport>,
}

impl MultiNodeReport {
    pub fn n_nodes(&self) -> usize {
        self.per_node.len()
    }

    /// Cluster makespan: nodes run concurrently, so the cluster finishes
    /// when the slowest node does.
    pub fn makespan(&self) -> f64 {
        self.per_node
            .iter()
            .map(PoolReport::makespan)
            .fold(0.0, f64::max)
    }

    pub fn total_flops(&self) -> f64 {
        self.per_node.iter().map(PoolReport::total_flops).sum()
    }

    pub fn total_completed(&self) -> u64 {
        self.per_node.iter().map(PoolReport::total_completed).sum()
    }

    pub fn kernel_launches(&self) -> u64 {
        self.per_node.iter().map(PoolReport::kernel_launches).sum()
    }

    /// Aggregate FLOP throughput of the whole cluster.
    pub fn throughput_flops(&self) -> f64 {
        let span = self.makespan();
        if span <= 0.0 {
            0.0
        } else {
            self.total_flops() / span
        }
    }
}

/// Run `workloads` across `n_nodes` nodes of `devices_per_node` devices
/// each. Tenants shard across nodes by the same least-loaded/class-affine
/// rule used within a node, then each node runs its shard as an
/// independent device pool.
pub fn run_multinode(
    cfg: &SimConfig,
    workloads: &[TenantWorkload],
    n_nodes: usize,
    devices_per_node: usize,
) -> MultiNodeReport {
    assert!(n_nodes >= 1, "need at least one node");
    assert!(devices_per_node >= 1, "need at least one device per node");
    let items: Vec<_> = workloads
        .iter()
        .map(|w| (w.class_ref(), w.total_flops()))
        .collect();
    let node_of = place(&items, n_nodes).device_of;
    let per_node = (0..n_nodes)
        .map(|node| {
            let shard: Vec<TenantWorkload> = workloads
                .iter()
                .zip(&node_of)
                .filter(|(_, &n)| n == node)
                .map(|(w, _)| w.clone())
                .collect();
            run_pool(cfg, &shard, devices_per_node)
        })
        .collect();
    MultiNodeReport { node_of, per_node }
}

/// Run `workloads` across a pool of `n_devices` copies of `cfg.spec`,
/// sharding tenants least-loaded with class affinity.
pub fn run_pool(cfg: &SimConfig, workloads: &[TenantWorkload], n_devices: usize) -> PoolReport {
    assert!(n_devices >= 1, "need at least one device");
    // Borrowed classes: placement groups by the same keys as `class_key()`
    // (WorkloadClassRef has the identical variant order) without cloning a
    // name per tenant.
    let items: Vec<_> = workloads
        .iter()
        .map(|w| (w.class_ref(), w.total_flops()))
        .collect();
    let assignment = place(&items, n_devices).device_of;
    let per_device = (0..n_devices)
        .map(|d| {
            // Pre-count the shard so collecting it never reallocates.
            let members = assignment.iter().filter(|&&dev| dev == d).count();
            let mut shard: Vec<TenantWorkload> = Vec::with_capacity(members);
            shard.extend(
                workloads
                    .iter()
                    .zip(&assignment)
                    .filter(|(_, &dev)| dev == d)
                    .map(|(w, _)| w.clone()),
            );
            debug_assert_eq!(shard.len(), members, "pre-counted shard must not grow");
            run(cfg, &shard)
        })
        .collect();
    PoolReport { assignment, per_device }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::DeviceSpec;
    use crate::gpusim::engine::Policy;
    use crate::gpusim::kernel::GemmShape;
    use crate::workload::sgemm_tenants;

    fn cfg(policy: Policy) -> SimConfig {
        SimConfig::new(DeviceSpec::v100(), policy)
    }

    #[test]
    fn pool_conserves_inferences_and_flops() {
        let w = sgemm_tenants(12, 5, GemmShape::SQUARE_256);
        let expected_flops: f64 = w.iter().map(|x| x.total_flops()).sum();
        for n in [1usize, 2, 3, 4] {
            let r = run_pool(&cfg(Policy::SpaceTime { max_batch: 8 }), &w, n);
            assert_eq!(r.total_completed(), 60, "devices={n}");
            assert!((r.total_flops() - expected_flops).abs() < 1e-3);
            assert_eq!(r.assignment.len(), 12);
            assert!(r.assignment.iter().all(|&d| d < n));
        }
    }

    #[test]
    fn one_device_pool_matches_plain_run() {
        let w = sgemm_tenants(6, 4, GemmShape::RESNET18_CONV2_2);
        let pool = run_pool(&cfg(Policy::SpaceTime { max_batch: 32 }), &w, 1);
        let plain = run(&cfg(Policy::SpaceTime { max_batch: 32 }), &w);
        assert_eq!(pool.makespan(), plain.makespan);
        assert_eq!(pool.total_completed(), plain.total_completed());
        assert_eq!(pool.kernel_launches(), plain.kernel_launches);
    }

    #[test]
    fn uniform_class_spreads_evenly() {
        let w = sgemm_tenants(16, 2, GemmShape::SQUARE_256);
        let r = run_pool(&cfg(Policy::SpaceTime { max_batch: 8 }), &w, 4);
        for d in 0..4 {
            let members = r.assignment.iter().filter(|&&x| x == d).count();
            assert_eq!(members, 4, "device {d} should host 4 of 16 tenants");
        }
    }

    #[test]
    fn borrowed_class_placement_matches_owned() {
        use crate::gpusim::kernel::KernelDesc;
        // Mixed GEMM + named kernels: the borrowed WorkloadClassRef keys
        // must shard tenants exactly like the owned WorkloadClass keys.
        let mut w = sgemm_tenants(6, 2, GemmShape::SQUARE_256);
        w.push(TenantWorkload::new(
            vec![KernelDesc::other(6, "relu", 1e7, 1e6, 8)],
            2,
        ));
        w.push(TenantWorkload::new(
            vec![KernelDesc::other(7, "relu", 1e7, 1e6, 8)],
            2,
        ));
        w.push(TenantWorkload::new(vec![], 1));
        let owned: Vec<_> = w.iter().map(|x| (x.class_key(), x.total_flops())).collect();
        let borrowed: Vec<_> = w.iter().map(|x| (x.class_ref(), x.total_flops())).collect();
        for n in [1usize, 2, 3] {
            assert_eq!(place(&owned, n).device_of, place(&borrowed, n).device_of);
        }
    }

    #[test]
    fn multinode_conserves_inferences_and_flops() {
        let w = sgemm_tenants(16, 3, GemmShape::SQUARE_256);
        let expected_flops: f64 = w.iter().map(|x| x.total_flops()).sum();
        for nodes in [1usize, 2, 4] {
            let r = run_multinode(&cfg(Policy::SpaceTime { max_batch: 8 }), &w, nodes, 2);
            assert_eq!(r.n_nodes(), nodes);
            assert_eq!(r.total_completed(), 48, "nodes={nodes}");
            assert!((r.total_flops() - expected_flops).abs() < 1e-3);
            assert_eq!(r.node_of.len(), 16);
            assert!(r.node_of.iter().all(|&n| n < nodes));
        }
    }

    #[test]
    fn one_node_multinode_matches_plain_pool() {
        let w = sgemm_tenants(8, 4, GemmShape::RESNET18_CONV2_2);
        let multi = run_multinode(&cfg(Policy::SpaceTime { max_batch: 16 }), &w, 1, 3);
        let pool = run_pool(&cfg(Policy::SpaceTime { max_batch: 16 }), &w, 3);
        assert_eq!(multi.makespan(), pool.makespan());
        assert_eq!(multi.total_completed(), pool.total_completed());
        assert_eq!(multi.kernel_launches(), pool.kernel_launches());
    }

    #[test]
    fn multinode_makespan_is_max_of_nodes_and_scaling_helps() {
        let w = sgemm_tenants(24, 4, GemmShape::SQUARE_256);
        let r4 = run_multinode(&cfg(Policy::SpaceTime { max_batch: 8 }), &w, 4, 2);
        let per: Vec<f64> = r4.per_node.iter().map(PoolReport::makespan).collect();
        assert_eq!(r4.makespan(), per.iter().cloned().fold(0.0, f64::max));
        // More nodes → shorter makespan for a uniform workload.
        let r1 = run_multinode(&cfg(Policy::SpaceTime { max_batch: 8 }), &w, 1, 2);
        assert!(r4.makespan() < r1.makespan());
        assert!(r4.throughput_flops() > r1.throughput_flops());
    }

    #[test]
    fn pool_makespan_is_max_of_devices() {
        let w = sgemm_tenants(8, 3, GemmShape::SQUARE_256);
        let r = run_pool(&cfg(Policy::TimeMux), &w, 2);
        let per: Vec<f64> = r.per_device.iter().map(|x| x.makespan).collect();
        assert_eq!(r.makespan(), per.iter().cloned().fold(0.0, f64::max));
        assert!(r.throughput_flops() > 0.0);
    }
}
