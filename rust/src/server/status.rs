//! Plaintext status endpoint: a minimal TCP listener that writes the
//! current metrics snapshot as JSON to every connection and closes it
//! (curl-able; no HTTP stack is vendored offline — DESIGN.md §7).

use std::io::Write;
use std::net::{TcpListener, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::server::frontend::ServerHandle;

/// Running status endpoint.
pub struct StatusEndpoint {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl StatusEndpoint {
    /// Bind and serve snapshots; `addr` may use port 0 for an ephemeral
    /// port (read back via [`StatusEndpoint::addr`]).
    pub fn start(addr: impl ToSocketAddrs, handle: ServerHandle) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let thread = std::thread::Builder::new()
            .name("stgpu-status".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((mut sock, _)) => {
                            let body = handle
                                .snapshot()
                                .map(|s| s.to_json().to_string())
                                .unwrap_or_else(|| "{\"error\":\"no snapshot\"}".into());
                            let _ = sock.write_all(body.as_bytes());
                            let _ = sock.write_all(b"\n");
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Self { addr: local, stop, thread: Some(thread) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for StatusEndpoint {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}
