//! Plaintext status endpoint: a minimal TCP listener that writes the
//! current metrics snapshot as JSON to every connection and closes it
//! (curl-able; no HTTP stack is vendored offline — DESIGN.md §7).
//!
//! The endpoint is generic over a snapshot *provider* closure
//! ([`StatusEndpoint::start_with`]) so a cluster front-end can serve an
//! aggregated view over per-node snapshots ([`aggregate_nodes`]) through
//! the same listener the single-process server uses.

use std::io::Write;
use std::net::{TcpListener, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::server::frontend::ServerHandle;
use crate::util::json::Json;

/// Aggregate per-node status snapshots (shape of
/// [`crate::coordinator::cluster::NodeSummary::to_json`]) into one
/// cluster-level snapshot: counters sum, `busy_s` sums, and
/// `slo_attainment` is recomputed from the summed hits/completed rather
/// than averaged (nodes with more traffic weigh more).
pub fn aggregate_nodes(nodes: &[Json]) -> Json {
    fn get(j: &Json, k: &str) -> f64 {
        j.get(k).and_then(Json::as_f64).unwrap_or(0.0)
    }
    let mut offered = 0.0;
    let mut completed = 0.0;
    let mut hits = 0.0;
    let mut misses = 0.0;
    let mut dropped = 0.0;
    let mut backlog = 0.0;
    let mut busy_s = 0.0;
    let mut reconfigs = 0.0;
    for n in nodes {
        offered += get(n, "offered");
        completed += get(n, "completed");
        hits += get(n, "hits");
        misses += get(n, "misses");
        dropped += get(n, "dropped");
        backlog += get(n, "backlog");
        busy_s += get(n, "busy_s");
        reconfigs += get(n, "reconfigs");
    }
    let att = if completed > 0.0 { hits / completed } else { 1.0 };
    Json::obj(vec![
        ("nodes", Json::num(nodes.len() as f64)),
        ("offered", Json::num(offered)),
        ("completed", Json::num(completed)),
        ("hits", Json::num(hits)),
        ("misses", Json::num(misses)),
        ("dropped", Json::num(dropped)),
        ("backlog", Json::num(backlog)),
        ("busy_s", Json::num(busy_s)),
        ("reconfigs", Json::num(reconfigs)),
        ("slo_attainment", Json::num(att)),
        ("per_node", Json::Arr(nodes.to_vec())),
    ])
}

/// Running status endpoint.
pub struct StatusEndpoint {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl StatusEndpoint {
    /// Bind and serve snapshots; `addr` may use port 0 for an ephemeral
    /// port (read back via [`StatusEndpoint::addr`]).
    pub fn start(addr: impl ToSocketAddrs, handle: ServerHandle) -> std::io::Result<Self> {
        Self::start_with(addr, move || {
            handle
                .snapshot()
                .map(|s| s.to_json().to_string())
                .unwrap_or_else(|| "{\"error\":\"no snapshot\"}".into())
        })
    }

    /// Bind and serve whatever `provider` returns per connection. This is
    /// the seam the cluster tier uses to expose an [`aggregate_nodes`]
    /// roll-up instead of a single shard's snapshot.
    pub fn start_with(
        addr: impl ToSocketAddrs,
        provider: impl Fn() -> String + Send + 'static,
    ) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let thread = std::thread::Builder::new()
            .name("stgpu-status".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((mut sock, _)) => {
                            let body = provider();
                            let _ = sock.write_all(body.as_bytes());
                            let _ = sock.write_all(b"\n");
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Self { addr: local, stop, thread: Some(thread) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for StatusEndpoint {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;

    fn node(offered: f64, completed: f64, hits: f64, busy_s: f64) -> Json {
        Json::obj(vec![
            ("node", Json::num(0.0)),
            ("offered", Json::num(offered)),
            ("completed", Json::num(completed)),
            ("hits", Json::num(hits)),
            ("misses", Json::num(completed - hits)),
            ("dropped", Json::num(0.0)),
            ("backlog", Json::num(offered - completed)),
            ("busy_s", Json::num(busy_s)),
            ("reconfigs", Json::num(1.0)),
            ("slo_attainment", Json::num(if completed > 0.0 { hits / completed } else { 1.0 })),
        ])
    }

    #[test]
    fn aggregate_nodes_sums_counters_and_weighs_attainment_by_traffic() {
        // Node 0: 100 completed, all hits. Node 1: 300 completed, none hit.
        // A naive average of attainments would say 0.5; traffic-weighted
        // aggregation must say 0.25.
        let agg = aggregate_nodes(&[node(120.0, 100.0, 100.0, 0.5), node(310.0, 300.0, 0.0, 1.5)]);
        assert_eq!(agg.get("nodes").and_then(Json::as_f64), Some(2.0));
        assert_eq!(agg.get("offered").and_then(Json::as_f64), Some(430.0));
        assert_eq!(agg.get("completed").and_then(Json::as_f64), Some(400.0));
        assert_eq!(agg.get("hits").and_then(Json::as_f64), Some(100.0));
        assert_eq!(agg.get("backlog").and_then(Json::as_f64), Some(30.0));
        assert!((agg.get("busy_s").and_then(Json::as_f64).unwrap() - 2.0).abs() < 1e-12);
        assert!((agg.get("slo_attainment").and_then(Json::as_f64).unwrap() - 0.25).abs() < 1e-12);
        assert_eq!(agg.get("per_node").and_then(Json::as_arr).map(<[Json]>::len), Some(2));
    }

    #[test]
    fn aggregate_of_no_nodes_is_empty_but_well_formed() {
        let agg = aggregate_nodes(&[]);
        assert_eq!(agg.get("nodes").and_then(Json::as_f64), Some(0.0));
        assert_eq!(agg.get("slo_attainment").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn start_with_serves_the_provider_string() {
        let ep = StatusEndpoint::start_with("127.0.0.1:0", || "{\"ok\":true}".to_string())
            .expect("bind ephemeral");
        let mut sock = std::net::TcpStream::connect(ep.addr()).expect("connect");
        let mut body = String::new();
        sock.read_to_string(&mut body).expect("read snapshot");
        assert_eq!(body, "{\"ok\":true}\n");
        ep.stop();
    }
}
