//! Threaded serving frontend: clients submit requests through a channel;
//! a dedicated leader thread owns the [`Coordinator`] and pumps scheduling
//! rounds, routing each completion back to its submitter.
//!
//! The design mirrors a vLLM-style router: submission is non-blocking with
//! admission control; batching happens inside the coordinator; the leader
//! thread is the only mutator, so no lock is held across a PJRT execution.
//!
//! Admission outcomes surface verbatim to submitters: a saturated bounded
//! front replies `Err(Reject::Overloaded)` / `Err(Reject::QueueFull)`, and
//! a deadline-aware coordinator replies `Err(Reject::DeadlineInfeasible)`
//! for requests predicted past their SLO, rather than letting queues grow
//! without bound. An embedder exposing this frontend over HTTP maps those
//! rejects to status codes with `Reject::http_status` (429 for
//! shed/backpressure, 504 for infeasible deadlines). Per-device metrics
//! and per-tenant SLO attainment ride the snapshot (`Snapshot::devices`,
//! `TenantSnapshot::slo_attainment`), so the status endpoint reports the
//! whole pool.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::{Coordinator, InferenceResponse, Reject, RequestContext, RequestId};
use crate::metrics::Snapshot;
use crate::runtime::HostTensor;

/// What a submitter gets back.
pub type Reply = Result<InferenceResponse, Reject>;

enum Msg {
    Submit {
        ctx: RequestContext,
        payload: Vec<HostTensor>,
        reply: Sender<Reply>,
    },
    Snapshot {
        reply: Sender<Snapshot>,
    },
    Shutdown,
}

/// Handle cloned into client threads.
#[derive(Clone)]
pub struct ServerHandle {
    tx: Sender<Msg>,
}

impl ServerHandle {
    /// Submit a context-carrying request; returns a receiver for the
    /// eventual reply, or [`Reject::ServerShutdown`] right here when the
    /// leader is gone — a dead server must fail at submit time, not hand
    /// out a receiver that only errors on `recv`.
    pub fn submit_ctx(
        &self,
        ctx: RequestContext,
        payload: Vec<HostTensor>,
    ) -> Result<Receiver<Reply>, Reject> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Msg::Submit { ctx, payload, reply: reply_tx })
            .map_err(|_| Reject::ServerShutdown)?;
        Ok(reply_rx)
    }

    /// Submit and return a receiver for the eventual reply — the
    /// deprecation-path `(tenant, payload)` signature, now a thin wrapper
    /// over [`ServerHandle::submit_ctx`] with a default context. When the
    /// server is already down the receiver is preloaded with
    /// [`Reject::ServerShutdown`] so the failure is observable immediately
    /// instead of surfacing as a bare channel disconnect.
    pub fn submit(&self, tenant: usize, payload: Vec<HostTensor>) -> Receiver<Reply> {
        match self.submit_ctx(RequestContext::new(tenant), payload) {
            Ok(rx) => rx,
            Err(rej) => {
                let (tx, rx) = channel();
                let _ = tx.send(Err(rej));
                rx
            }
        }
    }

    /// Submit a context-carrying request and block for the reply.
    pub fn submit_blocking_ctx(&self, ctx: RequestContext, payload: Vec<HostTensor>) -> Reply {
        match self.submit_ctx(ctx, payload) {
            Ok(rx) => rx.recv().unwrap_or(Err(Reject::ServerShutdown)),
            Err(rej) => Err(rej),
        }
    }

    /// Submit and block for the reply (default-context compatibility path).
    pub fn submit_blocking(&self, tenant: usize, payload: Vec<HostTensor>) -> Reply {
        self.submit_blocking_ctx(RequestContext::new(tenant), payload)
    }

    /// Snapshot the server's metrics.
    pub fn snapshot(&self) -> Option<Snapshot> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Snapshot { reply: tx }).ok()?;
        rx.recv().ok()
    }
}

/// The running server: leader thread + handle.
pub struct Server {
    handle: ServerHandle,
    leader: Option<JoinHandle<Coordinator>>,
    tx: Sender<Msg>,
}

/// Leader-loop tuning.
#[derive(Debug, Clone, Copy)]
pub struct ServeOpts {
    /// How long to accumulate submissions before a round when the backlog
    /// is shallow (the batching window; paper §4 "dynamically schedule
    /// kernels as they arrive").
    pub batch_timeout: Duration,
    /// Backlog depth that triggers an immediate round.
    pub eager_backlog: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        Self { batch_timeout: Duration::from_micros(200), eager_backlog: 16 }
    }
}

impl Server {
    /// Start the leader thread over a warmed coordinator.
    pub fn start(coordinator: Coordinator, opts: ServeOpts) -> Self {
        let (tx, rx) = channel::<Msg>();
        let handle = ServerHandle { tx: tx.clone() };
        let leader = std::thread::Builder::new()
            .name("stgpu-leader".into())
            .spawn(move || leader_loop(coordinator, rx, opts))
            .expect("spawn leader");
        Self { handle, leader: Some(leader), tx }
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Stop the leader and recover the coordinator (for final reporting).
    pub fn shutdown(mut self) -> Coordinator {
        let _ = self.tx.send(Msg::Shutdown);
        self.leader
            .take()
            .expect("leader present")
            .join()
            .expect("leader panicked")
    }
}

/// In-flight bookkeeping: request id -> reply channel.
struct Inflight {
    entries: Vec<(RequestId, Sender<Reply>)>,
}

impl Inflight {
    fn new() -> Self {
        Self { entries: Vec::new() }
    }

    fn add(&mut self, id: RequestId, reply: Sender<Reply>) {
        self.entries.push((id, reply));
    }

    fn complete(&mut self, id: RequestId, reply: Reply) {
        if let Some(pos) = self.entries.iter().position(|(i, _)| *i == id) {
            let (_, tx) = self.entries.swap_remove(pos);
            let _ = tx.send(reply);
        }
    }
}

fn leader_loop(mut coord: Coordinator, rx: Receiver<Msg>, opts: ServeOpts) -> Coordinator {
    let mut inflight = Inflight::new();
    'serve: loop {
        // Phase 1: accumulate submissions for the batching window. The
        // window clock starts at the FIRST enqueue of the round (not at
        // phase entry), so an idle server never charges waiting time
        // against the batching budget. With pipelined rounds in flight
        // and nothing queued, skip straight to collection (after one
        // non-blocking poll for messages) so responses of the round still
        // executing are never held hostage to a lull in arrivals.
        let mut window_end: Option<Instant> = if coord.pending() > 0 {
            Some(Instant::now() + opts.batch_timeout)
        } else if coord.in_flight_rounds() > 0 {
            Some(Instant::now())
        } else {
            None
        };
        loop {
            let timeout = match window_end {
                // Work pending: wait only out the remaining window.
                Some(end) => end.saturating_duration_since(Instant::now()),
                // Idle: block in short slices for the next message.
                None => Duration::from_millis(50),
            };
            let msg = match rx.recv_timeout(timeout) {
                Ok(m) => Some(m),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => break 'serve,
            };
            match msg {
                Some(Msg::Submit { ctx, payload, reply }) => {
                    match coord.submit_ctx(ctx, payload) {
                        Ok(id) => inflight.add(id, reply),
                        Err(rej) => {
                            let _ = reply.send(Err(rej));
                        }
                    }
                    if window_end.is_none() {
                        window_end = Some(Instant::now() + opts.batch_timeout);
                    }
                    if coord.pending() >= opts.eager_backlog {
                        break; // enough to fill a super-kernel: go now
                    }
                }
                Some(Msg::Snapshot { reply }) => {
                    let _ = reply.send(coord.snapshot());
                }
                Some(Msg::Shutdown) => break 'serve,
                None => {
                    if coord.pending() > 0 || coord.in_flight_rounds() > 0 {
                        break; // window elapsed with work queued/in flight
                    }
                    // Idle: keep waiting.
                }
            }
        }
        // Phase 2: one scheduling round (also collects rounds still in
        // flight on the lane workers when the pipeline is deeper than 1).
        if coord.pending() > 0 || coord.in_flight_rounds() > 0 {
            match coord.run_round() {
                Ok(outcome) => {
                    for resp in outcome.responses {
                        inflight.complete(resp.id, Ok(resp));
                    }
                    for (id, rej) in outcome.rejections {
                        inflight.complete(id, Err(rej));
                    }
                }
                Err(e) => {
                    log::error!("round failed: {e:#}");
                }
            }
        }
    }
    // Drain what's left — queued AND in-flight pipelined rounds — so no
    // submitter hangs and no completion is lost at shutdown.
    while coord.pending() > 0 || coord.in_flight_rounds() > 0 {
        match coord.run_round() {
            Ok(outcome) => {
                for resp in outcome.responses {
                    inflight.complete(resp.id, Ok(resp));
                }
                for (id, rej) in outcome.rejections {
                    inflight.complete(id, Err(rej));
                }
            }
            Err(_) => break,
        }
    }
    for (_, tx) in inflight.entries.drain(..) {
        let _ = tx.send(Err(Reject::ServerShutdown));
    }
    coord
}

#[cfg(test)]
mod tests {
    // Live-server tests need artifacts; see rust/tests/integration_server.rs.
    use super::*;

    #[test]
    fn serve_opts_default_sane() {
        let o = ServeOpts::default();
        assert!(o.batch_timeout < Duration::from_millis(10));
        assert!(o.eager_backlog >= 1);
    }

    /// Regression for the silent-drop: submitting to a dead server must
    /// surface [`Reject::ServerShutdown`] at submit time (context path) or
    /// as an immediately available preloaded reply (compat path) — never a
    /// bare channel disconnect the caller only hits on `recv`.
    #[test]
    fn dead_server_rejects_at_submit_time() {
        let (tx, rx) = channel::<Msg>();
        drop(rx); // leader gone
        let handle = ServerHandle { tx };
        match handle.submit_ctx(RequestContext::new(0), vec![]) {
            Err(Reject::ServerShutdown) => {}
            other => panic!("expected ServerShutdown at submit time, got {other:?}"),
        }
        // Compat wrapper: receiver is preloaded, try_recv succeeds NOW.
        let rx = handle.submit(0, vec![]);
        assert_eq!(rx.try_recv().unwrap().unwrap_err(), Reject::ServerShutdown);
        assert_eq!(
            handle.submit_blocking(0, vec![]).unwrap_err(),
            Reject::ServerShutdown
        );
    }
}
