//! The async gateway tier in front of the coordinator.
//!
//! Layered like an Axum middleware stack, evaluated in order on every
//! request — each layer either passes the request down or rejects with a
//! structured [`Reject`]:
//!
//! ```text
//!   wire (reactor: non-blocking accept + worker pool)
//!     │
//!     ▼
//!   auth        [`AuthTable`]      — API key → tenant + isolation class
//!     ▼                              (`Reject::AuthFailed`)
//!   validation                     — wire fields well-formed
//!     ▼                              (`Reject::BadRequest`)
//!   rate limit  [`TokenBucket`]    — per-tenant tokens + burst credit
//!     ▼                              (`Reject::RateLimited{retry_after}`)
//!   breaker     [`CircuitBreaker`] — per-shard trip/half-open/close
//!     ▼                              (`Reject::BreakerOpen{device,..}`)
//!   admission   [`GatewayBackend`] — coordinator submit (EDF queues)
//! ```
//!
//! The gateway builds the [`RequestContext`] from the authenticated
//! principal plus wire fields (deadline budget, priority, trace id), so
//! the deadline that reaches the EDF heaps is the wire's — config SLOs
//! apply only when the wire names no deadline. A breaker-tripped shard
//! sheds HERE: the coordinator's queues never see the request.
//!
//! Every admission-path method takes `now: Instant` explicitly, which is
//! what lets the integration tests and the fig16 overload sweep drive
//! auth/rate-limit/breaker dynamics on a virtual clock, deterministically.

pub mod auth;
pub mod breaker;
pub mod ratelimit;
pub mod reactor;

use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

pub use auth::{AuthTable, Principal};
pub use breaker::{BreakerState, CircuitBreaker};
pub use ratelimit::TokenBucket;
pub use reactor::{Reactor, ReactorHandle};

use crate::config::{GatewayConfig, IsolationClass};

/// Ceiling on a wire-supplied `budget_ms`: 24 hours. Anything larger is
/// a client bug, and `Duration::from_secs_f64` panics near `f64::MAX` —
/// the validation layer rejects out-of-range budgets before a duration
/// is ever constructed, so a hostile `{"budget_ms":1e300}` is a
/// `BadRequest`, not a worker panic.
pub const MAX_BUDGET_MS: f64 = 86_400_000.0;
use crate::coordinator::{Coordinator, Priority, Reject, RequestContext};
use crate::runtime::HostTensor;
use crate::server::frontend::{Reply, ServerHandle};
use crate::util::json::Json;

/// What a backend submission yields: an immediate verdict (simulated or
/// rejected-at-admission backends) or a receiver the reply will land on
/// (the threaded serving frontend).
#[derive(Debug)]
pub enum BackendReply {
    Ready(Reply),
    Pending(Receiver<Reply>),
}

/// The admission target behind the gateway. Production uses
/// [`ServerBackend`]; tests inject synchronous fakes (e.g. an
/// always-overloaded shard) and fig16 drives a virtual-clock simulator.
pub trait GatewayBackend {
    /// Device shards behind this backend (breaker count).
    fn devices(&self) -> usize;
    /// Which shard `tenant`'s requests route to (breaker key).
    fn device_of(&self, tenant: usize) -> usize;
    /// Submit an admitted request.
    fn submit(&mut self, ctx: RequestContext, payload: Vec<HostTensor>) -> BackendReply;
}

/// Production backend: the threaded serving frontend, with the
/// tenant → device placement captured from the coordinator at build time
/// (placement is static per run).
pub struct ServerBackend {
    handle: ServerHandle,
    placement: Vec<usize>,
    devices: usize,
}

impl ServerBackend {
    /// Capture placement from the coordinator (before `Server::start`
    /// takes ownership of it) and pair it with the serving handle.
    pub fn from_coordinator(handle: ServerHandle, coord: &Coordinator) -> Self {
        let placement = (0..coord.tenants.len()).map(|t| coord.device_of(t)).collect();
        Self { handle, placement, devices: coord.devices() }
    }

    /// Build from pre-captured placement — for callers that must record
    /// `device_of` before the coordinator moves into `Server::start`.
    pub fn new(handle: ServerHandle, placement: Vec<usize>, devices: usize) -> Self {
        Self { handle, placement, devices: devices.max(1) }
    }
}

impl GatewayBackend for ServerBackend {
    fn devices(&self) -> usize {
        self.devices
    }

    fn device_of(&self, tenant: usize) -> usize {
        self.placement.get(tenant).copied().unwrap_or(0)
    }

    fn submit(&mut self, ctx: RequestContext, payload: Vec<HostTensor>) -> BackendReply {
        match self.handle.submit_ctx(ctx, payload) {
            Ok(rx) => BackendReply::Pending(rx),
            Err(rej) => BackendReply::Ready(Err(rej)),
        }
    }
}

/// Wire-decoded request fields (everything but the payload).
#[derive(Debug, Clone, Copy)]
pub struct WireRequest<'a> {
    pub api_key: &'a str,
    /// Client deadline budget in milliseconds; `None` falls back to the
    /// tenant's SLO default.
    pub budget_ms: Option<f64>,
    /// Scheduling priority; `None` takes the isolation class default.
    pub priority: Option<Priority>,
    pub trace_id: u64,
}

/// An admitted request in flight: pass back to [`Gateway::wait`] for the
/// reply (which also feeds the breaker the outcome), or — when the
/// gateway sits behind a lock — block on [`GatewayTicket::into_reply`]
/// WITHOUT the lock and feed the outcome back via [`Gateway::finish`].
#[derive(Debug)]
pub struct GatewayTicket {
    /// Shard the request was routed to.
    pub device: usize,
    /// True once the admission outcome already reached the breaker (the
    /// synchronous-reply path records during `admit`).
    recorded: bool,
    reply: BackendReply,
}

impl GatewayTicket {
    /// Block for the backend reply. Needs no gateway access, so callers
    /// that share a `Mutex<Gateway>` across threads (the reactor) drop
    /// the guard first — a stalled backend must not serialize every
    /// other worker's auth/rate-limit rejections behind one in-flight
    /// request. Pass the returned [`TicketOutcome`] to
    /// [`Gateway::finish`] for breaker bookkeeping.
    pub fn into_reply(self) -> (TicketOutcome, Reply) {
        let out = match self.reply {
            BackendReply::Ready(r) => r,
            BackendReply::Pending(rx) => rx.recv().unwrap_or(Err(Reject::ServerShutdown)),
        };
        (TicketOutcome { device: self.device, recorded: self.recorded }, out)
    }
}

/// What's left of a ticket once the reply arrived: the breaker key and
/// whether the outcome was already recorded at admission.
#[derive(Debug, Clone, Copy)]
pub struct TicketOutcome {
    device: usize,
    recorded: bool,
}

/// Monotonic gateway counters (status JSON / tests).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GatewayStats {
    /// Requests that passed every layer and reached the backend.
    pub admitted: u64,
    /// Rejected by the per-tenant token bucket.
    pub rate_limited: u64,
    /// Shed by an open breaker (the backend was never asked).
    pub breaker_shed: u64,
    /// Admitted requests whose backend verdict was a rejection.
    pub backend_rejects: u64,
    /// Rejected before the bucket: malformed wire fields.
    pub bad_requests: u64,
}

/// The gateway: auth → validation → rate limit → breaker → admission.
pub struct Gateway<B: GatewayBackend> {
    auth: AuthTable,
    /// Per-tenant buckets, indexed by tenant id (only tenants with API
    /// keys have one; admission always goes through auth first).
    buckets: Vec<Option<TokenBucket>>,
    /// One breaker per device shard.
    breakers: Vec<CircuitBreaker>,
    backend: B,
    stats: GatewayStats,
    /// Isolation class per tenant (status JSON), same indexing as
    /// `buckets`.
    classes: Vec<Option<IsolationClass>>,
}

impl<B: GatewayBackend> Gateway<B> {
    pub fn new(cfg: &GatewayConfig, backend: B) -> Self {
        let auth = AuthTable::from_config(cfg);
        let n_tenants = auth.principals().iter().map(|p| p.tenant + 1).max().unwrap_or(0);
        let mut buckets: Vec<Option<TokenBucket>> = Vec::new();
        let mut classes = Vec::new();
        buckets.resize_with(n_tenants, || None);
        classes.resize(n_tenants, None);
        for p in auth.principals() {
            // First key (in tenant-sorted order) wins if a tenant has
            // several; buckets are per TENANT, not per key.
            if buckets[p.tenant].is_none() {
                buckets[p.tenant] = Some(TokenBucket::new(
                    cfg.rate * p.class.rate_mult(),
                    cfg.burst * p.class.burst_mult(),
                ));
                classes[p.tenant] = Some(p.class);
            }
        }
        let breakers = (0..backend.devices().max(1))
            .map(|_| {
                CircuitBreaker::new(
                    cfg.breaker_window,
                    cfg.breaker_threshold,
                    Duration::from_secs_f64(cfg.breaker_cooldown_ms / 1e3),
                    cfg.half_open_probes,
                )
            })
            .collect();
        Self { auth, buckets, breakers, backend, stats: GatewayStats::default(), classes }
    }

    /// Run one request through the full layer stack. On `Ok` the request
    /// reached the backend; use [`Gateway::wait`] on the ticket for the
    /// reply. Allocation-free after warmup: every rejection on this path
    /// carries only `Copy` data (`BadRequest` strings are built in cold
    /// helpers).
    // lint: hot-path
    pub fn admit(
        &mut self,
        wire: &WireRequest<'_>,
        payload: Vec<HostTensor>,
        now: Instant,
    ) -> Result<GatewayTicket, Reject> {
        // Layer 1: auth.
        let Some(principal) = self.auth.authenticate(wire.api_key) else {
            return Err(Reject::AuthFailed);
        };
        // Layer 2: validation. The upper bound is load-bearing:
        // `Duration::from_secs_f64` in layer 5 panics on huge values.
        if let Some(ms) = wire.budget_ms {
            if !ms.is_finite() || ms <= 0.0 || ms > MAX_BUDGET_MS {
                self.stats.bad_requests += 1;
                return Err(bad_budget());
            }
        }
        // Layer 3: per-tenant token bucket.
        let bucket = self.buckets[principal.tenant]
            .as_mut()
            .expect("authenticated tenants have a bucket");
        if let Err(retry_after) = bucket.try_take(now) {
            self.stats.rate_limited += 1;
            return Err(Reject::RateLimited { retry_after });
        }
        // Layer 4: the shard's circuit breaker. An open breaker sheds
        // HERE — the coordinator queues are never touched.
        let device = self.backend.device_of(principal.tenant);
        if let Err(retry_after) = self.breakers[device].allow(now) {
            self.stats.breaker_shed += 1;
            return Err(Reject::BreakerOpen { device, retry_after });
        }
        // Layer 5: admission. Build the context the EDF queues will
        // order by: wire deadline/priority, class default priority, SLO
        // only if the wire named nothing.
        let mut ctx = RequestContext::new(principal.tenant)
            .with_priority(match wire.priority {
                Some(p) => p,
                None => principal.default_priority(),
            })
            .with_trace_id(wire.trace_id);
        if let Some(ms) = wire.budget_ms {
            ctx = ctx.with_budget(Duration::from_secs_f64(ms / 1e3));
        }
        self.stats.admitted += 1;
        match self.backend.submit(ctx, payload) {
            BackendReply::Ready(Err(rej)) => {
                // Synchronous verdict: feed the breaker now.
                self.breakers[device].record(rej.is_overload(), now);
                self.stats.backend_rejects += 1;
                Err(rej)
            }
            BackendReply::Ready(Ok(res)) => {
                self.breakers[device].record(false, now);
                Ok(GatewayTicket { device, recorded: true, reply: BackendReply::Ready(Ok(res)) })
            }
            BackendReply::Pending(rx) => {
                Ok(GatewayTicket { device, recorded: false, reply: BackendReply::Pending(rx) })
            }
        }
    }

    /// Collect an admitted request's reply (blocking on the pending
    /// path) and feed the breaker its outcome. `now` timestamps the
    /// outcome for breaker bookkeeping. Convenience for single-threaded
    /// callers (tests, the fig16 sweep); the reactor uses the split
    /// [`GatewayTicket::into_reply`] + [`Gateway::finish`] path so the
    /// blocking wait happens outside the gateway lock.
    pub fn wait(&mut self, ticket: GatewayTicket, now: Instant) -> Reply {
        let (outcome, out) = ticket.into_reply();
        self.finish(outcome, &out, now);
        out
    }

    /// Record a completed request's verdict into the breaker and the
    /// counters (no-op if the synchronous path already recorded it at
    /// admission).
    pub fn finish(&mut self, outcome: TicketOutcome, out: &Reply, now: Instant) {
        if !outcome.recorded {
            self.breakers[outcome.device].record(
                matches!(out, Err(r) if r.is_overload()),
                now,
            );
            if out.is_err() {
                self.stats.backend_rejects += 1;
            }
        }
    }

    pub fn stats(&self) -> GatewayStats {
        self.stats
    }

    /// Tenant behind `api_key`, without counting an auth attempt (the
    /// reactor uses this to build the payload before admission; a miss
    /// still flows through [`Gateway::admit`] so the failure is counted
    /// exactly once).
    pub fn peek_tenant(&self, api_key: &str) -> Option<usize> {
        self.auth.peek(api_key).map(|p| p.tenant)
    }

    pub fn auth_failures(&self) -> u64 {
        self.auth.failures()
    }

    pub fn breaker_state(&self, device: usize) -> BreakerState {
        self.breakers[device].state()
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// The `"gateway"` section of the versioned status JSON: per-tenant
    /// token balances, per-shard breaker states, and the layer counters.
    pub fn status_json(&self, now: Instant) -> Json {
        let tenants: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter_map(|(t, b)| b.as_ref().map(|b| (t, b)))
            .map(|(t, b)| {
                let class = self.classes[t].map(|c| c.as_str()).unwrap_or("standard");
                Json::obj(vec![
                    ("tenant", Json::num(t as f64)),
                    ("class", Json::str(class)),
                    ("tokens", Json::num(b.available(now))),
                    ("rate", Json::num(b.rate())),
                    ("burst", Json::num(b.burst())),
                ])
            })
            .collect();
        let breakers: Vec<Json> = self
            .breakers
            .iter()
            .enumerate()
            .map(|(d, br)| {
                Json::obj(vec![
                    ("device", Json::num(d as f64)),
                    ("state", Json::str(br.state().as_str())),
                    ("trips", Json::num(br.trips() as f64)),
                    ("window_overload", Json::num(br.window_overload_frac())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("tenants", Json::Arr(tenants)),
            ("breakers", Json::Arr(breakers)),
            ("auth_failures", Json::num(self.auth.failures() as f64)),
            ("admitted", Json::num(self.stats.admitted as f64)),
            ("rate_limited", Json::num(self.stats.rate_limited as f64)),
            ("breaker_shed", Json::num(self.stats.breaker_shed as f64)),
            ("backend_rejects", Json::num(self.stats.backend_rejects as f64)),
            ("bad_requests", Json::num(self.stats.bad_requests as f64)),
        ])
    }
}

/// Cold constructor for the one validation rejection that carries a
/// message — keeps the admission fast path allocation-free.
#[cold]
fn bad_budget() -> Reject {
    Reject::BadRequest("budget_ms must be finite, > 0, and <= 86400000 (24h)".into())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GatewayTenant, IsolationClass};
    use crate::coordinator::InferenceResponse;

    /// A scriptable synchronous backend: replies with a fixed verdict and
    /// counts submissions.
    struct FakeBackend {
        devices: usize,
        verdict: Option<Reject>,
        calls: u64,
        last_ctx: Option<RequestContext>,
    }

    impl FakeBackend {
        fn ok(devices: usize) -> Self {
            Self { devices, verdict: None, calls: 0, last_ctx: None }
        }

        fn rejecting(devices: usize, rej: Reject) -> Self {
            Self { devices, verdict: Some(rej), calls: 0, last_ctx: None }
        }
    }

    impl GatewayBackend for FakeBackend {
        fn devices(&self) -> usize {
            self.devices
        }

        fn device_of(&self, tenant: usize) -> usize {
            tenant % self.devices
        }

        fn submit(&mut self, ctx: RequestContext, _payload: Vec<HostTensor>) -> BackendReply {
            self.calls += 1;
            self.last_ctx = Some(ctx);
            match &self.verdict {
                Some(rej) => BackendReply::Ready(Err(rej.clone())),
                None => BackendReply::Ready(Ok(InferenceResponse {
                    id: self.calls,
                    tenant: ctx.tenant,
                    output: HostTensor { shape: vec![1], data: vec![0.0] },
                    latency_s: 0.001,
                    service_s: 0.001,
                    fused_r: 1,
                    trace_id: ctx.trace_id,
                })),
            }
        }
    }

    fn cfg() -> GatewayConfig {
        GatewayConfig {
            rate: 10.0,
            burst: 2.0,
            breaker_window: 4,
            breaker_threshold: 0.5,
            breaker_cooldown_ms: 100.0,
            half_open_probes: 1,
            tenants: vec![GatewayTenant {
                api_key: "k0".into(),
                tenant: 0,
                class: IsolationClass::Standard,
            }],
            ..GatewayConfig::default()
        }
    }

    fn wire(key: &str) -> WireRequest<'_> {
        WireRequest { api_key: key, budget_ms: None, priority: None, trace_id: 0 }
    }

    #[test]
    fn layers_reject_in_order() {
        let t0 = Instant::now();
        let mut g = Gateway::new(&cfg(), FakeBackend::ok(1));
        // Unknown key: auth, before any token is spent.
        assert_eq!(g.admit(&wire("nope"), vec![], t0).unwrap_err(), Reject::AuthFailed);
        assert_eq!(g.auth_failures(), 1);
        // Malformed budget: validation, before the bucket.
        let bad = WireRequest { budget_ms: Some(-1.0), ..wire("k0") };
        assert!(matches!(g.admit(&bad, vec![], t0), Err(Reject::BadRequest(_))));
        // Two tokens of burst pass, the third is rate limited with a hint.
        assert!(g.admit(&wire("k0"), vec![], t0).is_ok());
        assert!(g.admit(&wire("k0"), vec![], t0).is_ok());
        match g.admit(&wire("k0"), vec![], t0) {
            Err(Reject::RateLimited { retry_after }) => {
                assert!((retry_after.as_secs_f64() - 0.1).abs() < 1e-9);
            }
            other => panic!("expected RateLimited, got {:?}", other.map(|t| t.device)),
        }
        let s = g.stats();
        assert_eq!((s.admitted, s.rate_limited, s.bad_requests), (2, 1, 1));
        assert_eq!(g.backend().calls, 2);
    }

    #[test]
    fn huge_budget_is_a_bad_request_not_a_panic() {
        let t0 = Instant::now();
        let mut g = Gateway::new(&cfg(), FakeBackend::ok(1));
        // 1e300 ms is finite and > 0 but would panic in
        // Duration::from_secs_f64; the ceiling catches it first.
        for ms in [1e300, MAX_BUDGET_MS * 2.0, f64::MAX] {
            let w = WireRequest { budget_ms: Some(ms), ..wire("k0") };
            assert!(matches!(g.admit(&w, vec![], t0), Err(Reject::BadRequest(_))));
        }
        assert_eq!(g.stats().bad_requests, 3);
        // The gateway keeps serving: exactly at the ceiling is fine.
        let w = WireRequest { budget_ms: Some(MAX_BUDGET_MS), ..wire("k0") };
        assert!(g.admit(&w, vec![], t0).is_ok());
    }

    #[test]
    fn wire_fields_land_in_the_context() {
        let t0 = Instant::now();
        let mut g = Gateway::new(&cfg(), FakeBackend::ok(1));
        let w = WireRequest {
            api_key: "k0",
            budget_ms: Some(7.0),
            priority: Some(Priority::Batch),
            trace_id: 42,
        };
        let ticket = g.admit(&w, vec![], t0).unwrap();
        let reply = g.wait(ticket, t0).unwrap();
        assert_eq!(reply.trace_id, 42);
        let ctx = g.backend().last_ctx.unwrap();
        assert_eq!(ctx.tenant, 0);
        assert_eq!(ctx.priority, Priority::Batch);
        assert_eq!(
            ctx.resolve_deadline(t0, Duration::from_secs(1)),
            t0 + Duration::from_millis(7)
        );
    }

    #[test]
    fn breaker_trips_and_sheds_without_backend_calls() {
        let t0 = Instant::now();
        let mut c = cfg();
        c.burst = 1000.0; // keep the bucket out of the way
        let mut g = Gateway::new(&c, FakeBackend::rejecting(1, Reject::Overloaded));
        // Four overload verdicts fill the window and trip the breaker.
        for _ in 0..4 {
            assert_eq!(g.admit(&wire("k0"), vec![], t0).unwrap_err(), Reject::Overloaded);
        }
        assert_eq!(g.breaker_state(0), BreakerState::Open);
        let calls_at_trip = g.backend().calls;
        // Open: sheds at the gateway; the backend is NOT called.
        match g.admit(&wire("k0"), vec![], t0).unwrap_err() {
            Reject::BreakerOpen { device, retry_after } => {
                assert_eq!(device, 0);
                assert!(retry_after <= Duration::from_millis(100));
            }
            other => panic!("expected BreakerOpen, got {other:?}"),
        }
        assert_eq!(g.backend().calls, calls_at_trip);
        assert_eq!(g.stats().breaker_shed, 1);
        // After the cooldown the shard has recovered: one clean probe
        // closes the breaker (half_open_probes = 1).
        g.backend_mut().verdict = None;
        let t1 = t0 + Duration::from_millis(100);
        let ticket = g.admit(&wire("k0"), vec![], t1).unwrap();
        assert!(g.wait(ticket, t1).is_ok());
        assert_eq!(g.breaker_state(0), BreakerState::Closed);
    }

    #[test]
    fn status_json_reports_tokens_and_breakers() {
        let t0 = Instant::now();
        let mut g = Gateway::new(&cfg(), FakeBackend::ok(1));
        let _ = g.admit(&wire("k0"), vec![], t0);
        let _ = g.admit(&wire("missing"), vec![], t0);
        let j = g.status_json(t0);
        let tenants = j.get("tenants").and_then(Json::as_arr).unwrap();
        assert_eq!(tenants.len(), 1);
        assert_eq!(tenants[0].get("tenant").and_then(Json::as_f64), Some(0.0));
        // One of the two burst tokens is spent.
        assert!((tenants[0].get("tokens").and_then(Json::as_f64).unwrap() - 1.0).abs() < 1e-9);
        let breakers = j.get("breakers").and_then(Json::as_arr).unwrap();
        assert_eq!(breakers.len(), 1);
        assert_eq!(breakers[0].get("state").and_then(Json::as_str), Some("closed"));
        assert_eq!(j.get("auth_failures").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("admitted").and_then(Json::as_f64), Some(1.0));
    }
}
