//! The gateway's connection/reactor layer: a non-blocking accept loop
//! feeding a fixed pool of worker threads (no async runtime is vendored
//! offline — same constraint as [`crate::server::status`], same idiom).
//!
//! The acceptor thread polls a non-blocking [`TcpListener`] and hands
//! accepted connections to workers over an mpsc queue; each worker
//! speaks a line-delimited JSON protocol — one request object per line,
//! one response object per line, connections are kept alive across
//! requests. The reactor is transport-only: it is generic over a
//! `Fn(&str) -> String` handler, and [`gateway_handler`] adapts a
//! [`Gateway`] (auth → validation → rate limit → breaker → admission)
//! into that shape.
//!
//! Wire request fields: `{"api_key": "...", "budget_ms": 12.5,
//! "priority": "high", "trace_id": 7}` — everything but `api_key` is
//! optional. Responses are either
//! `{"ok": true, "id": .., "tenant": .., "latency_ms": .., "trace_id": ..}`
//! or `{"ok": false, "error": <structured Reject JSON>}`.
//!
//! `trace_id` rides as a JSON number only while it is exactly
//! representable in an `f64` (< 2^53); larger 64-bit ids must be sent —
//! and are echoed back — as a decimal **string** (`"trace_id":
//! "18446744073709551615"`), so caller-chosen random u64 ids round-trip
//! bit-exactly. A numeric id at or above 2^53 is rejected as
//! `bad_request` rather than silently altered.
//!
//! Keep-alive connections each occupy one pool worker, so a connection
//! that stays idle past the configured idle timeout (no complete
//! request and no new bytes) is closed to let queued connections in.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::{Priority, Reject};
use crate::runtime::HostTensor;
use crate::server::gateway::{Gateway, GatewayBackend, WireRequest};
use crate::util::json::Json;
use crate::util::sync::lock_recover;

/// Default idle-connection bound for [`Reactor::start`]; the serving CLI
/// threads `gateway.idle_timeout_ms` through [`Reactor::start_with`].
pub const DEFAULT_IDLE_TIMEOUT: Duration = Duration::from_secs(10);

/// Largest integer exactly representable in an `f64` (2^53): the bound
/// up to which a numeric JSON `trace_id` round-trips without precision
/// loss. Ids at or above it travel as decimal strings.
const TRACE_ID_NUM_MAX: u64 = 1 << 53;

/// Per-connection request handler: one request line in, one response
/// line out (without the trailing newline).
pub type Handler = dyn Fn(&str) -> String + Send + Sync;

/// The reactor: builder entry point. See [`Reactor::start`].
pub struct Reactor;

/// A running reactor; dropping (or [`ReactorHandle::stop`]) shuts it
/// down and joins every thread.
pub struct ReactorHandle {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Reactor {
    /// Bind `addr` (port 0 for ephemeral) and serve connections on
    /// `workers` pool threads, passing each request line to `handler`,
    /// with the [`DEFAULT_IDLE_TIMEOUT`] keep-alive bound.
    pub fn start(
        addr: impl ToSocketAddrs,
        workers: usize,
        handler: Arc<Handler>,
    ) -> std::io::Result<ReactorHandle> {
        Self::start_with(addr, workers, DEFAULT_IDLE_TIMEOUT, handler)
    }

    /// [`Reactor::start`] with an explicit idle-connection timeout: a
    /// keep-alive connection that produces no complete request and no
    /// new bytes for this long is closed, freeing its pool worker for
    /// queued connections.
    pub fn start_with(
        addr: impl ToSocketAddrs,
        workers: usize,
        idle_timeout: Duration,
        handler: Arc<Handler>,
    ) -> std::io::Result<ReactorHandle> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx): (Sender<TcpStream>, Receiver<TcpStream>) = channel();
        let rx = Arc::new(Mutex::new(rx));

        let mut pool = Vec::new();
        for i in 0..workers.max(1) {
            let rx = rx.clone();
            let handler = handler.clone();
            let stop = stop.clone();
            pool.push(
                std::thread::Builder::new()
                    .name(format!("stgpu-gw-worker-{i}"))
                    .spawn(move || worker_loop(&rx, &*handler, &stop, idle_timeout))?,
            );
        }

        let stop2 = stop.clone();
        let acceptor = std::thread::Builder::new()
            .name("stgpu-gw-acceptor".into())
            .spawn(move || {
                // `tx` moves in here: when the acceptor exits, the queue
                // sender drops and idle workers see the disconnect.
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((sock, _)) => {
                            // Workers poll the stop flag between reads.
                            let _ = sock.set_read_timeout(Some(Duration::from_millis(50)));
                            let _ = sock.set_nonblocking(false);
                            if tx.send(sock).is_err() {
                                break;
                            }
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;

        Ok(ReactorHandle { addr: local, stop, acceptor: Some(acceptor), workers: pool })
    }
}

fn worker_loop(
    rx: &Mutex<Receiver<TcpStream>>,
    handler: &Handler,
    stop: &AtomicBool,
    idle_timeout: Duration,
) {
    loop {
        // Hold the queue lock only for the dequeue, not for the whole
        // connection.
        let sock = {
            let guard = lock_recover(rx);
            guard.recv_timeout(Duration::from_millis(50))
        };
        match sock {
            Ok(sock) => serve_connection(sock, handler, stop, idle_timeout),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::Relaxed) {
                    return;
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
        }
    }
}

/// Serve one keep-alive connection: request line in, response line out,
/// until EOF, a write error, the idle timeout, or shutdown.
fn serve_connection(
    sock: TcpStream,
    handler: &Handler,
    stop: &AtomicBool,
    idle_timeout: Duration,
) {
    let mut writer = match sock.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(sock);
    let mut line = String::new();
    // Bytes of `line` already seen at the last activity check: lets a
    // slowly-trickling request count as activity without resetting the
    // idle clock for a buffer that is merely non-empty.
    let mut seen_len = 0usize;
    let mut last_activity = Instant::now();
    while !stop.load(Ordering::Relaxed) {
        match reader.read_line(&mut line) {
            Ok(0) => return, // EOF
            Ok(_) => {
                let trimmed = line.trim();
                if !trimmed.is_empty() {
                    let resp = handler(trimmed);
                    if writer.write_all(resp.as_bytes()).is_err()
                        || writer.write_all(b"\n").is_err()
                    {
                        return;
                    }
                }
                // Only a COMPLETE line retires the buffer. On the
                // timeout path below, `read_line` has already appended
                // any partially-read bytes (read_until's contract), and
                // clearing there would corrupt a request that straddles
                // a timeout boundary.
                line.clear();
                seen_len = 0;
                last_activity = Instant::now();
            }
            // Read timeout: keep the partial buffer, re-check the stop
            // flag and the idle clock, and keep accumulating.
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if line.len() > seen_len {
                    seen_len = line.len();
                    last_activity = Instant::now();
                }
                if last_activity.elapsed() >= idle_timeout {
                    return;
                }
            }
            Err(_) => return,
        }
    }
}

impl ReactorHandle {
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.acceptor.take() {
            let _ = t.join();
        }
        for t in self.workers.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for ReactorHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Adapt a [`Gateway`] into a reactor [`Handler`]: decode the wire JSON,
/// run the admission stack, wait for the backend reply, and encode the
/// response. `payload_for` supplies the model-input tensors for an
/// authenticated tenant (the wire carries request metadata, not
/// activations — the serving CLI generates payloads from the tenant's
/// configured shape, exactly like the driver path).
pub fn gateway_handler<B: GatewayBackend + Send + 'static>(
    gateway: Arc<Mutex<Gateway<B>>>,
    payload_for: Arc<dyn Fn(usize) -> Vec<HostTensor> + Send + Sync>,
) -> Arc<Handler> {
    Arc::new(move |line: &str| {
        let reply = handle_line(&gateway, &payload_for, line);
        let json = match reply {
            Ok(ok) => ok,
            Err(rej) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", rej.to_json()),
            ]),
        };
        json.to_string()
    })
}

fn handle_line<B: GatewayBackend>(
    gateway: &Mutex<Gateway<B>>,
    payload_for: &(dyn Fn(usize) -> Vec<HostTensor> + Send + Sync),
    line: &str,
) -> Result<Json, Reject> {
    let req = Json::parse(line).map_err(|e| Reject::BadRequest(format!("bad json: {e}")))?;
    let api_key = req
        .get("api_key")
        .and_then(Json::as_str)
        .ok_or_else(|| Reject::BadRequest("missing api_key".into()))?;
    let budget_ms = req.get("budget_ms").and_then(Json::as_f64);
    let priority = match req.get("priority").and_then(Json::as_str) {
        None => None,
        Some(p) => Some(
            Priority::parse(p)
                .ok_or_else(|| Reject::BadRequest(format!("unknown priority {p:?}")))?,
        ),
    };
    let trace_id = decode_trace_id(&req)?;
    let wire = WireRequest { api_key, budget_ms, priority, trace_id };

    // The gateway lock is held for the cheap admission stack only —
    // NEVER across the blocking wait for the backend reply, so one
    // in-flight request can't serialize the other workers' (or the
    // status endpoint's) auth/rate-limit/breaker verdicts behind it.
    let tenant = {
        let mut gw = lock_recover(gateway);
        match gw.peek_tenant(api_key) {
            Some(t) => t,
            None => {
                // Let admit() record the auth failure.
                let now = Instant::now();
                return match gw.admit(&wire, Vec::new(), now) {
                    Err(rej) => Err(rej),
                    Ok(_) => unreachable!("unknown key cannot admit"),
                };
            }
        }
    };
    // Payload generation is also lock-free: only admit() needs the
    // gateway.
    let payload = payload_for(tenant);
    let ticket = lock_recover(gateway).admit(&wire, payload, Instant::now())?;
    // Blocking wait with the lock RELEASED; re-lock briefly to feed the
    // breaker the outcome.
    let (outcome, out) = ticket.into_reply();
    lock_recover(gateway).finish(outcome, &out, Instant::now());
    let res = out?;
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("id", Json::num(res.id as f64)),
        ("tenant", Json::num(res.tenant as f64)),
        ("latency_ms", Json::num(res.latency_s * 1e3)),
        ("trace_id", trace_id_json(res.trace_id)),
    ]))
}

/// Decode the wire `trace_id`: a JSON number for ids below 2^53 (the
/// f64-exact range — the JSON parser stores numbers as `f64`, so larger
/// numerics would be silently rounded and break client correlation), or
/// a decimal string for full-range u64 ids. Absent means 0.
fn decode_trace_id(req: &Json) -> Result<u64, Reject> {
    match req.get("trace_id") {
        None | Some(Json::Null) => Ok(0),
        Some(Json::Str(s)) => s
            .parse::<u64>()
            .map_err(|_| Reject::BadRequest(format!("trace_id string must be a u64, got {s:?}"))),
        Some(v) => {
            let f = v
                .as_f64()
                .ok_or_else(|| Reject::BadRequest("trace_id must be an integer or string".into()))?;
            if !(f.is_finite() && f >= 0.0 && f.fract() == 0.0 && f < TRACE_ID_NUM_MAX as f64) {
                return Err(Reject::BadRequest(
                    "numeric trace_id must be an integer in [0, 2^53); send larger ids as a string"
                        .into(),
                ));
            }
            Ok(f as u64)
        }
    }
}

/// Encode a `trace_id` for the response: number while exact in f64,
/// decimal string beyond — whichever form round-trips bit-exactly.
fn trace_id_json(id: u64) -> Json {
    if id < TRACE_ID_NUM_MAX {
        Json::num(id as f64)
    } else {
        Json::Str(id.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GatewayConfig, GatewayTenant, IsolationClass};
    use crate::coordinator::{InferenceResponse, RequestContext};
    use crate::server::gateway::BackendReply;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    #[test]
    fn echo_round_trip_and_keep_alive() {
        let handler: Arc<Handler> = Arc::new(|line: &str| format!("echo:{line}"));
        let r = Reactor::start("127.0.0.1:0", 2, handler).expect("bind");
        let sock = TcpStream::connect(r.addr()).expect("connect");
        let mut reader = BufReader::new(sock.try_clone().unwrap());
        let mut w = sock;
        // Two requests on ONE connection: the reactor keeps it alive.
        for i in 0..2 {
            w.write_all(format!("ping{i}\n").as_bytes()).unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            assert_eq!(resp.trim(), format!("echo:ping{i}"));
        }
        r.stop();
    }

    #[test]
    fn request_straddling_a_read_timeout_is_reassembled() {
        let handler: Arc<Handler> = Arc::new(|line: &str| format!("echo:{line}"));
        let r = Reactor::start("127.0.0.1:0", 1, handler).expect("bind");
        let sock = TcpStream::connect(r.addr()).expect("connect");
        let mut reader = BufReader::new(sock.try_clone().unwrap());
        let mut w = sock;
        // First half, then a pause well past the worker's 50ms read
        // timeout, then the rest: the partial bytes must survive the
        // timeout (not be discarded with the cleared buffer).
        w.write_all(b"pi").unwrap();
        w.flush().unwrap();
        std::thread::sleep(Duration::from_millis(150));
        w.write_all(b"ng\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        assert_eq!(resp.trim(), "echo:ping");
        r.stop();
    }

    #[test]
    fn idle_connections_are_closed_after_the_timeout() {
        let handler: Arc<Handler> = Arc::new(|line: &str| format!("echo:{line}"));
        let r = Reactor::start_with("127.0.0.1:0", 1, Duration::from_millis(200), handler)
            .expect("bind");
        let sock = TcpStream::connect(r.addr()).expect("connect");
        let mut reader = BufReader::new(sock.try_clone().unwrap());
        let mut w = sock;
        // The connection works while active...
        w.write_all(b"hi\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        assert_eq!(resp.trim(), "echo:hi");
        // ...then the worker hangs up once it sits idle, freeing the
        // pool slot (EOF on our side).
        let mut eof = String::new();
        assert_eq!(reader.read_line(&mut eof).unwrap(), 0);
        r.stop();
    }

    #[test]
    fn concurrent_connections_are_served_by_the_pool() {
        let handler: Arc<Handler> = Arc::new(|line: &str| line.to_uppercase());
        let r = Reactor::start("127.0.0.1:0", 4, handler).expect("bind");
        let addr = r.addr();
        let threads: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let sock = TcpStream::connect(addr).expect("connect");
                    let mut reader = BufReader::new(sock.try_clone().unwrap());
                    let mut w = sock;
                    w.write_all(format!("req{i}\n").as_bytes()).unwrap();
                    let mut resp = String::new();
                    reader.read_line(&mut resp).unwrap();
                    assert_eq!(resp.trim(), format!("REQ{i}"));
                })
            })
            .collect();
        for t in threads {
            t.join().expect("client thread");
        }
        r.stop();
    }

    /// Synchronous always-OK backend for protocol tests.
    struct OkBackend {
        calls: u64,
    }

    impl GatewayBackend for OkBackend {
        fn devices(&self) -> usize {
            1
        }

        fn device_of(&self, _tenant: usize) -> usize {
            0
        }

        fn submit(&mut self, ctx: RequestContext, _payload: Vec<HostTensor>) -> BackendReply {
            self.calls += 1;
            BackendReply::Ready(Ok(InferenceResponse {
                id: self.calls,
                tenant: ctx.tenant,
                output: HostTensor { shape: vec![1], data: vec![0.0] },
                latency_s: 0.002,
                service_s: 0.002,
                fused_r: 1,
                trace_id: ctx.trace_id,
            }))
        }
    }

    #[test]
    fn gateway_handler_speaks_the_wire_protocol() {
        let cfg = GatewayConfig {
            rate: 1000.0,
            burst: 1000.0,
            tenants: vec![GatewayTenant {
                api_key: "secret".into(),
                tenant: 0,
                class: IsolationClass::Standard,
            }],
            ..GatewayConfig::default()
        };
        let gw = Arc::new(Mutex::new(Gateway::new(&cfg, OkBackend { calls: 0 })));
        let handler = gateway_handler(gw.clone(), Arc::new(|_t| Vec::new()));
        let r = Reactor::start("127.0.0.1:0", 2, handler).expect("bind");
        let sock = TcpStream::connect(r.addr()).expect("connect");
        let mut reader = BufReader::new(sock.try_clone().unwrap());
        let mut w = sock;

        // A well-formed request completes and echoes the trace id.
        w.write_all(b"{\"api_key\":\"secret\",\"budget_ms\":50,\"trace_id\":9}\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let j = Json::parse(resp.trim()).expect("response json");
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("trace_id").and_then(Json::as_f64), Some(9.0));

        // A bad key is rejected with the structured error and counted.
        w.write_all(b"{\"api_key\":\"wrong\"}\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let j = Json::parse(resp.trim()).expect("error json");
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(
            j.get("error").and_then(|e| e.get("error")).and_then(Json::as_str),
            Some("auth_failed")
        );

        // Malformed JSON is a bad_request, not a hangup.
        w.write_all(b"not json\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let j = Json::parse(resp.trim()).expect("error json");
        assert_eq!(
            j.get("error").and_then(|e| e.get("error")).and_then(Json::as_str),
            Some("bad_request")
        );

        r.stop();
        let g = gw.lock().unwrap();
        assert_eq!(g.stats().admitted, 1);
        assert_eq!(g.auth_failures(), 1);
    }

    #[test]
    fn trace_ids_round_trip_including_full_u64_range() {
        let cfg = GatewayConfig {
            rate: 1000.0,
            burst: 1000.0,
            tenants: vec![GatewayTenant {
                api_key: "secret".into(),
                tenant: 0,
                class: IsolationClass::Standard,
            }],
            ..GatewayConfig::default()
        };
        let gw = Arc::new(Mutex::new(Gateway::new(&cfg, OkBackend { calls: 0 })));
        let handler = gateway_handler(gw, Arc::new(|_t| Vec::new()));
        let call = &*handler;
        let big = u64::MAX - 1;

        // Numeric form: exact below 2^53, echoed as a number.
        let resp = call("{\"api_key\":\"secret\",\"trace_id\":12345}");
        let j = Json::parse(&resp).expect("response json");
        assert_eq!(j.get("trace_id").and_then(Json::as_f64), Some(12345.0));

        // String form: the full u64 range round-trips bit-exactly.
        let resp = call(&format!("{{\"api_key\":\"secret\",\"trace_id\":\"{big}\"}}"));
        let j = Json::parse(&resp).expect("response json");
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("trace_id").and_then(Json::as_str), Some(big.to_string().as_str()));

        // A numeric id at/above 2^53 would be silently rounded by the
        // f64 decode, so it is rejected rather than altered.
        let resp = call("{\"api_key\":\"secret\",\"trace_id\":9007199254740993}");
        let j = Json::parse(&resp).expect("error json");
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(
            j.get("error").and_then(|e| e.get("error")).and_then(Json::as_str),
            Some("bad_request")
        );

        // Garbage string ids are rejected too.
        let resp = call("{\"api_key\":\"secret\",\"trace_id\":\"not-a-number\"}");
        let j = Json::parse(&resp).expect("error json");
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
    }

    /// Backend that parks every submission as a pending reply the test
    /// completes by hand — models the production threaded frontend.
    struct ParkedBackend {
        parked: Arc<Mutex<Vec<(RequestContext, std::sync::mpsc::Sender<crate::server::frontend::Reply>)>>>,
    }

    impl GatewayBackend for ParkedBackend {
        fn devices(&self) -> usize {
            1
        }

        fn device_of(&self, _tenant: usize) -> usize {
            0
        }

        fn submit(&mut self, ctx: RequestContext, _payload: Vec<HostTensor>) -> BackendReply {
            let (tx, rx) = std::sync::mpsc::channel();
            self.parked.lock().unwrap().push((ctx, tx));
            BackendReply::Pending(rx)
        }
    }

    #[test]
    fn gateway_lock_is_released_while_a_reply_is_pending() {
        let cfg = GatewayConfig {
            rate: 1000.0,
            burst: 1000.0,
            tenants: vec![GatewayTenant {
                api_key: "secret".into(),
                tenant: 0,
                class: IsolationClass::Standard,
            }],
            ..GatewayConfig::default()
        };
        let parked = Arc::new(Mutex::new(Vec::new()));
        let gw = Arc::new(Mutex::new(Gateway::new(&cfg, ParkedBackend { parked: parked.clone() })));
        let handler = gateway_handler(gw.clone(), Arc::new(|_t| Vec::new()));
        let r = Reactor::start("127.0.0.1:0", 2, handler).expect("bind");

        // Connection A: a request whose backend reply we hold parked.
        let sock_a = TcpStream::connect(r.addr()).expect("connect a");
        let mut reader_a = BufReader::new(sock_a.try_clone().unwrap());
        let mut wa = sock_a;
        wa.write_all(b"{\"api_key\":\"secret\",\"trace_id\":7}\n").unwrap();
        // Wait until A's request has actually been admitted and parked.
        let deadline = Instant::now() + Duration::from_secs(5);
        while parked.lock().unwrap().is_empty() {
            assert!(Instant::now() < deadline, "request never reached the backend");
            std::thread::sleep(Duration::from_millis(5));
        }

        // Connection B: with A still in flight, a cheap rejection must
        // complete — the worker serving A may NOT be holding the
        // gateway lock across its blocking wait.
        let sock_b = TcpStream::connect(r.addr()).expect("connect b");
        sock_b.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut reader_b = BufReader::new(sock_b.try_clone().unwrap());
        let mut wb = sock_b;
        wb.write_all(b"{\"api_key\":\"wrong\"}\n").unwrap();
        let mut resp_b = String::new();
        reader_b.read_line(&mut resp_b).expect("b served while a pending");
        let j = Json::parse(resp_b.trim()).expect("b json");
        assert_eq!(
            j.get("error").and_then(|e| e.get("error")).and_then(Json::as_str),
            Some("auth_failed")
        );

        // Release A and check the reply (with its breaker outcome)
        // still lands.
        let (ctx, tx) = parked.lock().unwrap().pop().unwrap();
        tx.send(Ok(InferenceResponse {
            id: 1,
            tenant: ctx.tenant,
            output: HostTensor { shape: vec![1], data: vec![0.0] },
            latency_s: 0.002,
            service_s: 0.002,
            fused_r: 1,
            trace_id: ctx.trace_id,
        }))
        .unwrap();
        let mut resp_a = String::new();
        reader_a.read_line(&mut resp_a).unwrap();
        let j = Json::parse(resp_a.trim()).expect("a json");
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("trace_id").and_then(Json::as_f64), Some(7.0));

        r.stop();
        assert_eq!(gw.lock().unwrap().stats().admitted, 1);
    }
}
