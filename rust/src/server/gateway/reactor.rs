//! The gateway's connection/reactor layer: a non-blocking accept loop
//! feeding a fixed pool of worker threads (no async runtime is vendored
//! offline — same constraint as [`crate::server::status`], same idiom).
//!
//! The acceptor thread polls a non-blocking [`TcpListener`] and hands
//! accepted connections to workers over an mpsc queue; each worker
//! speaks a line-delimited JSON protocol — one request object per line,
//! one response object per line, connections are kept alive across
//! requests. The reactor is transport-only: it is generic over a
//! `Fn(&str) -> String` handler, and [`gateway_handler`] adapts a
//! [`Gateway`] (auth → validation → rate limit → breaker → admission)
//! into that shape.
//!
//! Wire request fields: `{"api_key": "...", "budget_ms": 12.5,
//! "priority": "high", "trace_id": 7}` — everything but `api_key` is
//! optional. Responses are either
//! `{"ok": true, "id": .., "tenant": .., "latency_ms": .., "trace_id": ..}`
//! or `{"ok": false, "error": <structured Reject JSON>}`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::{Priority, Reject};
use crate::runtime::HostTensor;
use crate::server::gateway::{Gateway, GatewayBackend, WireRequest};
use crate::util::json::Json;

/// Per-connection request handler: one request line in, one response
/// line out (without the trailing newline).
pub type Handler = dyn Fn(&str) -> String + Send + Sync;

/// The reactor: builder entry point. See [`Reactor::start`].
pub struct Reactor;

/// A running reactor; dropping (or [`ReactorHandle::stop`]) shuts it
/// down and joins every thread.
pub struct ReactorHandle {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Reactor {
    /// Bind `addr` (port 0 for ephemeral) and serve connections on
    /// `workers` pool threads, passing each request line to `handler`.
    pub fn start(
        addr: impl ToSocketAddrs,
        workers: usize,
        handler: Arc<Handler>,
    ) -> std::io::Result<ReactorHandle> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx): (Sender<TcpStream>, Receiver<TcpStream>) = channel();
        let rx = Arc::new(Mutex::new(rx));

        let mut pool = Vec::new();
        for i in 0..workers.max(1) {
            let rx = rx.clone();
            let handler = handler.clone();
            let stop = stop.clone();
            pool.push(
                std::thread::Builder::new()
                    .name(format!("stgpu-gw-worker-{i}"))
                    .spawn(move || worker_loop(&rx, &*handler, &stop))?,
            );
        }

        let stop2 = stop.clone();
        let acceptor = std::thread::Builder::new()
            .name("stgpu-gw-acceptor".into())
            .spawn(move || {
                // `tx` moves in here: when the acceptor exits, the queue
                // sender drops and idle workers see the disconnect.
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((sock, _)) => {
                            // Workers poll the stop flag between reads.
                            let _ = sock.set_read_timeout(Some(Duration::from_millis(50)));
                            let _ = sock.set_nonblocking(false);
                            if tx.send(sock).is_err() {
                                break;
                            }
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;

        Ok(ReactorHandle { addr: local, stop, acceptor: Some(acceptor), workers: pool })
    }
}

fn worker_loop(rx: &Mutex<Receiver<TcpStream>>, handler: &Handler, stop: &AtomicBool) {
    loop {
        // Hold the queue lock only for the dequeue, not for the whole
        // connection.
        let sock = {
            let guard = rx.lock().expect("reactor queue poisoned");
            guard.recv_timeout(Duration::from_millis(50))
        };
        match sock {
            Ok(sock) => serve_connection(sock, handler, stop),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::Relaxed) {
                    return;
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
        }
    }
}

/// Serve one keep-alive connection: request line in, response line out,
/// until EOF, a write error, or shutdown.
fn serve_connection(sock: TcpStream, handler: &Handler, stop: &AtomicBool) {
    let mut writer = match sock.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(sock);
    let mut line = String::new();
    while !stop.load(Ordering::Relaxed) {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return, // EOF
            Ok(_) => {
                let trimmed = line.trim();
                if trimmed.is_empty() {
                    continue;
                }
                let resp = handler(trimmed);
                if writer.write_all(resp.as_bytes()).is_err()
                    || writer.write_all(b"\n").is_err()
                {
                    return;
                }
            }
            // Read timeout: re-check the stop flag and keep waiting.
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => return,
        }
    }
}

impl ReactorHandle {
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.acceptor.take() {
            let _ = t.join();
        }
        for t in self.workers.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for ReactorHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Adapt a [`Gateway`] into a reactor [`Handler`]: decode the wire JSON,
/// run the admission stack, wait for the backend reply, and encode the
/// response. `payload_for` supplies the model-input tensors for an
/// authenticated tenant (the wire carries request metadata, not
/// activations — the serving CLI generates payloads from the tenant's
/// configured shape, exactly like the driver path).
pub fn gateway_handler<B: GatewayBackend + Send + 'static>(
    gateway: Arc<Mutex<Gateway<B>>>,
    payload_for: Arc<dyn Fn(usize) -> Vec<HostTensor> + Send + Sync>,
) -> Arc<Handler> {
    Arc::new(move |line: &str| {
        let reply = handle_line(&gateway, &payload_for, line);
        let json = match reply {
            Ok(ok) => ok,
            Err(rej) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", rej.to_json()),
            ]),
        };
        json.to_string()
    })
}

fn handle_line<B: GatewayBackend>(
    gateway: &Mutex<Gateway<B>>,
    payload_for: &(dyn Fn(usize) -> Vec<HostTensor> + Send + Sync),
    line: &str,
) -> Result<Json, Reject> {
    let req = Json::parse(line).map_err(|e| Reject::BadRequest(format!("bad json: {e}")))?;
    let api_key = req
        .get("api_key")
        .and_then(Json::as_str)
        .ok_or_else(|| Reject::BadRequest("missing api_key".into()))?;
    let budget_ms = req.get("budget_ms").and_then(Json::as_f64);
    let priority = match req.get("priority").and_then(Json::as_str) {
        None => None,
        Some(p) => Some(
            Priority::parse(p)
                .ok_or_else(|| Reject::BadRequest(format!("unknown priority {p:?}")))?,
        ),
    };
    let trace_id = req.get("trace_id").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let wire = WireRequest { api_key, budget_ms, priority, trace_id };

    // Admission holds the gateway lock; the (possibly blocking) wait for
    // the backend reply does too — per-request replies are matched to
    // their ticket, and the simulated backend's submit is itself
    // synchronous, so the lock is the ordering domain. The worker pool
    // provides the connection-level concurrency.
    let mut gw = gateway.lock().expect("gateway poisoned");
    let tenant = match gw.peek_tenant(api_key) {
        Some(t) => t,
        None => {
            // Let admit() record the auth failure.
            let now = Instant::now();
            return match gw.admit(&wire, Vec::new(), now) {
                Err(rej) => Err(rej),
                Ok(_) => unreachable!("unknown key cannot admit"),
            };
        }
    };
    let payload = payload_for(tenant);
    let now = Instant::now();
    let ticket = gw.admit(&wire, payload, now)?;
    let res = gw.wait(ticket, Instant::now())?;
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("id", Json::num(res.id as f64)),
        ("tenant", Json::num(res.tenant as f64)),
        ("latency_ms", Json::num(res.latency_s * 1e3)),
        ("trace_id", Json::num(res.trace_id as f64)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GatewayConfig, GatewayTenant, IsolationClass};
    use crate::coordinator::{InferenceResponse, RequestContext};
    use crate::server::gateway::BackendReply;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    #[test]
    fn echo_round_trip_and_keep_alive() {
        let handler: Arc<Handler> = Arc::new(|line: &str| format!("echo:{line}"));
        let r = Reactor::start("127.0.0.1:0", 2, handler).expect("bind");
        let sock = TcpStream::connect(r.addr()).expect("connect");
        let mut reader = BufReader::new(sock.try_clone().unwrap());
        let mut w = sock;
        // Two requests on ONE connection: the reactor keeps it alive.
        for i in 0..2 {
            w.write_all(format!("ping{i}\n").as_bytes()).unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            assert_eq!(resp.trim(), format!("echo:ping{i}"));
        }
        r.stop();
    }

    #[test]
    fn concurrent_connections_are_served_by_the_pool() {
        let handler: Arc<Handler> = Arc::new(|line: &str| line.to_uppercase());
        let r = Reactor::start("127.0.0.1:0", 4, handler).expect("bind");
        let addr = r.addr();
        let threads: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let sock = TcpStream::connect(addr).expect("connect");
                    let mut reader = BufReader::new(sock.try_clone().unwrap());
                    let mut w = sock;
                    w.write_all(format!("req{i}\n").as_bytes()).unwrap();
                    let mut resp = String::new();
                    reader.read_line(&mut resp).unwrap();
                    assert_eq!(resp.trim(), format!("REQ{i}"));
                })
            })
            .collect();
        for t in threads {
            t.join().expect("client thread");
        }
        r.stop();
    }

    /// Synchronous always-OK backend for protocol tests.
    struct OkBackend {
        calls: u64,
    }

    impl GatewayBackend for OkBackend {
        fn devices(&self) -> usize {
            1
        }

        fn device_of(&self, _tenant: usize) -> usize {
            0
        }

        fn submit(&mut self, ctx: RequestContext, _payload: Vec<HostTensor>) -> BackendReply {
            self.calls += 1;
            BackendReply::Ready(Ok(InferenceResponse {
                id: self.calls,
                tenant: ctx.tenant,
                output: HostTensor { shape: vec![1], data: vec![0.0] },
                latency_s: 0.002,
                service_s: 0.002,
                fused_r: 1,
                trace_id: ctx.trace_id,
            }))
        }
    }

    #[test]
    fn gateway_handler_speaks_the_wire_protocol() {
        let cfg = GatewayConfig {
            rate: 1000.0,
            burst: 1000.0,
            tenants: vec![GatewayTenant {
                api_key: "secret".into(),
                tenant: 0,
                class: IsolationClass::Standard,
            }],
            ..GatewayConfig::default()
        };
        let gw = Arc::new(Mutex::new(Gateway::new(&cfg, OkBackend { calls: 0 })));
        let handler = gateway_handler(gw.clone(), Arc::new(|_t| Vec::new()));
        let r = Reactor::start("127.0.0.1:0", 2, handler).expect("bind");
        let sock = TcpStream::connect(r.addr()).expect("connect");
        let mut reader = BufReader::new(sock.try_clone().unwrap());
        let mut w = sock;

        // A well-formed request completes and echoes the trace id.
        w.write_all(b"{\"api_key\":\"secret\",\"budget_ms\":50,\"trace_id\":9}\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let j = Json::parse(resp.trim()).expect("response json");
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("trace_id").and_then(Json::as_f64), Some(9.0));

        // A bad key is rejected with the structured error and counted.
        w.write_all(b"{\"api_key\":\"wrong\"}\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let j = Json::parse(resp.trim()).expect("error json");
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(
            j.get("error").and_then(|e| e.get("error")).and_then(Json::as_str),
            Some("auth_failed")
        );

        // Malformed JSON is a bad_request, not a hangup.
        w.write_all(b"not json\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let j = Json::parse(resp.trim()).expect("error json");
        assert_eq!(
            j.get("error").and_then(|e| e.get("error")).and_then(Json::as_str),
            Some("bad_request")
        );

        r.stop();
        let g = gw.lock().unwrap();
        assert_eq!(g.stats().admitted, 1);
        assert_eq!(g.auth_failures(), 1);
    }
}
