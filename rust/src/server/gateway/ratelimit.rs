//! Per-tenant token-bucket rate limiting with burst credit.
//!
//! Each authenticated tenant owns one [`TokenBucket`]: tokens refill
//! continuously at `rate` per second up to a `burst` ceiling, and every
//! admitted request spends one token. A full bucket therefore absorbs a
//! `burst`-sized spike at line rate; sustained traffic is clamped to
//! `rate`. When the bucket is empty the gateway rejects with
//! [`crate::coordinator::Reject::RateLimited`] carrying the exact refill
//! time — clients that honor `retry_after` converge on the sustainable
//! rate instead of hammering the front door.
//!
//! All methods take `now` explicitly: the bucket never reads the clock,
//! so tests and the fig16 overload bench drive it on a virtual timeline.

use std::time::{Duration, Instant};

/// A continuous-refill token bucket.
#[derive(Debug, Clone)]
pub struct TokenBucket {
    /// Tokens added per second.
    rate: f64,
    /// Token ceiling (burst credit).
    burst: f64,
    /// Tokens at the instant `last` (refill is applied lazily).
    tokens: f64,
    /// When `tokens` was last settled; `None` until the first call, so
    /// construction needs no clock read.
    last: Option<Instant>,
}

impl TokenBucket {
    /// A full bucket. `rate` must be > 0; `burst` is clamped to >= 1 so a
    /// single request can always eventually pass.
    pub fn new(rate: f64, burst: f64) -> Self {
        let burst = burst.max(1.0);
        Self { rate: rate.max(f64::MIN_POSITIVE), burst, tokens: burst, last: None }
    }

    /// Settle the lazy refill up to `now`.
    // lint: hot-path
    fn refill(&mut self, now: Instant) {
        if let Some(last) = self.last {
            let dt = now.saturating_duration_since(last).as_secs_f64();
            self.tokens = (self.tokens + self.rate * dt).min(self.burst);
        }
        self.last = Some(now);
    }

    /// Spend one token, or report how long until one is available.
    // lint: hot-path
    pub fn try_take(&mut self, now: Instant) -> Result<(), Duration> {
        self.refill(now);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            Ok(())
        } else {
            Err(Duration::from_secs_f64((1.0 - self.tokens) / self.rate))
        }
    }

    /// Non-mutating view of the balance at `now` (status reporting).
    pub fn available(&self, now: Instant) -> f64 {
        match self.last {
            Some(last) => {
                let dt = now.saturating_duration_since(last).as_secs_f64();
                (self.tokens + self.rate * dt).min(self.burst)
            }
            None => self.burst,
        }
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    pub fn burst(&self) -> f64 {
        self.burst
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_then_rate_limit_then_refill() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(10.0, 3.0);
        // The full burst passes back-to-back...
        assert!(b.try_take(t0).is_ok());
        assert!(b.try_take(t0).is_ok());
        assert!(b.try_take(t0).is_ok());
        // ...the 4th is limited, with the exact refill hint (1 token at
        // 10/s = 100ms).
        let retry = b.try_take(t0).unwrap_err();
        assert!((retry.as_secs_f64() - 0.1).abs() < 1e-9, "{retry:?}");
        // Before the hint elapses: still limited.
        assert!(b.try_take(t0 + Duration::from_millis(50)).is_err());
        // At the hint: exactly one token has refilled.
        assert!(b.try_take(t0 + Duration::from_millis(100)).is_ok());
        assert!(b.try_take(t0 + Duration::from_millis(100)).is_err());
    }

    #[test]
    fn refill_is_capped_at_burst() {
        let t0 = Instant::now();
        let mut b = TokenBucket::new(1000.0, 2.0);
        assert!(b.try_take(t0).is_ok());
        // A long idle period refills to the cap, not beyond.
        let later = t0 + Duration::from_secs(60);
        assert!((b.available(later) - 2.0).abs() < 1e-9);
        assert!(b.try_take(later).is_ok());
        assert!(b.try_take(later).is_ok());
        assert!(b.try_take(later).is_err());
    }

    #[test]
    fn available_is_pure_and_full_before_first_use() {
        let b = TokenBucket::new(5.0, 7.0);
        assert_eq!(b.available(Instant::now()), 7.0);
        assert_eq!(b.burst(), 7.0);
        assert_eq!(b.rate(), 5.0);
    }
}
