//! API-key authentication: the validated `[gateway.tenants]` table.
//!
//! Keys are bound to tenant NAMES in config and resolved to tenant
//! indices at load time ([`crate::config::GatewayConfig`]), so the table
//! the gateway consults at admission is already index-checked — a lookup
//! either yields a [`Principal`] or fails with
//! [`crate::coordinator::Reject::AuthFailed`]. Lookup is a single hash
//! probe with no per-request allocation.

use std::collections::HashMap;

use crate::config::{GatewayConfig, IsolationClass};
use crate::coordinator::Priority;

/// The authenticated identity behind an API key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Principal {
    /// Tenant index (into the coordinator's tenant registry).
    pub tenant: usize,
    /// Isolation class: scales the rate-limit bucket and picks the
    /// default priority.
    pub class: IsolationClass,
}

impl Principal {
    /// The scheduling priority this principal's requests default to when
    /// the wire names none.
    pub fn default_priority(&self) -> Priority {
        match self.class {
            IsolationClass::Premium => Priority::High,
            IsolationClass::Standard => Priority::Normal,
            IsolationClass::Batch => Priority::Batch,
        }
    }
}

/// Immutable key → principal table built from the validated config.
#[derive(Debug, Default)]
pub struct AuthTable {
    keys: HashMap<String, Principal>,
    /// Lifetime failed-lookup count (status JSON).
    failures: u64,
}

impl AuthTable {
    pub fn from_config(cfg: &GatewayConfig) -> Self {
        let keys = cfg
            .tenants
            .iter()
            .map(|k| (k.api_key.clone(), Principal { tenant: k.tenant, class: k.class }))
            .collect();
        Self { keys, failures: 0 }
    }

    /// Authenticate one API key; a miss is counted.
    // lint: hot-path
    pub fn authenticate(&mut self, api_key: &str) -> Option<Principal> {
        match self.keys.get(api_key) {
            Some(p) => Some(*p),
            None => {
                self.failures += 1;
                None
            }
        }
    }

    /// Look a key up WITHOUT counting a failure — for transport layers
    /// that need the tenant (e.g. to build the payload) before the real
    /// authenticated admission runs.
    pub fn peek(&self, api_key: &str) -> Option<Principal> {
        self.keys.get(api_key).copied()
    }

    /// Lifetime failed authentications.
    pub fn failures(&self) -> u64 {
        self.failures
    }

    /// Every principal in the table, sorted by tenant index (for building
    /// per-tenant gateway state deterministically).
    pub fn principals(&self) -> Vec<Principal> {
        let mut out: Vec<Principal> = self.keys.values().copied().collect();
        out.sort_by_key(|p| p.tenant);
        out
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GatewayTenant;

    fn cfg() -> GatewayConfig {
        GatewayConfig {
            tenants: vec![
                GatewayTenant {
                    api_key: "key-prem".into(),
                    tenant: 0,
                    class: IsolationClass::Premium,
                },
                GatewayTenant {
                    api_key: "key-batch".into(),
                    tenant: 1,
                    class: IsolationClass::Batch,
                },
            ],
            ..GatewayConfig::default()
        }
    }

    #[test]
    fn known_keys_resolve_and_misses_count() {
        let mut t = AuthTable::from_config(&cfg());
        assert_eq!(t.len(), 2);
        let p = t.authenticate("key-prem").expect("known key");
        assert_eq!(p.tenant, 0);
        assert_eq!(p.class, IsolationClass::Premium);
        assert_eq!(p.default_priority(), Priority::High);
        let b = t.authenticate("key-batch").unwrap();
        assert_eq!(b.default_priority(), Priority::Batch);
        assert_eq!(t.failures(), 0);
        assert!(t.authenticate("nope").is_none());
        assert!(t.authenticate("").is_none());
        assert_eq!(t.failures(), 2);
    }

    #[test]
    fn principals_sorted_by_tenant() {
        let t = AuthTable::from_config(&cfg());
        let ps = t.principals();
        assert_eq!(ps.len(), 2);
        assert!(ps[0].tenant <= ps[1].tenant);
    }
}
