//! Per-shard circuit breakers: trip on sustained overload rejections,
//! shed at the gateway while open, and probe back via half-open.
//!
//! One [`CircuitBreaker`] guards each device shard. Admission outcomes
//! feed a sliding window; when the overload fraction
//! ([`crate::coordinator::Reject::is_overload`]: `Overloaded` /
//! `DeadlineInfeasible`) of a full window reaches the trip threshold the
//! breaker opens and the gateway rejects with
//! [`crate::coordinator::Reject::BreakerOpen`] WITHOUT touching
//! coordinator queues — the shard gets its cooldown without also paying
//! the admission traffic that tripped it. After the cooldown the breaker
//! half-opens: a bounded number of probe requests pass through, and the
//! breaker closes only when all of them come back clean (any overload
//! outcome re-opens it for another cooldown).
//!
//! All transitions take `now` explicitly — no hidden clock — so the
//! trip/half-open/close cycle is deterministic under test and in the
//! fig16 virtual-time overload sweep.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Breaker position, in the classic three-state protocol.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Traffic flows; outcomes are being watched.
    Closed,
    /// Shedding at the gateway until the cooldown elapses.
    Open,
    /// Cooldown over: letting a few probes through to test the shard.
    HalfOpen,
}

impl BreakerState {
    /// Stable wire name (status JSON).
    pub fn as_str(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half_open",
        }
    }
}

/// Circuit breaker for one device shard.
#[derive(Debug)]
pub struct CircuitBreaker {
    /// Sliding outcome window size (admissions observed while closed).
    window: usize,
    /// Overload fraction of a FULL window that trips the breaker.
    threshold: f64,
    /// How long a tripped breaker sheds before half-opening.
    cooldown: Duration,
    /// Clean probes required to close from half-open.
    probes_to_close: u32,
    state: BreakerState,
    /// Outcomes while closed: `true` = overload rejection.
    outcomes: VecDeque<bool>,
    /// Overload count inside `outcomes` (kept in step, O(1) updates).
    overloads: usize,
    /// When the breaker last opened.
    opened_at: Option<Instant>,
    /// Probes admitted this half-open episode.
    probes_issued: u32,
    /// Clean probe outcomes this half-open episode.
    probes_ok: u32,
    /// Lifetime trip count (status/metrics).
    trips: u64,
}

impl CircuitBreaker {
    pub fn new(
        window: usize,
        threshold: f64,
        cooldown: Duration,
        probes_to_close: u32,
    ) -> Self {
        Self {
            window: window.max(1),
            threshold: threshold.clamp(f64::MIN_POSITIVE, 1.0),
            cooldown,
            probes_to_close: probes_to_close.max(1),
            state: BreakerState::Closed,
            outcomes: VecDeque::with_capacity(window.max(1)),
            overloads: 0,
            opened_at: None,
            probes_issued: 0,
            probes_ok: 0,
            trips: 0,
        }
    }

    /// May a request pass right now? `Err` carries the remaining cooldown
    /// (the `retry_after` hint for [`crate::coordinator::Reject::BreakerOpen`]).
    // lint: hot-path
    pub fn allow(&mut self, now: Instant) -> Result<(), Duration> {
        match self.state {
            BreakerState::Closed => Ok(()),
            BreakerState::Open => {
                let opened = self.opened_at.expect("open breaker has a trip instant");
                let elapsed = now.saturating_duration_since(opened);
                if elapsed >= self.cooldown {
                    // Cooldown over: half-open and admit this caller as the
                    // first probe.
                    self.state = BreakerState::HalfOpen;
                    self.probes_issued = 1;
                    self.probes_ok = 0;
                    Ok(())
                } else {
                    Err(self.cooldown - elapsed)
                }
            }
            BreakerState::HalfOpen => {
                if self.probes_issued < self.probes_to_close {
                    self.probes_issued += 1;
                    Ok(())
                } else {
                    // Probe quota in flight: hold further traffic for the
                    // probes' verdict rather than stampeding the shard.
                    Err(self.cooldown)
                }
            }
        }
    }

    /// Record the admission outcome of a request this breaker allowed.
    /// `overload` is [`crate::coordinator::Reject::is_overload`] for
    /// rejections and `false` for accepted requests.
    // lint: hot-path
    pub fn record(&mut self, overload: bool, now: Instant) {
        match self.state {
            BreakerState::Closed => {
                self.outcomes.push_back(overload);
                if overload {
                    self.overloads += 1;
                }
                if self.outcomes.len() > self.window
                    && self.outcomes.pop_front() == Some(true)
                {
                    self.overloads -= 1;
                }
                let full = self.outcomes.len() >= self.window;
                if full && self.overloads as f64 >= self.threshold * self.window as f64 {
                    self.trip(now);
                }
            }
            BreakerState::HalfOpen => {
                if overload {
                    // The shard is still drowning: re-open for another
                    // full cooldown.
                    self.trip(now);
                } else {
                    self.probes_ok += 1;
                    if self.probes_ok >= self.probes_to_close {
                        self.state = BreakerState::Closed;
                        self.opened_at = None;
                    }
                }
            }
            // A straggler completion from before the trip: the open
            // breaker's verdict doesn't change.
            BreakerState::Open => {}
        }
    }

    // lint: hot-path
    fn trip(&mut self, now: Instant) {
        self.state = BreakerState::Open;
        self.opened_at = Some(now);
        self.outcomes.clear();
        self.overloads = 0;
        self.probes_issued = 0;
        self.probes_ok = 0;
        self.trips += 1;
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// Lifetime trip count.
    pub fn trips(&self) -> u64 {
        self.trips
    }

    /// Overload fraction of the current closed-state window (0 when the
    /// window is empty or the breaker is not closed).
    pub fn window_overload_frac(&self) -> f64 {
        if self.outcomes.is_empty() {
            0.0
        } else {
            self.overloads as f64 / self.outcomes.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn breaker() -> CircuitBreaker {
        // window 4, trip at >= 50%, 100ms cooldown, 2 probes to close.
        CircuitBreaker::new(4, 0.5, Duration::from_millis(100), 2)
    }

    #[test]
    fn trips_only_on_a_full_window_at_threshold() {
        let t0 = Instant::now();
        let mut b = breaker();
        // Three overloads in a row: window not full yet, still closed.
        for _ in 0..3 {
            assert!(b.allow(t0).is_ok());
            b.record(true, t0);
        }
        assert_eq!(b.state(), BreakerState::Closed);
        // Fourth outcome fills the window at 100% overload: trip.
        assert!(b.allow(t0).is_ok());
        b.record(true, t0);
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.trips(), 1);
        // Open: shed with the remaining cooldown as the hint.
        let retry = b.allow(t0 + Duration::from_millis(40)).unwrap_err();
        assert!((retry.as_secs_f64() - 0.060).abs() < 1e-9, "{retry:?}");
    }

    #[test]
    fn healthy_window_never_trips() {
        let t0 = Instant::now();
        let mut b = breaker();
        for i in 0..64 {
            assert!(b.allow(t0).is_ok());
            // 25% overload: under the 50% threshold.
            b.record(i % 4 == 0, t0);
        }
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.window_overload_frac() <= 0.5);
    }

    #[test]
    fn half_open_probes_close_or_reopen() {
        let t0 = Instant::now();
        let mut b = breaker();
        for _ in 0..4 {
            b.allow(t0).unwrap();
            b.record(true, t0);
        }
        assert_eq!(b.state(), BreakerState::Open);
        // Cooldown elapses: the next caller is probe #1.
        let t1 = t0 + Duration::from_millis(100);
        assert!(b.allow(t1).is_ok());
        assert_eq!(b.state(), BreakerState::HalfOpen);
        // Probe #2 passes; probe #3 is held while the verdict is out.
        assert!(b.allow(t1).is_ok());
        assert!(b.allow(t1).is_err());
        // Both probes come back clean: closed again.
        b.record(false, t1);
        b.record(false, t1);
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allow(t1).is_ok());
        // Trip again, half-open again, and this time a probe sees
        // overload: straight back to open, full cooldown.
        for _ in 0..4 {
            b.allow(t1).unwrap();
            b.record(true, t1);
        }
        let t2 = t1 + Duration::from_millis(100);
        assert!(b.allow(t2).is_ok());
        b.record(true, t2);
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.trips(), 3);
        assert!(b.allow(t2 + Duration::from_millis(99)).is_err());
        assert!(b.allow(t2 + Duration::from_millis(100)).is_ok());
    }

    #[test]
    fn state_names_are_stable() {
        assert_eq!(BreakerState::Closed.as_str(), "closed");
        assert_eq!(BreakerState::Open.as_str(), "open");
        assert_eq!(BreakerState::HalfOpen.as_str(), "half_open");
    }
}
