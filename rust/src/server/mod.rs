//! Serving frontend: the async gateway tier (auth → validation → rate
//! limit → admission), the threaded leader loop that pumps the
//! coordinator, plus a plaintext TCP status endpoint.

pub mod frontend;
pub mod gateway;
pub mod status;

pub use frontend::{Reply, ServeOpts, Server, ServerHandle};
pub use gateway::{
    AuthTable, BackendReply, BreakerState, CircuitBreaker, Gateway, GatewayBackend,
    GatewayStats, GatewayTicket, Principal, Reactor, ReactorHandle, ServerBackend,
    TicketOutcome, TokenBucket, WireRequest,
};
pub use status::{aggregate_nodes, StatusEndpoint};
