//! Serving frontend: the threaded leader loop that pumps the coordinator,
//! plus a plaintext TCP status endpoint.

pub mod frontend;
pub mod status;

pub use frontend::{Reply, ServeOpts, Server, ServerHandle};
pub use status::{aggregate_nodes, StatusEndpoint};
