//! Model substrate: layer descriptions, sequential model graphs, and the
//! zoo of architectures the paper's evaluation uses (DESIGN.md S5).

pub mod graph;
pub mod layer;
pub mod zoo;

pub use graph::{GraphBuilder, ModelGraph};
pub use layer::{Layer, LayerOp};
