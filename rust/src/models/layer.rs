//! Layer descriptions and their lowering to schedulable kernels.
//!
//! Every model in the zoo is a sequence of layers; each layer lowers to one
//! kernel. Convolutions and dense layers lower to im2col SGEMM kernels
//! (paper §4.1: "matrix multiplication is often used to implement the
//! convolution operator"), using the paper's layout convention for
//! conv2_2 — `M = spatial pixels (per tile), N = output channels,
//! K = input channels · kH · kW` — so that ResNet-18 conv2_2 at a 128×128
//! input reproduces the paper's `M=256, N=128, K=1152` exactly.
//! BatchNorm/ReLU are folded into the preceding GEMM's epilogue (standard
//! inference practice); pooling and depthwise convolutions lower to
//! bandwidth-bound non-GEMM kernels.

use crate::gpusim::kernel::{GemmShape, KernelDesc, TenantId};

/// A layer operation, parameterized enough to compute FLOPs, bytes, params
/// and the lowered GEMM shape.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerOp {
    /// Standard convolution (lowered to im2col SGEMM). `groups > 1` models
    /// grouped convolution (ResNeXt/SENet-154): FLOPs, params and the GEMM
    /// K dimension all shrink by the group count, and the layer lowers to
    /// `groups` same-shape GEMM kernels.
    Conv {
        cin: u32,
        cout: u32,
        kernel: u32,
        stride: u32,
        groups: u32,
    },
    /// Depthwise convolution (MobileNetV2): bandwidth-bound, not a GEMM.
    DwConv { channels: u32, kernel: u32, stride: u32 },
    /// Fully-connected layer (SGEMM with N = batch).
    Dense { d_in: u32, d_out: u32 },
    /// Pooling (max/avg): bandwidth-bound elementwise-class kernel.
    /// `valid` selects valid (AlexNet-style, no padding) vs same
    /// (ResNet-style, padded) output-size semantics.
    Pool { kernel: u32, stride: u32, valid: bool },
    /// Squeeze-and-Excitation gate (SENet): two tiny FCs + rescale.
    SeGate { channels: u32, reduction: u32 },
    /// RNN cell step: x·W_ih + h·W_hh fused as one matvec-shaped GEMM.
    RnnStep { hidden: u32 },
}

/// A layer instance bound to its input spatial size.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    pub op: LayerOp,
    /// Input spatial height/width (1 for FC/RNN layers).
    pub h_in: u32,
    pub w_in: u32,
}

impl Layer {
    /// Output spatial size after this layer.
    pub fn out_hw(&self) -> (u32, u32) {
        match &self.op {
            LayerOp::Conv { stride, .. } | LayerOp::DwConv { stride, .. } => {
                (self.h_in.div_ceil(*stride), self.w_in.div_ceil(*stride))
            }
            LayerOp::Pool {
                kernel,
                stride,
                valid,
            } => {
                if *valid {
                    (
                        (self.h_in.saturating_sub(*kernel)) / stride + 1,
                        (self.w_in.saturating_sub(*kernel)) / stride + 1,
                    )
                } else {
                    (self.h_in.div_ceil(*stride), self.w_in.div_ceil(*stride))
                }
            }
            LayerOp::Dense { .. } | LayerOp::RnnStep { .. } => (1, 1),
            LayerOp::SeGate { .. } => (self.h_in, self.w_in),
        }
    }

    /// Output channel count.
    pub fn out_channels(&self) -> u32 {
        match &self.op {
            LayerOp::Conv { cout, .. } => *cout,
            LayerOp::DwConv { channels, .. } => *channels,
            LayerOp::Dense { d_out, .. } => *d_out,
            LayerOp::Pool { .. } => 0, // caller tracks channels
            LayerOp::SeGate { channels, .. } => *channels,
            LayerOp::RnnStep { hidden } => *hidden,
        }
    }

    /// Trainable parameter count.
    pub fn params(&self) -> u64 {
        match &self.op {
            LayerOp::Conv {
                cin,
                cout,
                kernel,
                groups,
                ..
            } => {
                (*cin as u64 / *groups as u64) * (*cout as u64) * (*kernel as u64).pow(2)
                    + *cout as u64
            }
            LayerOp::DwConv { channels, kernel, .. } => {
                (*channels as u64) * (*kernel as u64).pow(2) + *channels as u64
            }
            LayerOp::Dense { d_in, d_out } => (*d_in as u64) * (*d_out as u64) + *d_out as u64,
            LayerOp::Pool { .. } => 0,
            LayerOp::SeGate {
                channels,
                reduction,
            } => 2 * (*channels as u64) * (*channels as u64 / *reduction as u64),
            LayerOp::RnnStep { hidden } => 2 * (*hidden as u64) * (*hidden as u64),
        }
    }

    /// FLOPs for one forward pass at batch size `batch`.
    pub fn flops(&self, batch: u32) -> f64 {
        let (ho, wo) = self.out_hw();
        let pix = (ho * wo * batch) as f64;
        match &self.op {
            LayerOp::Conv {
                cin,
                cout,
                kernel,
                groups,
                ..
            } => {
                2.0 * pix * (*cout as f64) * (*cin as f64 / *groups as f64)
                    * (*kernel as f64).powi(2)
            }
            LayerOp::DwConv { channels, kernel, .. } => {
                2.0 * pix * (*channels as f64) * (*kernel as f64).powi(2)
            }
            LayerOp::Dense { d_in, d_out } => {
                2.0 * batch as f64 * (*d_in as f64) * (*d_out as f64)
            }
            LayerOp::Pool { kernel, .. } => {
                pix * (*kernel as f64).powi(2) // compares/adds
            }
            LayerOp::SeGate {
                channels,
                reduction,
            } => {
                let c = *channels as f64;
                let r = c / *reduction as f64;
                batch as f64 * (4.0 * c * r + c * (self.h_in * self.w_in) as f64)
            }
            LayerOp::RnnStep { hidden } => 2.0 * batch as f64 * 2.0 * (*hidden as f64).powi(2),
        }
    }

    /// The lowered GEMM shape (None for non-GEMM layers), and how many GEMM
    /// kernels the layer produces. Convolutions use the paper's im2col
    /// layout — `M = output pixels, N = output channels,
    /// K = input channels · kH · kW` — as ONE kernel whose grid covers all
    /// pixels (that is how cuBLAS executes it). With a 128×128 network
    /// input, ResNet-18's 128-channel 3×3 stage runs at 16×16 spatial
    /// resolution, so its GEMM is exactly the paper's `M=256, N=128, K=1152`
    /// (the layer the paper calls conv2_2).
    pub fn gemm(&self, batch: u32) -> Option<(GemmShape, u32)> {
        match &self.op {
            LayerOp::Conv {
                cin,
                cout,
                kernel,
                groups,
                ..
            } => {
                let (ho, wo) = self.out_hw();
                let pixels = (ho * wo * batch).max(1);
                // Grouped conv = `groups` independent GEMMs over channel
                // slices (each N = cout/G, K = (cin/G)·k²).
                Some((
                    GemmShape::new(
                        pixels,
                        (*cout / *groups).max(1),
                        (*cin / *groups).max(1) * *kernel * *kernel,
                    ),
                    *groups,
                ))
            }
            LayerOp::Dense { d_in, d_out } => {
                Some((GemmShape::new(*d_out, batch.max(1), *d_in), 1))
            }
            LayerOp::RnnStep { hidden } => {
                // x·W_ih + h·W_hh fused: M=hidden, N=batch, K=2·hidden.
                // At batch 1 this is the paper's RNN matvec when hidden=512
                // (reported as M=512, N=1, K=512 per constituent GEMM; we
                // keep the two GEMMs separate to match Table 1's shape).
                Some((GemmShape::new(*hidden, batch.max(1), *hidden), 2))
            }
            _ => None,
        }
    }

    /// HBM bytes for one forward pass (weights + input + output), fp32.
    pub fn bytes(&self, batch: u32, cin_for_pool: u32) -> f64 {
        let (ho, wo) = self.out_hw();
        let b = batch as f64;
        match &self.op {
            LayerOp::Conv { cin, cout, kernel, groups, .. } => {
                let w = (*cin / *groups * *cout * *kernel * *kernel) as f64;
                let input = b * (*cin as f64) * (self.h_in * self.w_in) as f64;
                let output = b * (*cout as f64) * (ho * wo) as f64;
                4.0 * (w + input + output)
            }
            LayerOp::DwConv { channels, kernel, .. } => {
                let w = (*channels * *kernel * *kernel) as f64;
                let input = b * (*channels as f64) * (self.h_in * self.w_in) as f64;
                let output = b * (*channels as f64) * (ho * wo) as f64;
                4.0 * (w + input + output)
            }
            LayerOp::Dense { d_in, d_out } => {
                4.0 * ((*d_in as f64) * (*d_out as f64) + b * (*d_in + *d_out) as f64)
            }
            LayerOp::Pool { .. } => {
                let c = cin_for_pool as f64;
                4.0 * b * c * ((self.h_in * self.w_in) as f64 + (ho * wo) as f64)
            }
            LayerOp::SeGate { channels, .. } => {
                4.0 * b * (*channels as f64) * (2.0 * (self.h_in * self.w_in) as f64)
            }
            LayerOp::RnnStep { hidden } => {
                4.0 * (2.0 * (*hidden as f64).powi(2) + b * 3.0 * (*hidden as f64))
            }
        }
    }

    /// Lower this layer to kernels for `tenant` at batch `batch`.
    /// GEMM-lowered layers may produce several same-shape kernels (pixel
    /// tiles), which is exactly what the space-time batcher feeds on.
    pub fn lower(&self, tenant: TenantId, batch: u32, channels_in: u32) -> Vec<KernelDesc> {
        if let Some((shape, tiles)) = self.gemm(batch) {
            let mut k = KernelDesc::sgemm(tenant, shape);
            k.name = format!("{}:{}", self.name, k.name);
            return (0..tiles).map(|_| k.clone()).collect();
        }
        let flops = self.flops(batch);
        let bytes = self.bytes(batch, channels_in);
        let (ho, wo) = self.out_hw();
        // One CTA per 1024 output elements, floor 1.
        let out_elems = (ho * wo).max(1) as u64 * batch.max(1) as u64;
        let ctas = (out_elems / 1024).clamp(1, 1024) as u32;
        vec![KernelDesc::other(
            tenant,
            self.name.clone(),
            flops,
            bytes,
            ctas,
        )]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper §4.1: "ResNet-18 conv2_2" with a 128×128 network input — the
    /// 128-channel 3×3 stage runs at 16×16 spatial resolution (128 / 8
    /// after stem + two stride-2 stages), giving the paper's exact GEMM
    /// shape M=256, N=128, K=1152.
    #[test]
    fn conv2_2_lowering_matches_paper_shape() {
        let layer = Layer {
            name: "conv2_2".into(),
            op: LayerOp::Conv {
                cin: 128,
                cout: 128,
                kernel: 3,
                stride: 1,
                groups: 1,
            },
            h_in: 16,
            w_in: 16,
        };
        let (shape, kernels) = layer.gemm(1).unwrap();
        assert_eq!(shape, GemmShape::new(256, 128, 1152));
        assert_eq!(kernels, 1);
    }

    #[test]
    fn rnn_step_matches_paper_matvec() {
        let layer = Layer {
            name: "rnn".into(),
            op: LayerOp::RnnStep { hidden: 512 },
            h_in: 1,
            w_in: 1,
        };
        let (shape, count) = layer.gemm(1).unwrap();
        assert_eq!(shape, GemmShape::new(512, 1, 512));
        assert_eq!(count, 2); // W_ih and W_hh
    }

    #[test]
    fn conv_flops_formula() {
        // 3×3 conv, 64→64ch, 56×56 out, batch 1: 2·56²·64·64·9.
        let layer = Layer {
            name: "c".into(),
            op: LayerOp::Conv {
                cin: 64,
                cout: 64,
                kernel: 3,
                stride: 1,
                groups: 1,
            },
            h_in: 56,
            w_in: 56,
        };
        let expect = 2.0 * 56.0 * 56.0 * 64.0 * 64.0 * 9.0;
        assert_eq!(layer.flops(1), expect);
        assert_eq!(layer.flops(4), 4.0 * expect);
    }

    #[test]
    fn stride_halves_output() {
        let layer = Layer {
            name: "c".into(),
            op: LayerOp::Conv {
                cin: 3,
                cout: 64,
                kernel: 7,
                stride: 2,
                groups: 1,
            },
            h_in: 224,
            w_in: 224,
        };
        assert_eq!(layer.out_hw(), (112, 112));
    }

    #[test]
    fn dense_params_include_bias() {
        let layer = Layer {
            name: "fc".into(),
            op: LayerOp::Dense {
                d_in: 2048,
                d_out: 1000,
            },
            h_in: 1,
            w_in: 1,
        };
        assert_eq!(layer.params(), 2048 * 1000 + 1000);
    }

    #[test]
    fn conv_lowers_to_single_gemm_kernel() {
        let layer = Layer {
            name: "conv".into(),
            op: LayerOp::Conv {
                cin: 128,
                cout: 128,
                kernel: 3,
                stride: 1,
                groups: 1,
            },
            h_in: 32,
            w_in: 32,
        };
        let kernels = layer.lower(3, 1, 128);
        assert_eq!(kernels.len(), 1);
        assert_eq!(kernels[0].tenant, 3);
        assert_eq!(
            kernels[0].shape,
            Some(GemmShape::new(1024, 128, 1152)),
            "M = all 32·32 output pixels"
        );
    }

    #[test]
    fn same_arch_tenants_produce_identical_shape_classes() {
        // The cross-tenant batchability precondition (paper §2): same
        // architecture + same batch ⇒ identical GEMM shape classes.
        let layer = Layer {
            name: "conv".into(),
            op: LayerOp::Conv {
                cin: 64,
                cout: 64,
                kernel: 3,
                stride: 1,
                groups: 1,
            },
            h_in: 28,
            w_in: 28,
        };
        let a = layer.lower(0, 2, 64);
        let b = layer.lower(7, 2, 64);
        assert_eq!(a[0].shape, b[0].shape);
        assert_ne!(a[0].tenant, b[0].tenant);
    }

    #[test]
    fn pool_lowers_to_non_gemm_kernel() {
        let layer = Layer {
            name: "pool".into(),
            op: LayerOp::Pool {
                kernel: 2,
                stride: 2,
                valid: false,
            },
            h_in: 56,
            w_in: 56,
        };
        let kernels = layer.lower(0, 1, 64);
        assert_eq!(kernels.len(), 1);
        assert!(kernels[0].shape.is_none());
        assert!(kernels[0].flops > 0.0 && kernels[0].bytes > 0.0);
    }

    #[test]
    fn dwconv_is_cheap_relative_to_conv() {
        let dw = Layer {
            name: "dw".into(),
            op: LayerOp::DwConv {
                channels: 128,
                kernel: 3,
                stride: 1,
            },
            h_in: 32,
            w_in: 32,
        };
        let full = Layer {
            name: "c".into(),
            op: LayerOp::Conv {
                cin: 128,
                cout: 128,
                kernel: 3,
                stride: 1,
                groups: 1,
            },
            h_in: 32,
            w_in: 32,
        };
        assert!(dw.flops(1) * 64.0 <= full.flops(1));
    }
}
