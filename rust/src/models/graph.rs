//! Model graphs: an ordered layer list with channel/spatial bookkeeping,
//! plus lowering of a whole forward pass to a kernel stream.

use crate::gpusim::kernel::{KernelDesc, TenantId};
use crate::gpusim::memory::ModelFootprint;
use crate::models::layer::{Layer, LayerOp};

/// A sequential model graph. Residual/dense skip connections contribute
/// negligible FLOPs and are folded into the epilogues of their join layers,
/// so a sequence is sufficient for cost and scheduling purposes (the
/// *dependency* structure that matters to the scheduler — layer i before
/// layer i+1 — is preserved).
#[derive(Debug, Clone)]
pub struct ModelGraph {
    pub name: String,
    /// Publication year — used by the Figure 1 latency-trend bench.
    pub year: u32,
    pub layers: Vec<Layer>,
    /// Channels flowing *into* each layer (for pooling byte accounting).
    channels_in: Vec<u32>,
}

/// Incremental builder tracking spatial size and channel count.
pub struct GraphBuilder {
    name: String,
    year: u32,
    h: u32,
    w: u32,
    c: u32,
    layers: Vec<Layer>,
    channels_in: Vec<u32>,
}

impl GraphBuilder {
    pub fn new(name: impl Into<String>, year: u32, input_hw: u32, input_c: u32) -> Self {
        Self {
            name: name.into(),
            year,
            h: input_hw,
            w: input_hw,
            c: input_c,
            layers: Vec::new(),
            channels_in: Vec::new(),
        }
    }

    fn push(&mut self, name: String, op: LayerOp) -> &mut Self {
        let layer = Layer {
            name,
            op,
            h_in: self.h,
            w_in: self.w,
        };
        let (ho, wo) = layer.out_hw();
        self.channels_in.push(self.c);
        let out_c = layer.out_channels();
        if out_c > 0 {
            self.c = out_c;
        }
        self.h = ho;
        self.w = wo;
        self.layers.push(layer);
        self
    }

    pub fn conv(&mut self, name: &str, cout: u32, kernel: u32, stride: u32) -> &mut Self {
        self.conv_grouped(name, cout, kernel, stride, 1)
    }

    /// Grouped convolution (ResNeXt/SENet-154): `groups` independent
    /// channel-slice GEMMs; FLOPs and params shrink by the group count.
    pub fn conv_grouped(
        &mut self,
        name: &str,
        cout: u32,
        kernel: u32,
        stride: u32,
        groups: u32,
    ) -> &mut Self {
        let cin = self.c;
        debug_assert!(groups >= 1 && cin % groups == 0 && cout % groups == 0);
        self.push(
            name.to_string(),
            LayerOp::Conv {
                cin,
                cout,
                kernel,
                stride,
                groups,
            },
        )
    }

    pub fn dwconv(&mut self, name: &str, kernel: u32, stride: u32) -> &mut Self {
        let channels = self.c;
        self.push(
            name.to_string(),
            LayerOp::DwConv {
                channels,
                kernel,
                stride,
            },
        )
    }

    /// Padded ("same") pooling — ResNet-style.
    pub fn pool(&mut self, name: &str, kernel: u32, stride: u32) -> &mut Self {
        self.push(
            name.to_string(),
            LayerOp::Pool {
                kernel,
                stride,
                valid: false,
            },
        )
    }

    /// Unpadded ("valid") pooling — AlexNet/VGG-style.
    pub fn pool_valid(&mut self, name: &str, kernel: u32, stride: u32) -> &mut Self {
        self.push(
            name.to_string(),
            LayerOp::Pool {
                kernel,
                stride,
                valid: true,
            },
        )
    }

    /// Global average pool: collapses spatial dims to 1×1.
    pub fn global_pool(&mut self, name: &str) -> &mut Self {
        let k = self.h.max(1);
        self.push(
            name.to_string(),
            LayerOp::Pool {
                kernel: k,
                stride: k,
                valid: false,
            },
        )
    }

    /// Override the tracked channel count — models concatenation joins
    /// (DenseNet) whose contributing layers are bookkept separately.
    pub fn set_channels(&mut self, c: u32) -> &mut Self {
        self.c = c;
        self
    }

    pub fn dense(&mut self, name: &str, d_out: u32) -> &mut Self {
        let d_in = if self.h * self.w > 1 {
            self.c * self.h * self.w
        } else {
            self.c
        };
        self.h = 1;
        self.w = 1;
        self.push(name.to_string(), LayerOp::Dense { d_in, d_out })
    }

    pub fn se_gate(&mut self, name: &str, reduction: u32) -> &mut Self {
        let channels = self.c;
        self.push(
            name.to_string(),
            LayerOp::SeGate {
                channels,
                reduction,
            },
        )
    }

    pub fn rnn_step(&mut self, name: &str, hidden: u32) -> &mut Self {
        self.push(name.to_string(), LayerOp::RnnStep { hidden })
    }

    pub fn build(&mut self) -> ModelGraph {
        ModelGraph {
            name: std::mem::take(&mut self.name),
            year: self.year,
            layers: std::mem::take(&mut self.layers),
            channels_in: std::mem::take(&mut self.channels_in),
        }
    }
}

impl ModelGraph {
    /// Total trainable parameters.
    pub fn params(&self) -> u64 {
        self.layers.iter().map(|l| l.params()).sum()
    }

    /// Weight bytes (fp32).
    pub fn weight_bytes(&self) -> u64 {
        self.params() * 4
    }

    /// FLOPs of one forward pass at `batch`.
    pub fn flops(&self, batch: u32) -> f64 {
        self.layers.iter().map(|l| l.flops(batch)).sum()
    }

    /// Peak activation bytes at `batch` — approximated as twice the largest
    /// inter-layer tensor (double-buffered producer/consumer).
    pub fn activation_bytes(&self, batch: u32) -> u64 {
        let mut peak: u64 = 0;
        let mut h;
        let mut w;
        for (i, layer) in self.layers.iter().enumerate() {
            let (ho, wo) = layer.out_hw();
            h = ho;
            w = wo;
            let c = if layer.out_channels() > 0 {
                layer.out_channels()
            } else {
                self.channels_in[i]
            };
            let bytes = 4u64 * batch as u64 * c as u64 * (h as u64) * (w as u64);
            peak = peak.max(bytes);
        }
        peak * 2
    }

    /// Memory footprint used by the Figure 5 memory-wall model.
    pub fn footprint(&self, batch: u32) -> ModelFootprint {
        ModelFootprint {
            weights: self.weight_bytes(),
            activations: self.activation_bytes(batch),
        }
    }

    /// Lower the whole forward pass to an ordered kernel stream for `tenant`.
    pub fn lower(&self, tenant: TenantId, batch: u32) -> Vec<KernelDesc> {
        let mut out = Vec::new();
        for (i, layer) in self.layers.iter().enumerate() {
            out.extend(layer.lower(tenant, batch, self.channels_in[i]));
        }
        out
    }

    /// Number of GEMM-lowered kernels at `batch` (batchability measure).
    pub fn gemm_kernel_count(&self, batch: u32) -> usize {
        self.lower(0, batch)
            .iter()
            .filter(|k| k.shape.is_some())
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelGraph {
        GraphBuilder::new("tiny", 2020, 32, 3)
            .conv("c1", 16, 3, 1)
            .pool("p1", 2, 2)
            .conv("c2", 32, 3, 1)
            .global_pool("gap")
            .dense("fc", 10)
            .build()
    }

    #[test]
    fn builder_tracks_shapes() {
        let g = tiny();
        assert_eq!(g.layers.len(), 5);
        assert_eq!(g.layers[0].h_in, 32);
        assert_eq!(g.layers[2].h_in, 16); // after 2×2 pool
        // fc input: 32 channels after global pool.
        match g.layers[4].op {
            LayerOp::Dense { d_in, d_out } => {
                assert_eq!(d_in, 32);
                assert_eq!(d_out, 10);
            }
            _ => panic!("expected dense"),
        }
    }

    #[test]
    fn params_sum_layers() {
        let g = tiny();
        let expect: u64 = (3 * 16 * 9 + 16) + (16 * 32 * 9 + 32) + (32 * 10 + 10);
        assert_eq!(g.params(), expect);
        assert_eq!(g.weight_bytes(), expect * 4);
    }

    #[test]
    fn flops_scale_linearly_with_batch() {
        let g = tiny();
        assert!((g.flops(4) - 4.0 * g.flops(1)).abs() < 1e-6);
    }

    #[test]
    fn lowering_preserves_layer_order() {
        let g = tiny();
        let kernels = g.lower(0, 1);
        assert!(kernels.len() >= g.layers.len());
        // conv kernels come before the fc kernel.
        let conv_pos = kernels.iter().position(|k| k.name.contains("c1")).unwrap();
        let fc_pos = kernels.iter().position(|k| k.name.contains("fc")).unwrap();
        assert!(conv_pos < fc_pos);
    }

    #[test]
    fn activation_bytes_positive_and_batch_scaled() {
        let g = tiny();
        let a1 = g.activation_bytes(1);
        let a8 = g.activation_bytes(8);
        assert!(a1 > 0);
        assert_eq!(a8, a1 * 8);
    }

    #[test]
    fn gemm_kernel_count_counts_only_gemms() {
        let g = tiny();
        let total = g.lower(0, 1).len();
        let gemms = g.gemm_kernel_count(1);
        assert!(gemms > 0 && gemms < total);
    }
}
