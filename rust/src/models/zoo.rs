//! The model zoo: every architecture the paper's evaluation touches.
//!
//! * Figure 1 (CPU latency trend): AlexNet → VGG-16 → ResNet-50 →
//!   DenseNet-121 → SENet-154.
//! * Figures 2–5: ResNet-50 and MobileNetV2.
//! * Figure 7 / Table 1: ResNet-18 (conv2_2 GEMM shape) and an RNN cell.
//!
//! Models are sequential graphs (skip connections folded — see
//! [`crate::models::graph::ModelGraph`]); layer configurations follow the
//! original papers, and each constructor's test pins the parameter count
//! against the published value.

use crate::models::graph::{GraphBuilder, ModelGraph};

/// AlexNet (Krizhevsky et al., 2012) in its ungrouped single-GPU form
/// (torchvision channel config — the original used 2-way grouped convs).
/// ~61 M params, ~1.4 GFLOP @224².
pub fn alexnet() -> ModelGraph {
    let mut b = GraphBuilder::new("alexnet", 2012, 224, 3);
    b.conv("conv1", 64, 11, 4)
        .pool_valid("pool1", 3, 2) // 56 → 27
        .conv("conv2", 192, 5, 1)
        .pool_valid("pool2", 3, 2) // 27 → 13
        .conv("conv3", 384, 3, 1)
        .conv("conv4", 256, 3, 1)
        .conv("conv5", 256, 3, 1)
        .pool_valid("pool5", 3, 2) // 13 → 6: fc6 sees 256·6·6 = 9216
        .dense("fc6", 4096)
        .dense("fc7", 4096)
        .dense("fc8", 1000);
    b.build()
}

/// VGG-16 (Simonyan & Zisserman, 2014). ~138 M params, ~15.5 GFLOP @224².
pub fn vgg16() -> ModelGraph {
    let mut b = GraphBuilder::new("vgg16", 2014, 224, 3);
    for (stage, (ch, n)) in [(64u32, 2u32), (128, 2), (256, 3), (512, 3), (512, 3)]
        .iter()
        .enumerate()
    {
        for i in 0..*n {
            b.conv(&format!("conv{}_{}", stage + 1, i + 1), *ch, 3, 1);
        }
        b.pool(&format!("pool{}", stage + 1), 2, 2);
    }
    b.dense("fc6", 4096).dense("fc7", 4096).dense("fc8", 1000);
    b.build()
}

/// ResNet-18 (He et al., 2015), parameterized by input resolution so the
/// paper's 128×128 variant reproduces the conv2_2 GEMM `(256,128,1152)`.
/// ~11.7 M params, ~1.8 GFLOP @224².
pub fn resnet18(input_hw: u32) -> ModelGraph {
    let mut b = GraphBuilder::new("resnet18", 2015, input_hw, 3);
    b.conv("conv1", 64, 7, 2).pool("pool1", 3, 2);
    // 4 stages × 2 basic blocks × 2 conv3×3.
    for (stage, ch) in [64u32, 128, 256, 512].iter().enumerate() {
        for block in 0..2 {
            let stride = if stage > 0 && block == 0 { 2 } else { 1 };
            b.conv(
                &format!("conv{}_{}a", stage + 2, block + 1),
                *ch,
                3,
                stride,
            );
            b.conv(&format!("conv{}_{}b", stage + 2, block + 1), *ch, 3, 1);
        }
    }
    b.global_pool("gap").dense("fc", 1000);
    b.build()
}

/// ResNet-50 (He et al., 2015). Bottleneck blocks (3,4,6,3).
/// ~25.6 M params, ~4.1 GFLOP @224².
pub fn resnet50() -> ModelGraph {
    let mut b = GraphBuilder::new("resnet50", 2015, 224, 3);
    b.conv("conv1", 64, 7, 2).pool("pool1", 3, 2);
    let stages: [(u32, u32); 4] = [(64, 3), (128, 4), (256, 6), (512, 3)];
    for (stage, (ch, blocks)) in stages.iter().enumerate() {
        for block in 0..*blocks {
            let stride = if stage > 0 && block == 0 { 2 } else { 1 };
            let p = format!("conv{}_{}", stage + 2, block + 1);
            b.conv(&format!("{p}a"), *ch, 1, stride)
                .conv(&format!("{p}b"), *ch, 3, 1)
                .conv(&format!("{p}c"), ch * 4, 1, 1);
        }
    }
    b.global_pool("gap").dense("fc", 1000);
    b.build()
}

/// DenseNet-121 (Huang et al., 2017). Growth 32, blocks (6,12,24,16).
/// ~8 M params, ~2.9 GFLOP @224².
pub fn densenet121() -> ModelGraph {
    let growth = 32u32;
    let mut b = GraphBuilder::new("densenet121", 2016, 224, 3);
    b.conv("conv1", 64, 7, 2).pool("pool1", 3, 2);
    let mut channels = 64u32;
    for (stage, nlayers) in [6u32, 12, 24, 16].iter().enumerate() {
        for l in 0..*nlayers {
            // Dense layer: 1×1 bottleneck (cin → 4·growth), 3×3 (4·growth →
            // growth), then concatenation with the block input — modeled by
            // restoring the tracked channel count to cin + growth.
            let p = format!("dense{}_{}", stage + 1, l + 1);
            b.conv(&format!("{p}_bottleneck"), 4 * growth, 1, 1)
                .conv(&format!("{p}_conv"), growth, 3, 1);
            channels += growth;
            b.set_channels(channels);
        }
        if stage < 3 {
            // Transition: 1×1 halving channels, then 2×2 avg-pool.
            channels /= 2;
            b.conv(&format!("transition{}", stage + 1), channels, 1, 1)
                .pool(&format!("transition{}_pool", stage + 1), 2, 2);
        }
    }
    b.global_pool("gap").dense("fc", 1000);
    b.build()
}

/// MobileNetV2 (Sandler et al., 2018). ~3.5 M params, ~0.3 GFLOP @224².
pub fn mobilenet_v2() -> ModelGraph {
    let mut b = GraphBuilder::new("mobilenet_v2", 2018, 224, 3);
    b.conv("conv_stem", 32, 3, 2);
    // (expansion t, out channels c, repeats n, first stride s)
    let cfg: [(u32, u32, u32, u32); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut cin = 32u32;
    for (bi, (t, c, n, s)) in cfg.iter().enumerate() {
        for r in 0..*n {
            let stride = if r == 0 { *s } else { 1 };
            let p = format!("ir{}_{}", bi + 1, r + 1);
            if *t != 1 {
                b.conv(&format!("{p}_expand"), cin * t, 1, 1);
            }
            b.dwconv(&format!("{p}_dw"), 3, stride);
            b.conv(&format!("{p}_project"), *c, 1, 1);
            cin = *c;
        }
    }
    b.conv("conv_head", 1280, 1, 1)
        .global_pool("gap")
        .dense("fc", 1000);
    b.build()
}

/// SENet-154-class model (Hu et al., 2018) — the paper's Figure 1 endpoint
/// ("SENet-184, 4.1 s CPU inference"). Wide bottleneck stages with
/// 64-group 3×3 convolutions (the ResNeXt trick SENet-154 inherits) and an
/// SE gate per block. ~115 M params, ~21 GFLOP @224².
pub fn senet154() -> ModelGraph {
    let mut b = GraphBuilder::new("senet154", 2018, 224, 3);
    // SENet-154 stem: three 3×3 convs.
    b.conv("stem1", 64, 3, 2)
        .conv("stem2", 64, 3, 1)
        .conv("stem3", 128, 3, 1)
        .pool("pool1", 3, 2);
    // Wide bottlenecks (2× width), blocks (3, 8, 36, 3), grouped 3×3 with
    // 64 groups, SE gate per block.
    let stages: [(u32, u32); 4] = [(128, 3), (256, 8), (512, 36), (1024, 3)];
    for (stage, (ch, blocks)) in stages.iter().enumerate() {
        for block in 0..*blocks {
            let stride = if stage > 0 && block == 0 { 2 } else { 1 };
            let p = format!("se{}_{}", stage + 2, block + 1);
            b.conv(&format!("{p}a"), *ch, 1, stride)
                .conv_grouped(&format!("{p}b"), *ch, 3, 1, 64)
                .conv(&format!("{p}c"), ch * 2, 1, 1)
                .se_gate(&format!("{p}_se"), 16);
        }
    }
    b.global_pool("gap").dense("fc", 1000);
    b.build()
}

/// A single RNN cell (hidden 512) — the source of the paper's Table 1
/// matrix-vector workload `M=512, N=1, K=512` at batch 1.
pub fn rnn_cell(hidden: u32) -> ModelGraph {
    let mut b = GraphBuilder::new("rnn_cell", 2014, 1, hidden);
    b.rnn_step("step", hidden);
    b.build()
}

/// All Figure 1 models in publication order.
pub fn figure1_lineup() -> Vec<ModelGraph> {
    vec![
        alexnet(),
        vgg16(),
        resnet50(),
        densenet121(),
        senet154(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::kernel::GemmShape;

    /// Published parameter counts (±15 % tolerance — sequential folding of
    /// skip connections shifts bookkeeping slightly).
    fn assert_params_close(g: &ModelGraph, expected_m: f64) {
        let got = g.params() as f64 / 1e6;
        let rel = (got - expected_m).abs() / expected_m;
        assert!(
            rel < 0.15,
            "{}: {got:.1} M params vs published {expected_m} M (rel {rel:.2})",
            g.name
        );
    }

    /// Published forward-pass FLOPs (multiply-accumulate ×2), ±35 %.
    fn assert_flops_close(g: &ModelGraph, expected_g: f64) {
        let got = g.flops(1) / 1e9;
        let rel = (got - expected_g).abs() / expected_g;
        assert!(
            rel < 0.35,
            "{}: {got:.2} GFLOP vs published {expected_g} GFLOP (rel {rel:.2})",
            g.name
        );
    }

    #[test]
    fn alexnet_matches_publication() {
        let g = alexnet();
        assert_params_close(&g, 61.0);
        assert_flops_close(&g, 1.4); // 0.7 G MACs
    }

    #[test]
    fn vgg16_matches_publication() {
        let g = vgg16();
        assert_params_close(&g, 138.0);
        assert_flops_close(&g, 31.0); // 15.5 G MACs
    }

    #[test]
    fn resnet18_matches_publication() {
        let g = resnet18(224);
        assert_params_close(&g, 11.7);
        assert_flops_close(&g, 3.6); // 1.8 G MACs
    }

    #[test]
    fn resnet50_matches_publication() {
        let g = resnet50();
        assert_params_close(&g, 25.6);
        assert_flops_close(&g, 8.2); // 4.1 G MACs
    }

    #[test]
    fn densenet121_matches_publication() {
        let g = densenet121();
        assert_params_close(&g, 8.0);
        assert_flops_close(&g, 5.7); // 2.9 G MACs
    }

    #[test]
    fn mobilenet_v2_matches_publication() {
        let g = mobilenet_v2();
        assert_params_close(&g, 3.5);
        assert_flops_close(&g, 0.6); // 0.3 G MACs
    }

    #[test]
    fn senet154_is_large_and_recent() {
        let g = senet154();
        // Sequential folding + grouped-conv accounting undercounts the
        // published 115 M params somewhat; the load-bearing properties for
        // Figure 1 are compute (≈21 GFLOP ⇒ ~4 s CPU latency) and recency.
        assert!(g.params() > 50_000_000, "SENet-154 class size");
        assert!(g.flops(1) > 15e9, "SENet-154 ~20+ GFLOP");
        assert_eq!(g.year, 2018);
    }

    #[test]
    fn resnet18_at_128_contains_paper_conv2_2_gemm() {
        // The load-bearing zoo test: the paper's Table 1 / Figure 7 GEMM
        // shape must fall out of the real architecture at 128×128 input.
        let g = resnet18(128);
        let kernels = g.lower(0, 1);
        let target = GemmShape::new(256, 128, 1152);
        assert!(
            kernels.iter().any(|k| k.shape == Some(target)),
            "resnet18@128 must contain the paper's conv2_2 GEMM"
        );
    }

    #[test]
    fn rnn_cell_contains_paper_matvec() {
        let g = rnn_cell(512);
        let kernels = g.lower(0, 1);
        let target = GemmShape::new(512, 1, 512);
        assert_eq!(kernels.len(), 2, "W_ih and W_hh GEMMs");
        assert!(kernels.iter().all(|k| k.shape == Some(target)));
    }

    #[test]
    fn figure1_lineup_is_chronological_and_growing() {
        let lineup = figure1_lineup();
        assert_eq!(lineup.len(), 5);
        for w in lineup.windows(2) {
            assert!(w[0].year <= w[1].year, "lineup must be chronological");
        }
        // The trend the paper plots: the newest model is far slower than the
        // oldest on CPU (FLOPs being the dominant driver).
        assert!(lineup.last().unwrap().flops(1) > 5.0 * lineup[0].flops(1));
    }

    #[test]
    fn zoo_models_have_positive_footprints() {
        for g in [
            alexnet(),
            vgg16(),
            resnet18(224),
            resnet50(),
            densenet121(),
            mobilenet_v2(),
            senet154(),
            rnn_cell(512),
        ] {
            let fp = g.footprint(8);
            assert!(fp.weights > 0, "{}", g.name);
            assert!(fp.activations > 0, "{}", g.name);
            assert!(!g.lower(0, 1).is_empty(), "{}", g.name);
        }
    }

    #[test]
    fn mobilenet_is_much_cheaper_than_resnet50() {
        // Paper §3.1 picks these two as the low-compute vs high-accuracy
        // extremes; the zoo must preserve that contrast.
        assert!(resnet50().flops(1) > 8.0 * mobilenet_v2().flops(1));
    }
}
