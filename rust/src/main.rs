//! `stgpu` — the leader binary: serve, simulate, inspect.
//!
//! Subcommands:
//! * `serve    --config <toml> [--duration-s N] [--status ADDR]`
//!   Start the coordinator + threaded frontend over a device pool
//!   (`devices` in the config), drive closed-loop synthetic clients
//!   (paper §2: saturated queues), print per-tenant and per-device
//!   metrics. Overload sheds with a 429-style `Overloaded` rejection.
//! * `simulate --policy <p> --tenants N [--shape MxNxK] [--iters N]
//!   [--devices N] [--engine vectorized|legacy]`
//!   Run the V100 discrete-event simulator under a multiplexing policy;
//!   `--devices > 1` shards tenants across a device pool; `--engine
//!   legacy` selects the per-event reference engine (the equivalence
//!   oracle) instead of the default struct-of-arrays engine. With
//!   `--cluster N [--rounds R] [--seed S] [--journal F] [--serial]
//!   [--steal]` it runs the cluster tier instead (optionally with
//!   cross-node work stealing) and can persist the decision journal.
//!   `--steal` on the device path enables work-conserving lane stealing
//!   in the vectorized engine.
//! * `replay   <journal>`
//!   Re-execute a decision journal's configuration through the serial
//!   path and verify the regenerated journal is bitwise identical
//!   (exit 1 on digest mismatch).
//! * `tune     [--workload fig12] [--budget N] [--out-toml F]
//!   [--out-leaderboard F] [--check-baseline F]`
//!   Offline autotuner: search (lanes, pipeline depth, EDF slack,
//!   controller knobs) against gpusim ground truth, emit the winner as a
//!   validated `[server]`/`[controller]` TOML fragment + JSON leaderboard.
//! * `artifacts [--dir artifacts]`
//!   List the AOT artifact manifest the runtime would load.
//! * `trace    [--tenants N] [--policy <p>]`
//!   Render a Figure-6-style schedule Gantt from the simulator.
//!
//! The arg parser is hand-rolled: `clap` is not vendored offline
//! (DESIGN.md §7).

// The binary needs no escape hatch at all (the library's allowlisted
// Send/Sync impls are behind `#![deny(unsafe_code)]` there).
#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::time::{Duration, Instant};

use stgpu::config::{SchedulerKind, ServerConfig};
use stgpu::coordinator::{
    replay_journal, run_cluster, tuner, ClusterOpts, Coordinator, Journal,
};
use stgpu::gpusim::{self, DeviceSpec, Engine, GemmShape, Policy, SimConfig};
use stgpu::runtime::Manifest;
use stgpu::server::gateway::reactor::gateway_handler;
use stgpu::server::{aggregate_nodes, Gateway, Reactor, ServeOpts, Server, ServerBackend, StatusEndpoint};
use stgpu::util::json::Json;
use stgpu::util::bench::{fmt_flops, fmt_secs, Table};
use stgpu::util::prng::Rng;
use stgpu::util::sync::lock_recover;
use stgpu::workload::sgemm_tenants;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, positional, flags) = parse(&args);
    let code = match cmd.as_deref() {
        Some("serve") => cmd_serve(&flags),
        Some("simulate") => cmd_simulate(&flags),
        Some("replay") => cmd_replay(&positional, &flags),
        Some("tune") => cmd_tune(&flags),
        Some("artifacts") => cmd_artifacts(&flags),
        Some("trace") => cmd_trace(&flags),
        _ => {
            eprintln!(
                "usage: stgpu <serve|simulate|replay|tune|artifacts|trace> [--flag value]..."
            );
            eprintln!("{}", include_str!("main_help.txt"));
            2
        }
    };
    std::process::exit(code);
}

/// `--flag value` pairs after the subcommand; bare `--flag` maps to "true";
/// non-flag arguments collect as positionals (e.g. `replay <journal>`).
fn parse(args: &[String]) -> (Option<String>, Vec<String>, HashMap<String, String>) {
    let mut flags = HashMap::new();
    let mut positional = Vec::new();
    let cmd = args.first().cloned();
    let mut i = 1;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            flags.insert(name.to_string(), val);
        } else {
            positional.push(args[i].clone());
        }
        i += 1;
    }
    (cmd, positional, flags)
}

fn flag<'a>(flags: &'a HashMap<String, String>, name: &str, default: &'a str) -> &'a str {
    flags.get(name).map(String::as_str).unwrap_or(default)
}

fn parse_policy(
    s: &str,
    max_batch: u32,
    lanes: u32,
    adaptive: bool,
) -> Result<Policy, String> {
    Ok(match SchedulerKind::parse(s)? {
        SchedulerKind::Exclusive => Policy::Exclusive,
        SchedulerKind::TimeMux => Policy::TimeMux,
        SchedulerKind::SpaceMux => Policy::SpaceMuxMps { anomaly_seed: 42 },
        // --adaptive: the coordinator's controller picks the lane count
        // online; --lanes acts as its cap (defaulting to 4 when left at 1,
        // so a bare --adaptive has headroom to adapt within).
        SchedulerKind::SpaceTime if adaptive => Policy::SpaceTimeAdaptive {
            max_batch,
            max_lanes: if lanes > 1 { lanes } else { 4 },
        },
        SchedulerKind::SpaceTime if lanes > 1 => {
            Policy::SpaceTimeLanes { max_batch, lanes }
        }
        SchedulerKind::SpaceTime => Policy::SpaceTime { max_batch },
    })
}

fn parse_shape(s: &str) -> Result<GemmShape, String> {
    let parts: Vec<u32> = s
        .split('x')
        .map(|p| p.parse().map_err(|_| format!("bad shape {s:?}")))
        .collect::<Result<_, _>>()?;
    if parts.len() != 3 {
        return Err(format!("shape must be MxNxK, got {s:?}"));
    }
    Ok(GemmShape::new(parts[0], parts[1], parts[2]))
}

// ---------------------------------------------------------------------------

fn cmd_serve(flags: &HashMap<String, String>) -> i32 {
    let cfg_path = flag(flags, "config", "");
    let cfg = if cfg_path.is_empty() {
        eprintln!("serve: no --config given; using 4 built-in sgemm tenants");
        let mut c = ServerConfig::default();
        for i in 0..4 {
            c.tenants.push(stgpu::config::TenantConfig {
                name: format!("tenant{i}"),
                model: "sgemm:256x128x1152".into(),
                batch: 1,
                slo_ms: 100.0,
                weight_seed: i as u64,
            });
        }
        c
    } else {
        match ServerConfig::load(std::path::Path::new(cfg_path)) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("serve: config error: {e}");
                return 2;
            }
        }
    };
    let duration_s: f64 = flag(flags, "duration-s", "5").parse().unwrap_or(5.0);
    let n_tenants = cfg.tenants.len();
    if n_tenants == 0 {
        eprintln!("serve: config has no tenants");
        return 2;
    }

    let coord = match Coordinator::new(&cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("serve: {e:#}");
            return 1;
        }
    };
    let warmed = coord.warmup().unwrap_or(0);
    eprintln!(
        "serve: scheduler={} edf={} lanes={} pipeline_depth={} adaptive={} tenants={} devices={} queue_cap={} warmed={} executables, platform={}",
        coord.scheduler_label(),
        coord.deadline_aware(),
        coord.lanes(),
        coord.pipeline_depth(),
        coord.adaptive(),
        n_tenants,
        coord.devices(),
        coord.queue_cap(),
        warmed,
        coord.engine().platform()
    );

    // The gateway needs tenant → device placement, captured before the
    // coordinator moves into the server.
    let gw_placement = cfg
        .gateway
        .enabled
        .then(|| ((0..n_tenants).map(|t| coord.device_of(t)).collect::<Vec<_>>(), coord.devices()));

    let server = Server::start(
        coord,
        ServeOpts {
            batch_timeout: Duration::from_micros(cfg.batch_timeout_us),
            ..Default::default()
        },
    );

    let gateway = gw_placement.map(|(placement, devices)| {
        let backend = ServerBackend::new(server.handle(), placement, devices);
        std::sync::Arc::new(std::sync::Mutex::new(Gateway::new(&cfg.gateway, backend)))
    });
    let reactor = match (&gateway, &cfg.gateway.listen) {
        (Some(gw), Some(listen)) => {
            let models: Vec<String> = cfg.tenants.iter().map(|t| t.model.clone()).collect();
            let payload_for = std::sync::Arc::new(move |t: usize| {
                let spec = stgpu::coordinator::ModelSpec::parse(&models[t]).expect("model");
                let mut rng = Rng::new(0x6A7E + t as u64);
                spec.payload_shapes()
                    .iter()
                    .map(|s| stgpu::runtime::HostTensor::random(s, &mut rng))
                    .collect::<Vec<_>>()
            });
            let r = Reactor::start_with(
                listen.as_str(),
                cfg.gateway.reactor_workers,
                Duration::from_secs_f64(cfg.gateway.idle_timeout_ms / 1e3),
                gateway_handler(gw.clone(), payload_for),
            )
            .expect("bind gateway listener");
            eprintln!(
                "serve: gateway on {} ({} workers, {} keys)",
                r.addr(),
                cfg.gateway.reactor_workers,
                cfg.gateway.tenants.len()
            );
            Some(r)
        }
        _ => None,
    };

    let status = flags.get("status").map(|addr| {
        let handle = server.handle();
        let gw = gateway.clone();
        let ep = StatusEndpoint::start_with(addr.as_str(), move || {
            let mut j = handle
                .snapshot()
                .map(|s| s.to_json())
                .unwrap_or_else(|| Json::obj(vec![("error", Json::str("no snapshot"))]));
            if let (Some(gw), Json::Obj(map)) = (&gw, &mut j) {
                map.insert(
                    "gateway".to_string(),
                    lock_recover(gw).status_json(Instant::now()),
                );
            }
            j.to_string()
        })
        .expect("bind status endpoint");
        eprintln!("serve: status endpoint on {}", ep.addr());
        ep
    });

    // Closed-loop clients: one thread per tenant, resubmit on completion
    // (saturated queues — paper §2).
    let stop_at = Instant::now() + Duration::from_secs_f64(duration_s);
    let mut clients = Vec::new();
    for t in 0..n_tenants {
        let h = server.handle();
        let model = cfg.tenants[t].model.clone();
        clients.push(std::thread::spawn(move || {
            let spec = stgpu::coordinator::ModelSpec::parse(&model).expect("model");
            let mut rng = Rng::new(0xC11E + t as u64);
            let mut done = 0u64;
            while Instant::now() < stop_at {
                let payload = spec
                    .payload_shapes()
                    .iter()
                    .map(|s| stgpu::runtime::HostTensor::random(s, &mut rng))
                    .collect();
                match h.submit_blocking(t, payload) {
                    Ok(_) => done += 1,
                    Err(stgpu::coordinator::Reject::TenantEvicted) => break,
                    Err(_) => std::thread::sleep(Duration::from_millis(1)),
                }
            }
            done
        }));
    }
    for c in clients {
        let _ = c.join();
    }
    if let Some(r) = reactor {
        r.stop();
    }
    if let Some(ep) = status {
        ep.stop();
    }
    if let Some(gw) = &gateway {
        let g = gw.lock().unwrap();
        let s = g.stats();
        eprintln!(
            "serve: gateway admitted={} rate_limited={} breaker_shed={} backend_rejects={} auth_failures={}",
            s.admitted,
            s.rate_limited,
            s.breaker_shed,
            s.backend_rejects,
            g.auth_failures()
        );
    }
    let coord = server.shutdown();
    let snap = coord.snapshot();

    let mut table =
        Table::new(&["tenant", "completed", "p50", "p99", "mean", "rps", "slo_att"]);
    for (name, t) in &snap.tenants {
        table.row(&[
            name.clone(),
            t.completed.to_string(),
            fmt_secs(t.latency_p50_ns as f64 / 1e9),
            fmt_secs(t.latency_p99_ns as f64 / 1e9),
            fmt_secs(t.latency_mean_ns / 1e9),
            format!("{:.1}", t.completed as f64 / snap.wall_seconds),
            t.slo_attainment()
                .map_or_else(|| "-".to_string(), |a| format!("{:.1}%", a * 100.0)),
        ]);
    }
    println!("{}", table.render());
    if snap.devices.len() > 1
        || coord.lanes() > 1
        || coord.adaptive()
        || snap.devices.iter().any(|d| d.shed > 0)
    {
        let mut dev_table = Table::new(&[
            "device",
            "tenants",
            "launches",
            "superkernels",
            "drained",
            "shed",
            "dl_splits",
            "calib_err",
            "lane_util",
            "steals",
            "lane_calib",
            "ctrl",
            "flops",
        ]);
        for d in &snap.devices {
            // Per-lane utilization as "u0/u1/..."; interference calibration
            // as "lanes:err" pairs (empty until overlapped rounds ran).
            let lane_util = d
                .lane_utilization(snap.wall_seconds)
                .iter()
                .map(|u| format!("{:.0}%", u * 100.0))
                .collect::<Vec<_>>()
                .join("/");
            // Work-steal traffic as "total (per-thief s0/s1/...)"; "-"
            // until a lane stole anything (or with stealing off).
            let steals_total: u64 = d.lane_steals.iter().sum();
            let steals = if steals_total == 0 && d.launch_retries == 0 {
                "-".to_string()
            } else {
                format!(
                    "{} ({})",
                    steals_total,
                    d.lane_steals
                        .iter()
                        .map(|s| s.to_string())
                        .collect::<Vec<_>>()
                        .join("/")
                )
            };
            let lane_calib = if d.lane_calibration.is_empty() {
                "-".to_string()
            } else {
                d.lane_calibration
                    .iter()
                    .map(|(l, e)| format!("{l}:{e:.3}"))
                    .collect::<Vec<_>>()
                    .join(" ")
            };
            // Controller decision as "<lanes>L@<depth>D/<reconfigs>r"
            // ("-" with the adaptive controller off).
            let ctrl = if d.ctrl_adaptive {
                format!("{}L@{}D/{}r", d.ctrl_lanes, d.ctrl_depth, d.ctrl_reconfigs)
            } else {
                "-".to_string()
            };
            dev_table.row(&[
                d.device.to_string(),
                d.tenants.to_string(),
                d.launches.to_string(),
                d.superkernel_launches.to_string(),
                d.drained.to_string(),
                d.shed.to_string(),
                d.deadline_splits.to_string(),
                format!("{:.3}", d.cost_calibration_error),
                lane_util,
                steals,
                lane_calib,
                ctrl,
                format!("{:.3e}", d.flops),
            ]);
        }
        println!("{}", dev_table.render());
    }
    let shed_total = coord.shed_total();
    println!(
        "total: {} completed in {:.2}s ({:.1} req/s, {} throughput), {} superkernels, {} singleton kernels, {} shed (429)",
        snap.total_completed(),
        snap.wall_seconds,
        snap.throughput_rps(),
        fmt_flops(snap.throughput_flops()),
        snap.superkernel_launches,
        snap.kernel_launches,
        shed_total,
    );
    if let Some(bs) = coord.batcher_stats() {
        println!(
            "batcher: {} launches, mean fused R = {:.2}, padding waste = {:.1}%",
            bs.launches,
            bs.mean_fused(),
            bs.padding_waste() * 100.0
        );
    }
    0
}

// ---------------------------------------------------------------------------

fn cmd_simulate(flags: &HashMap<String, String>) -> i32 {
    if flags.contains_key("cluster") {
        return cmd_simulate_cluster(flags);
    }
    let tenants: usize = flag(flags, "tenants", "8").parse().unwrap_or(8);
    let iters: u32 = flag(flags, "iters", "50").parse().unwrap_or(50);
    let max_batch: u32 = flag(flags, "max-batch", "64").parse().unwrap_or(64);
    let devices: usize = flag(flags, "devices", "1").parse().unwrap_or(1).max(1);
    let lanes: u32 = flag(flags, "lanes", "1").parse().unwrap_or(1).max(1);
    let adaptive = flag(flags, "adaptive", "false") == "true";
    let shape = match parse_shape(flag(flags, "shape", "256x128x1152")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("simulate: {e}");
            return 2;
        }
    };
    let policy = match parse_policy(
        flag(flags, "policy", "space-time"),
        max_batch,
        lanes,
        adaptive,
    ) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("simulate: {e}");
            return 2;
        }
    };
    let engine = match Engine::parse(flag(flags, "engine", "vectorized")) {
        Some(e) => e,
        None => {
            eprintln!("simulate: unknown --engine (expected vectorized|legacy)");
            return 2;
        }
    };
    let cfg = SimConfig::new(DeviceSpec::v100(), policy)
        .with_engine(engine)
        .with_steal(flag(flags, "steal", "false") == "true");
    let workloads = sgemm_tenants(tenants, iters, shape);
    println!(
        "policy={} engine={} tenants={} shape={}x{}x{} iters={} devices={}",
        cfg.policy.label(),
        cfg.engine.label(),
        tenants,
        shape.m,
        shape.n,
        shape.k,
        iters,
        devices,
    );
    if devices > 1 {
        let pool = gpusim::run_pool(&cfg, &workloads, devices);
        println!(
            "pool: makespan={} aggregate_throughput={} mean_latency={} launches={} (super={})",
            fmt_secs(pool.makespan()),
            fmt_flops(pool.throughput_flops()),
            fmt_secs(pool.mean_latency()),
            pool.kernel_launches(),
            pool.superkernel_launches(),
        );
        for (d, r) in pool.per_device.iter().enumerate() {
            let members = pool.assignment.iter().filter(|&&x| x == d).count();
            println!(
                "  device {d}: tenants={members} makespan={} throughput={} launches={}",
                fmt_secs(r.makespan),
                fmt_flops(r.throughput_flops()),
                r.kernel_launches,
            );
        }
        return 0;
    }
    let report = gpusim::run(&cfg, &workloads);
    println!(
        "makespan={} throughput={} mean_latency={} straggler_gap={:.1}% launches={} (super={}, fused_problems={})",
        fmt_secs(report.makespan),
        fmt_flops(report.throughput_flops()),
        fmt_secs(report.mean_latency()),
        report.straggler_gap() * 100.0,
        report.kernel_launches,
        report.superkernel_launches,
        report.fused_problems,
    );
    if cfg.steal {
        println!("steals={}", report.steals);
    }
    0
}

// ---------------------------------------------------------------------------

/// `simulate --cluster N`: run the cluster tier (sequencer → node workers →
/// in-order committer) instead of the raw device simulator, print per-node
/// and aggregate statistics, and optionally persist the decision journal
/// for `stgpu replay`.
fn cmd_simulate_cluster(flags: &HashMap<String, String>) -> i32 {
    let nodes: usize = match flag(flags, "cluster", "2").parse() {
        Ok(n) => n,
        Err(_) => {
            eprintln!("simulate: bad --cluster value (expected a node count)");
            return 2;
        }
    };
    let mut opts = ClusterOpts::demo(nodes);
    if let Some(r) = flags.get("rounds") {
        match r.parse() {
            Ok(v) => opts.rounds = v,
            Err(_) => {
                eprintln!("simulate: bad --rounds {r:?}");
                return 2;
            }
        }
    }
    if let Some(s) = flags.get("seed") {
        match s.parse() {
            Ok(v) => opts.seed = v,
            Err(_) => {
                eprintln!("simulate: bad --seed {s:?}");
                return 2;
            }
        }
    }
    opts.steal = flag(flags, "steal", "false") == "true";
    let serial = flag(flags, "serial", "false") == "true";
    let report = match run_cluster(&opts, !serial) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("simulate: {e}");
            return 2;
        }
    };
    println!(
        "cluster: nodes={} rounds={} mode={} seed={}",
        opts.nodes,
        opts.rounds,
        if serial { "serial" } else { "parallel" },
        opts.seed,
    );
    let mut table =
        Table::new(&["node", "rounds", "offered", "completed", "dropped", "slo_att", "reconfigs"]);
    for n in &report.nodes {
        let att = if n.completed > 0 { n.hits as f64 / n.completed as f64 } else { 1.0 };
        table.row(&[
            n.node.to_string(),
            n.rounds.to_string(),
            n.offered.to_string(),
            n.completed.to_string(),
            n.dropped.to_string(),
            format!("{:.1}%", att * 100.0),
            n.reconfigs.to_string(),
        ]);
    }
    println!("{}", table.render());
    let per_node: Vec<Json> = report.nodes.iter().map(|n| n.to_json()).collect();
    let agg = aggregate_nodes(&per_node);
    println!(
        "aggregate: offered={} completed={} dropped={} slo_attainment={:.4} goodput={:.1} req/s",
        report.offered,
        report.completed,
        report.dropped,
        agg.get("slo_attainment").and_then(Json::as_f64).unwrap_or(1.0),
        report.goodput_rps(),
    );
    if opts.steal {
        println!(
            "stealing: {} decisions moved {} requests",
            report.steals, report.stolen_requests,
        );
    }
    println!(
        "journal: {} records, digest {}",
        report.journal.records().len(),
        report.journal.digest_hex(),
    );
    if let Some(path) = flags.get("journal") {
        if let Err(e) = report.journal.write_to(std::path::Path::new(path)) {
            eprintln!("simulate: cannot write journal {path}: {e}");
            return 1;
        }
        println!("journal: wrote {path}");
    }
    0
}

// ---------------------------------------------------------------------------

/// `replay <journal>`: re-execute a decision journal's recorded
/// configuration through the deterministic serial path and fail unless the
/// regenerated journal is bitwise identical to the file.
fn cmd_replay(positional: &[String], flags: &HashMap<String, String>) -> i32 {
    let path = match positional.first().map(String::as_str).or_else(|| {
        flags.get("journal").map(String::as_str)
    }) {
        Some(p) => p,
        None => {
            eprintln!("replay: usage: stgpu replay <journal>");
            return 2;
        }
    };
    let journal = match Journal::read_from(std::path::Path::new(path)) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("replay: {e}");
            return 1;
        }
    };
    match replay_journal(&journal) {
        Ok(out) => {
            println!(
                "replay: {} rounds x {} nodes; original digest {}, replayed digest {}",
                out.rounds, out.nodes, out.original, out.replayed
            );
            if out.matches {
                println!("replay: MATCH — journal is a faithful serial re-execution");
                0
            } else {
                eprintln!("replay: MISMATCH — parallel commit order diverged from serial");
                1
            }
        }
        Err(e) => {
            eprintln!("replay: {e}");
            1
        }
    }
}

// ---------------------------------------------------------------------------

fn cmd_tune(flags: &HashMap<String, String>) -> i32 {
    let workload = flag(flags, "workload", "fig12");
    let budget: usize = flag(flags, "budget", "64").parse().unwrap_or(64);
    eprintln!("tune: workload={workload} budget={budget} (each evaluation replays the trace)");
    let report = match tuner::tune(workload, budget) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("tune: {e}");
            return 2;
        }
    };
    let mut ranked: Vec<&stgpu::coordinator::TuneOutcome> = report.outcomes.iter().collect();
    ranked.sort_by(|a, b| b.goodput_rps.partial_cmp(&a.goodput_rps).unwrap());
    let mut table =
        Table::new(&["rank", "config", "goodput_rps", "slo_att", "p50", "p99", "reconfigs"]);
    for (i, o) in ranked.iter().enumerate().take(10) {
        table.row(&[
            (i + 1).to_string(),
            o.label.clone(),
            format!("{:.1}", o.goodput_rps),
            format!("{:.4}", o.attainment),
            fmt_secs(o.p50_s),
            fmt_secs(o.p99_s),
            o.reconfigs.to_string(),
        ]);
    }
    println!("{}", table.render());
    let best = report.best();
    println!(
        "tune: winner after {} evaluations: {} -> {:.1} req/s SLO-met goodput, attainment {:.4}",
        report.outcomes.len(),
        best.label,
        best.goodput_rps,
        best.attainment
    );
    match flags.get("out-toml") {
        Some(path) => {
            if let Err(e) = std::fs::write(path, report.best_toml()) {
                eprintln!("tune: cannot write {path}: {e}");
                return 1;
            }
            println!("tune: wrote {path}");
        }
        None => print!("{}", report.best_toml()),
    }
    if let Some(path) = flags.get("out-leaderboard") {
        let mut body = report.leaderboard_json().to_string();
        body.push('\n');
        if let Err(e) = std::fs::write(path, body) {
            eprintln!("tune: cannot write {path}: {e}");
            return 1;
        }
        println!("tune: wrote {path}");
    }
    if let Some(path) = flags.get("check-baseline") {
        let floor = std::fs::read_to_string(path)
            .map_err(|e| e.to_string())
            .and_then(|s| stgpu::util::json::Json::parse(&s))
            .and_then(|j| {
                j.get("throughput")
                    .and_then(stgpu::util::json::Json::as_f64)
                    .ok_or_else(|| "baseline has no numeric 'throughput'".to_string())
            });
        match floor {
            Ok(floor) => {
                if best.goodput_rps < floor {
                    eprintln!(
                        "tune: winner goodput {:.1} req/s BELOW baseline {floor:.1} ({path})",
                        best.goodput_rps
                    );
                    return 1;
                }
                println!(
                    "tune: winner goodput {:.1} req/s clears baseline {floor:.1} ({path})",
                    best.goodput_rps
                );
            }
            Err(e) => {
                eprintln!("tune: cannot check baseline {path}: {e}");
                return 1;
            }
        }
    }
    0
}

// ---------------------------------------------------------------------------

fn cmd_artifacts(flags: &HashMap<String, String>) -> i32 {
    let dir = flag(flags, "dir", "artifacts");
    let m = match Manifest::load(dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("artifacts: {e}");
            return 1;
        }
    };
    let mut table = Table::new(&["name", "kind", "impl", "r", "m", "n", "k", "flops"]);
    for a in &m.artifacts {
        let (mm, nn, kk) = a.mnk();
        table.row(&[
            a.name.clone(),
            a.kind.clone(),
            a.impl_.clone(),
            a.r().to_string(),
            mm.to_string(),
            nn.to_string(),
            kk.to_string(),
            fmt_flops(a.flops()),
        ]);
    }
    println!("{}", table.render());
    println!("{} artifacts in {dir}", m.len());
    0
}

// ---------------------------------------------------------------------------

fn cmd_trace(flags: &HashMap<String, String>) -> i32 {
    let tenants: usize = flag(flags, "tenants", "4").parse().unwrap_or(4);
    let max_batch: u32 = flag(flags, "max-batch", "64").parse().unwrap_or(64);
    let lanes: u32 = flag(flags, "lanes", "1").parse().unwrap_or(1).max(1);
    let adaptive = flag(flags, "adaptive", "false") == "true";
    let policy = match parse_policy(
        flag(flags, "policy", "space-time"),
        max_batch,
        lanes,
        adaptive,
    ) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("trace: {e}");
            return 2;
        }
    };
    let shape = match parse_shape(flag(flags, "shape", "256x128x1152")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("trace: {e}");
            return 2;
        }
    };
    let engine = match Engine::parse(flag(flags, "engine", "vectorized")) {
        Some(e) => e,
        None => {
            eprintln!("trace: unknown --engine (expected vectorized|legacy)");
            return 2;
        }
    };
    let cfg = SimConfig::new(DeviceSpec::v100(), policy).with_trace().with_engine(engine);
    let workloads = sgemm_tenants(tenants, 3, shape);
    let report = gpusim::run(&cfg, &workloads);
    println!("{}", report.trace.render_gantt(100));
    println!(
        "makespan={} launches={} occupancy={:.0}%",
        fmt_secs(report.trace.makespan()),
        report.trace.launches(),
        report.trace.occupancy(DeviceSpec::v100().sms as f64) * 100.0
    );
    0
}
