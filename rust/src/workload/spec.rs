//! Workload specifications: helpers that turn models / GEMM shapes into the
//! multi-tenant closed-loop workloads the simulator and benches consume.

use crate::gpusim::engine::TenantWorkload;
use crate::gpusim::kernel::{GemmShape, KernelDesc};
use crate::models::graph::ModelGraph;

/// Declarative description of a bench workload (also what the CLI accepts).
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub name: String,
    pub tenants: usize,
    pub iterations: u32,
    pub kind: WorkloadKind,
}

#[derive(Debug, Clone)]
pub enum WorkloadKind {
    /// Every tenant repeatedly submits one SGEMM of this shape
    /// (the paper's Figure 7 / Table 1 microbenchmark).
    Sgemm(GemmShape),
    /// Every tenant serves one model replica at a fixed batch size
    /// (the paper's Figure 3/4 macrobenchmark).
    Model { model: String, batch: u32 },
}

/// `n` tenants each submitting `iterations` SGEMMs of `shape` — the
/// saturated-queue microbenchmark of paper §4.1 ("R SGEMM kernel
/// evaluations are queued").
pub fn sgemm_tenants(n: usize, iterations: u32, shape: GemmShape) -> Vec<TenantWorkload> {
    (0..n)
        .map(|t| TenantWorkload::new(vec![KernelDesc::sgemm(t, shape)], iterations))
        .collect()
}

/// `n` replicas of `model` (same architecture, different weights — paper
/// §2's simplification), each running `iterations` forward passes at
/// `batch`.
pub fn model_tenants(
    n: usize,
    iterations: u32,
    model: &ModelGraph,
    batch: u32,
) -> Vec<TenantWorkload> {
    (0..n)
        .map(|t| TenantWorkload::new(model.lower(t, batch), iterations))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn sgemm_tenants_have_correct_ownership() {
        let w = sgemm_tenants(4, 10, GemmShape::SQUARE_256);
        assert_eq!(w.len(), 4);
        for (t, tw) in w.iter().enumerate() {
            assert_eq!(tw.iterations, 10);
            assert_eq!(tw.kernels.len(), 1);
            assert_eq!(tw.kernels[0].tenant, t);
        }
    }

    #[test]
    fn model_tenants_share_shape_classes() {
        let m = zoo::resnet18(128);
        let w = model_tenants(3, 2, &m, 1);
        assert_eq!(w.len(), 3);
        // Same architecture ⇒ kernel k of tenant i has the same GEMM shape
        // as kernel k of tenant j (the batchability precondition).
        for k in 0..w[0].kernels.len() {
            assert_eq!(w[0].kernels[k].shape, w[1].kernels[k].shape);
            assert_eq!(w[0].kernels[k].shape, w[2].kernels[k].shape);
        }
        // Distinct tenants own their kernels.
        assert!(w[1].kernels.iter().all(|k| k.tenant == 1));
    }

    #[test]
    fn model_tenants_flops_match_model() {
        let m = zoo::mobilenet_v2();
        let w = model_tenants(1, 1, &m, 2);
        let kernel_flops: f64 = w[0].kernels.iter().map(|k| k.flops).sum();
        let rel = (kernel_flops - m.flops(2)).abs() / m.flops(2);
        assert!(rel < 0.05, "lowered FLOPs should match graph FLOPs");
    }
}
