//! Workload generation: closed-loop saturated clients (the paper's §2
//! setting), open-loop Poisson arrivals (future-work scenario kept for the
//! serving examples), and deterministic trace replay.

pub mod arrivals;
pub mod spec;

pub use arrivals::{ArrivalProcess, RequestTrace, TracedRequest};
pub use spec::{sgemm_tenants, model_tenants, WorkloadSpec};
