//! Arrival processes and request traces.
//!
//! The paper's experiments saturate request queues (§2), which the simulator
//! expresses directly as closed-loop iteration counts. The *serving* path
//! (examples/, server/) additionally supports open-loop Poisson arrivals and
//! trace replay so the system is usable beyond the paper's simplification.

use crate::util::prng::Rng;

/// An open-loop arrival process generating request timestamps.
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    /// Poisson arrivals at `rate` requests/second.
    Poisson { rate: f64 },
    /// Deterministic arrivals every `period` seconds.
    Uniform { period: f64 },
    /// Markov-modulated Poisson: alternates `low`/`high` rates with mean
    /// dwell `dwell` seconds — a simple bursty-load model.
    Bursty { low: f64, high: f64, dwell: f64 },
}

impl ArrivalProcess {
    /// Generate arrival timestamps within `[0, horizon)`.
    pub fn generate(&self, rng: &mut Rng, horizon: f64) -> Vec<f64> {
        let mut out = Vec::new();
        match *self {
            ArrivalProcess::Poisson { rate } => {
                assert!(rate > 0.0, "rate must be positive");
                let mut t = rng.gen_exp(rate);
                while t < horizon {
                    out.push(t);
                    t += rng.gen_exp(rate);
                }
            }
            ArrivalProcess::Uniform { period } => {
                assert!(period > 0.0, "period must be positive");
                let mut t = period;
                while t < horizon {
                    out.push(t);
                    t += period;
                }
            }
            ArrivalProcess::Bursty { low, high, dwell } => {
                assert!(low > 0.0 && high >= low && dwell > 0.0);
                let mut t = 0.0;
                let mut phase_high = false;
                let mut phase_end = rng.gen_exp(1.0 / dwell);
                loop {
                    let rate = if phase_high { high } else { low };
                    t += rng.gen_exp(rate);
                    while t > phase_end {
                        phase_high = !phase_high;
                        phase_end += rng.gen_exp(1.0 / dwell);
                    }
                    if t >= horizon {
                        break;
                    }
                    out.push(t);
                }
            }
        }
        out
    }

    /// Mean request rate of the process.
    pub fn mean_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::Uniform { period } => 1.0 / period,
            ArrivalProcess::Bursty { low, high, .. } => (low + high) / 2.0,
        }
    }
}

/// One request in a trace: which tenant, when.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TracedRequest {
    pub t_arrival: f64,
    pub tenant: usize,
}

/// A merged multi-tenant request trace, sorted by arrival time.
#[derive(Debug, Clone, Default)]
pub struct RequestTrace {
    pub requests: Vec<TracedRequest>,
}

impl RequestTrace {
    /// Build a trace from per-tenant arrival processes over `horizon`.
    pub fn generate(
        processes: &[(usize, ArrivalProcess)],
        seed: u64,
        horizon: f64,
    ) -> Self {
        let mut requests = Vec::new();
        for (tenant, proc_) in processes {
            let mut rng = Rng::new(seed ^ (*tenant as u64).wrapping_mul(0x2545_F491_4F6C_DD1D));
            for t in proc_.generate(&mut rng, horizon) {
                requests.push(TracedRequest {
                    t_arrival: t,
                    tenant: *tenant,
                });
            }
        }
        requests.sort_by(|a, b| a.t_arrival.partial_cmp(&b.t_arrival).unwrap());
        Self { requests }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Serialize to CSV (t_arrival, tenant) for replay.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("t_arrival,tenant\n");
        for r in &self.requests {
            s.push_str(&format!("{:.9},{}\n", r.t_arrival, r.tenant));
        }
        s
    }

    /// Parse a CSV produced by [`RequestTrace::to_csv`].
    pub fn from_csv(text: &str) -> Result<Self, String> {
        let mut requests = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if i == 0 || line.trim().is_empty() {
                continue; // header / blank
            }
            let mut parts = line.split(',');
            let t = parts
                .next()
                .and_then(|s| s.trim().parse::<f64>().ok())
                .ok_or_else(|| format!("line {}: bad t_arrival", i + 1))?;
            let tenant = parts
                .next()
                .and_then(|s| s.trim().parse::<usize>().ok())
                .ok_or_else(|| format!("line {}: bad tenant", i + 1))?;
            requests.push(TracedRequest {
                t_arrival: t,
                tenant,
            });
        }
        requests.sort_by(|a, b| a.t_arrival.partial_cmp(&b.t_arrival).unwrap());
        Ok(Self { requests })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_respected() {
        let mut rng = Rng::new(1);
        let p = ArrivalProcess::Poisson { rate: 1000.0 };
        let arrivals = p.generate(&mut rng, 10.0);
        let rate = arrivals.len() as f64 / 10.0;
        assert!((rate - 1000.0).abs() < 50.0, "rate {rate}");
        // sorted & in-range
        assert!(arrivals.windows(2).all(|w| w[0] <= w[1]));
        assert!(arrivals.iter().all(|&t| (0.0..10.0).contains(&t)));
    }

    #[test]
    fn uniform_is_periodic() {
        let mut rng = Rng::new(2);
        let p = ArrivalProcess::Uniform { period: 0.5 };
        let arrivals = p.generate(&mut rng, 5.0);
        assert_eq!(arrivals.len(), 9); // 0.5, 1.0, ..., 4.5
        assert!((arrivals[1] - arrivals[0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn bursty_rate_between_low_and_high() {
        let mut rng = Rng::new(3);
        let p = ArrivalProcess::Bursty {
            low: 100.0,
            high: 2000.0,
            dwell: 0.5,
        };
        let arrivals = p.generate(&mut rng, 20.0);
        let rate = arrivals.len() as f64 / 20.0;
        assert!(rate > 100.0 && rate < 2000.0, "rate {rate}");
    }

    #[test]
    fn trace_merges_and_sorts() {
        let tr = RequestTrace::generate(
            &[
                (0, ArrivalProcess::Poisson { rate: 50.0 }),
                (1, ArrivalProcess::Poisson { rate: 50.0 }),
            ],
            7,
            5.0,
        );
        assert!(!tr.is_empty());
        assert!(tr
            .requests
            .windows(2)
            .all(|w| w[0].t_arrival <= w[1].t_arrival));
        assert!(tr.requests.iter().any(|r| r.tenant == 0));
        assert!(tr.requests.iter().any(|r| r.tenant == 1));
    }

    #[test]
    fn trace_csv_roundtrip() {
        let tr = RequestTrace::generate(&[(0, ArrivalProcess::Uniform { period: 1.0 })], 1, 5.0);
        let csv = tr.to_csv();
        let back = RequestTrace::from_csv(&csv).unwrap();
        assert_eq!(tr.requests.len(), back.requests.len());
        for (a, b) in tr.requests.iter().zip(back.requests.iter()) {
            assert!((a.t_arrival - b.t_arrival).abs() < 1e-9);
            assert_eq!(a.tenant, b.tenant);
        }
    }

    #[test]
    fn trace_csv_rejects_garbage() {
        assert!(RequestTrace::from_csv("t,tenant\nnot-a-number,0\n").is_err());
        assert!(RequestTrace::from_csv("t,tenant\n1.0,not-a-tenant\n").is_err());
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let p = [(0usize, ArrivalProcess::Poisson { rate: 100.0 })];
        let a = RequestTrace::generate(&p, 42, 5.0);
        let b = RequestTrace::generate(&p, 42, 5.0);
        let c = RequestTrace::generate(&p, 43, 5.0);
        assert_eq!(a.requests, b.requests);
        assert_ne!(a.requests, c.requests);
    }
}
