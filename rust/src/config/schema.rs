//! Typed configuration schema for the serving binary and examples.

use std::path::{Path, PathBuf};

use crate::config::toml_lite::{TomlDoc, TomlTable};

/// Which scheduler the coordinator runs (paper §3/§4 policies).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    Exclusive,
    TimeMux,
    SpaceMux,
    SpaceTime,
}

impl SchedulerKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "exclusive" => Ok(Self::Exclusive),
            "time-mux" | "time" => Ok(Self::TimeMux),
            "space-mux" | "space" => Ok(Self::SpaceMux),
            "space-time" | "spacetime" => Ok(Self::SpaceTime),
            other => Err(format!(
                "unknown scheduler {other:?} (expected exclusive|time-mux|space-mux|space-time)"
            )),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Exclusive => "exclusive",
            Self::TimeMux => "time-mux",
            Self::SpaceMux => "space-mux",
            Self::SpaceTime => "space-time",
        }
    }
}

/// One tenant: a deployed model instance with its own weights and SLO.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantConfig {
    pub name: String,
    /// Model zoo entry ("resnet18", "resnet50", "mobilenet_v2", "rnn_cell")
    /// or GEMM shape spec ("sgemm:256x128x1152").
    pub model: String,
    pub batch: u32,
    /// Latency SLO in milliseconds (p99 target for the SLO monitor).
    pub slo_ms: f64,
    /// Seed that derives this tenant's weights (tenants share architecture,
    /// never weights — paper §2).
    pub weight_seed: u64,
}

impl TenantConfig {
    fn from_table(t: &TomlTable, idx: usize) -> Result<Self, String> {
        let name = t
            .get("name")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string())
            .unwrap_or_else(|| format!("tenant{idx}"));
        let model = t
            .get("model")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("tenant {name}: missing model"))?
            .to_string();
        let batch = t.get("batch").and_then(|v| v.as_int()).unwrap_or(1) as u32;
        let slo_ms = t.get("slo_ms").and_then(|v| v.as_float()).unwrap_or(100.0);
        let weight_seed = t
            .get("weight_seed")
            .and_then(|v| v.as_int())
            .unwrap_or(idx as i64) as u64;
        if batch == 0 {
            return Err(format!("tenant {name}: batch must be >= 1"));
        }
        if slo_ms <= 0.0 {
            return Err(format!("tenant {name}: slo_ms must be positive"));
        }
        Ok(Self {
            name,
            model,
            batch,
            slo_ms,
            weight_seed,
        })
    }
}

/// The validated `[controller]` section: the adaptive space-time
/// controller's knobs ([`crate::coordinator::controller`]). With
/// `adaptive = false` (the default) the coordinator never constructs a
/// controller and the static `lanes` / `pipeline_depth` paths run
/// unchanged — bit-for-bit the pre-controller behavior.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ControllerConfig {
    /// Close the loop: re-decide (lanes, depth) online per device shard.
    pub adaptive: bool,
    /// Rounds per decision window — both the evaluation cadence and the
    /// minimum dwell between reconfigurations. Validated to [1, 65536].
    pub dwell_rounds: u32,
    /// Relative predicted-throughput gain a model-driven switch must show
    /// (hysteresis; 0.05 == 5%). Validated finite, >= 0.
    pub improvement: f64,
    /// Windowed deadline-attainment target that arms the controller's SLO
    /// pressure valve. Validated to (0, 1].
    pub slo_target: f64,
    /// Cap on the resident lane count the controller may choose
    /// (candidates are 1..=max_lanes). 0 (default) inherits `lanes` from
    /// `[server]`; explicit values validate to [1, 16] like `lanes`.
    pub max_lanes: usize,
    /// Cap on the effective pipeline depth. 0 (default) inherits
    /// `pipeline_depth`; explicit values validate to [1, 8].
    pub max_depth: usize,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        Self {
            adaptive: false,
            dwell_rounds: 32,
            improvement: 0.05,
            slo_target: 0.99,
            max_lanes: 0,
            max_depth: 0,
        }
    }
}

impl ControllerConfig {
    /// The lane cap with the `0 == inherit` default resolved against the
    /// `[server]` section.
    pub fn max_lanes_or(&self, lanes: usize) -> usize {
        if self.max_lanes == 0 {
            lanes.max(1)
        } else {
            self.max_lanes
        }
    }

    /// The depth cap with the `0 == inherit` default resolved.
    pub fn max_depth_or(&self, pipeline_depth: usize) -> usize {
        if self.max_depth == 0 {
            pipeline_depth.max(1)
        } else {
            self.max_depth
        }
    }

    fn from_table(t: &TomlTable) -> Result<Self, String> {
        let mut c = ControllerConfig::default();
        if let Some(v) = t.get("adaptive").and_then(|v| v.as_bool()) {
            c.adaptive = v;
        }
        if let Some(v) = t.get("dwell_rounds").and_then(|v| v.as_int()) {
            if !(1..=65536).contains(&v) {
                return Err("controller.dwell_rounds must be in [1, 65536]".into());
            }
            c.dwell_rounds = v as u32;
        }
        if let Some(v) = t.get("improvement").and_then(|v| v.as_float()) {
            if !v.is_finite() || v < 0.0 {
                return Err("controller.improvement must be finite and >= 0".into());
            }
            c.improvement = v;
        }
        if let Some(v) = t.get("slo_target").and_then(|v| v.as_float()) {
            if !v.is_finite() || v <= 0.0 || v > 1.0 {
                return Err("controller.slo_target must be in (0, 1]".into());
            }
            c.slo_target = v;
        }
        if let Some(v) = t.get("max_lanes").and_then(|v| v.as_int()) {
            if !(1..=16).contains(&v) {
                return Err("controller.max_lanes must be in [1, 16]".into());
            }
            c.max_lanes = v as usize;
        }
        if let Some(v) = t.get("max_depth").and_then(|v| v.as_int()) {
            if !(1..=8).contains(&v) {
                return Err("controller.max_depth must be in [1, 8]".into());
            }
            c.max_depth = v as usize;
        }
        Ok(c)
    }
}

/// The `[cluster]` section: scale-out across simulated nodes with a
/// replayable decision journal (see `coordinator::cluster`).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Simulated coordinator nodes. 1 (default) keeps the classic
    /// single-process tier. Validated to [1, 64].
    pub nodes: usize,
    /// Hotspot threshold: a node is hot while its offered-load EWMA
    /// exceeds `migrate_util` x its predicted service rate. Validated
    /// finite, > 0.
    pub migrate_util: f64,
    /// Consecutive hot rounds before a tenant migration fires. Validated
    /// to [1, 1024].
    pub migrate_sustain: u32,
    /// Where the decision journal is written (`stgpu replay` input).
    /// `None` keeps the journal in memory only.
    pub journal_path: Option<PathBuf>,
    /// Cross-node work stealing: an idle node may pull queued requests
    /// from the most-backlogged node when the gap is below the migration
    /// threshold (stealing smooths what migration would overreact to).
    /// Every steal is journaled, so replay stays bitwise deterministic.
    /// `false` (default) reproduces the migration-only cluster exactly.
    pub steal: bool,
    /// Minimum backlog gap (requests) between the most- and
    /// least-loaded node before a cross-node steal fires. Validated to
    /// [1, 1_000_000].
    pub steal_gap: usize,
    /// Most requests one cross-node steal may move. Validated to
    /// [1, 4096].
    pub steal_max: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            nodes: 1,
            migrate_util: 0.9,
            migrate_sustain: 3,
            journal_path: None,
            steal: false,
            steal_gap: 8,
            steal_max: 32,
        }
    }
}

impl ClusterConfig {
    fn from_table(t: &TomlTable) -> Result<Self, String> {
        let mut c = ClusterConfig::default();
        if let Some(v) = t.get("nodes").and_then(|v| v.as_int()) {
            if !(1..=64).contains(&v) {
                return Err("cluster.nodes must be in [1, 64]".into());
            }
            c.nodes = v as usize;
        }
        if let Some(v) = t.get("migrate_util").and_then(|v| v.as_float()) {
            if !v.is_finite() || v <= 0.0 {
                return Err("cluster.migrate_util must be finite and > 0".into());
            }
            c.migrate_util = v;
        }
        if let Some(v) = t.get("migrate_sustain").and_then(|v| v.as_int()) {
            if !(1..=1024).contains(&v) {
                return Err("cluster.migrate_sustain must be in [1, 1024]".into());
            }
            c.migrate_sustain = v as u32;
        }
        if let Some(v) = t.get("journal_path").and_then(|v| v.as_str()) {
            c.journal_path = Some(PathBuf::from(v));
        }
        if let Some(v) = t.get("steal").and_then(|v| v.as_bool()) {
            c.steal = v;
        }
        if let Some(v) = t.get("steal_gap").and_then(|v| v.as_int()) {
            if !(1..=1_000_000).contains(&v) {
                return Err("cluster.steal_gap must be in [1, 1000000]".into());
            }
            c.steal_gap = v as usize;
        }
        if let Some(v) = t.get("steal_max").and_then(|v| v.as_int()) {
            if !(1..=4096).contains(&v) {
                return Err("cluster.steal_max must be in [1, 4096]".into());
            }
            c.steal_max = v as usize;
        }
        Ok(c)
    }
}

/// Server configuration (the `stgpu serve` entrypoint and the examples).
#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    pub scheduler: SchedulerKind,
    /// Max problems fused into one super-kernel.
    pub max_batch: u32,
    /// Padding policy: `false` (default) rounds chunks up to the next R
    /// bucket (paper-faithful — padded lanes are ~free on a parallel GPU);
    /// `true` splits chunks into their exact binary bucket decomposition
    /// (zero padding — right when a padded lane costs real compute, e.g.
    /// this repo's CPU-PJRT substrate).
    pub split_exact: bool,
    /// SLO-aware drain (space-time only): visit backlogged tenants in
    /// head-of-queue deadline order instead of round-robin (paper §4.1:
    /// "determine when to execute workloads based on per-model SLOs").
    pub slo_aware: bool,
    /// Deadline-aware (EDF) planning (space-time only): drain earliest-
    /// deadline-first, plan launches against the per-shard cost model,
    /// split fused launches that would blow an urgent deadline, and shed
    /// predicted-infeasible requests at admission with
    /// `Reject::DeadlineInfeasible` (504-style). Implies `slo_aware`.
    pub edf: bool,
    /// Safety margin (seconds, >= 0) subtracted from every deadline budget
    /// by the EDF planner and the admission feasibility check.
    pub deadline_slack: f64,
    /// Spatial execution lanes per device (space-time only): the scheduler
    /// balances each round's fused launches across `lanes` concurrent
    /// streams and the driver executes them overlapped, with the cost
    /// model's co-location interference term keeping predictions honest.
    /// 1 (default) is the classic serial round. Validated to [1, 16].
    pub lanes: usize,
    /// Scheduling rounds allowed in flight per device shard: while round
    /// N executes on the persistent lane workers, the driver drains
    /// admission, plans, and marshals weights for round N+1. `1` is the
    /// old serial round loop (plan → execute → collect, nothing
    /// overlapped); `2` (default) overlaps one round of planning with
    /// execution. Validated to [1, 8].
    pub pipeline_depth: usize,
    /// Work-conserving lane execution (space-time only): an idle lane
    /// whose queue is empty steals queued launches from the back of the
    /// predicted-longest lane, and the balancer may deliberately overpack
    /// the cheapest-to-steal class. `false` (default) keeps per-lane
    /// queues strictly private — bit-for-bit the non-stealing behavior.
    pub steal: bool,
    /// Minimum queued items a lane must hold before a thief may steal
    /// from it (>= 1). Higher values keep thieves off nearly-empty queues
    /// where the owner is about to pick the work up anyway.
    pub steal_min_queue: usize,
    /// How long the batcher waits to accumulate a batch, microseconds.
    pub batch_timeout_us: u64,
    /// Devices in the pool. Tenants are sharded across devices by the
    /// placement layer (least-loaded with shape-class affinity); 1 runs
    /// the classic single-device coordinator.
    pub devices: usize,
    /// Per-tenant admission queue depth.
    pub queue_depth: usize,
    /// Global admission cap across all tenants and devices: once this many
    /// requests are pending, new submissions shed with `Reject::Overloaded`
    /// (429-style) instead of queuing without bound.
    pub queue_cap: usize,
    /// Straggler eviction: tenants slower than `eviction_threshold` × the
    /// median for `eviction_strikes` windows are evicted (paper §4).
    pub eviction_enabled: bool,
    pub eviction_threshold: f64,
    pub eviction_strikes: u32,
    /// Adaptive space-time controller (`[controller]` section): online
    /// (lanes, depth) reconfiguration per device shard. Off by default.
    pub controller: ControllerConfig,
    /// Cluster tier (`[cluster]` section): node count, hotspot-migration
    /// thresholds, and the decision-journal path. Single node by default.
    pub cluster: ClusterConfig,
    /// Gateway tier (`[gateway]` + `[gateway.tenants]` sections): auth,
    /// per-tenant rate limiting, and per-shard circuit breakers in front
    /// of the coordinator. Disabled by default.
    pub gateway: GatewayConfig,
    /// Directory holding the AOT artifacts (HLO text + manifest).
    pub artifacts_dir: PathBuf,
    /// Worker threads executing batches.
    pub workers: usize,
    pub seed: u64,
    pub tenants: Vec<TenantConfig>,
}

/// Isolation class an API key maps to: scales the tenant's token-bucket
/// allowance and picks the default scheduling priority the gateway stamps
/// on requests that don't name one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IsolationClass {
    /// Latency-critical paid tier: biggest bucket, high priority.
    Premium,
    /// The default interactive tier.
    #[default]
    Standard,
    /// Throughput-oriented background tier: smallest bucket, batch
    /// priority, first to shed.
    Batch,
}

impl IsolationClass {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "premium" => Ok(Self::Premium),
            "standard" => Ok(Self::Standard),
            "batch" => Ok(Self::Batch),
            other => Err(format!(
                "unknown isolation class {other:?} (expected premium|standard|batch)"
            )),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Self::Premium => "premium",
            Self::Standard => "standard",
            Self::Batch => "batch",
        }
    }

    /// Multiplier on the `[gateway]` base refill rate for this class.
    pub fn rate_mult(self) -> f64 {
        match self {
            Self::Premium => 4.0,
            Self::Standard => 1.0,
            Self::Batch => 0.25,
        }
    }

    /// Multiplier on the `[gateway]` base burst credit for this class.
    pub fn burst_mult(self) -> f64 {
        match self {
            Self::Premium => 4.0,
            Self::Standard => 1.0,
            Self::Batch => 0.5,
        }
    }
}

/// One `[gateway.tenants]` entry: an API key bound to a tenant (by name,
/// resolved to its index) and an isolation class.
#[derive(Debug, Clone, PartialEq)]
pub struct GatewayTenant {
    pub api_key: String,
    /// Index into `ServerConfig::tenants`.
    pub tenant: usize,
    pub class: IsolationClass,
}

/// The validated `[gateway]` section: the async gateway tier in front of
/// the coordinator (auth → validation → rate limit → admission). With
/// `enabled = false` (the default) the serving path is the bare
/// [`crate::server::ServerHandle`] — bit-for-bit the pre-gateway
/// behavior.
#[derive(Debug, Clone, PartialEq)]
pub struct GatewayConfig {
    pub enabled: bool,
    /// TCP listen address for the reactor (e.g. `"127.0.0.1:7071"`);
    /// `None` runs the gateway in-process only (tests, benches).
    pub listen: Option<String>,
    /// Reactor worker threads handling decoded connections. [1, 64].
    pub reactor_workers: usize,
    /// Keep-alive connections idle (no complete request, no new bytes)
    /// longer than this are closed so they stop pinning a reactor
    /// worker. Milliseconds, [1, 3_600_000].
    pub idle_timeout_ms: f64,
    /// Base token refill rate, requests/second per tenant (scaled by
    /// [`IsolationClass::rate_mult`]). Must be finite and > 0.
    pub rate: f64,
    /// Base burst credit, tokens (scaled by
    /// [`IsolationClass::burst_mult`]). Must be finite and >= 1.
    pub burst: f64,
    /// Sliding outcome window per shard breaker (admissions observed).
    /// [4, 65536].
    pub breaker_window: usize,
    /// Overload fraction of the window that trips the breaker. (0, 1].
    pub breaker_threshold: f64,
    /// How long a tripped breaker stays open before half-opening, ms.
    pub breaker_cooldown_ms: f64,
    /// Successful probes a half-open breaker needs to close. [1, 1024].
    pub half_open_probes: u32,
    /// API-key table from `[gateway.tenants]`.
    pub tenants: Vec<GatewayTenant>,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            listen: None,
            reactor_workers: 4,
            idle_timeout_ms: 10_000.0,
            rate: 64.0,
            burst: 128.0,
            breaker_window: 32,
            breaker_threshold: 0.5,
            breaker_cooldown_ms: 250.0,
            half_open_probes: 3,
            tenants: Vec::new(),
        }
    }
}

impl GatewayConfig {
    /// Parse `[gateway]` + the `[gateway.tenants]` key table. `tenants`
    /// is the already-parsed `[[tenant]]` list — API keys bind to tenant
    /// NAMES and resolve to indices here, so a typo fails at load time,
    /// not at the first request.
    fn from_doc(doc: &TomlDoc, tenants: &[TenantConfig]) -> Result<Self, String> {
        let mut cfg = GatewayConfig::default();
        if let Some(section) = doc.sections.get("gateway") {
            if let Some(v) = section.get("enabled").and_then(|v| v.as_bool()) {
                cfg.enabled = v;
            }
            if let Some(v) = section.get("listen").and_then(|v| v.as_str()) {
                cfg.listen = Some(v.to_string());
            }
            if let Some(v) = section.get("reactor_workers").and_then(|v| v.as_int()) {
                if !(1..=64).contains(&v) {
                    return Err("gateway.reactor_workers must be in [1, 64]".into());
                }
                cfg.reactor_workers = v as usize;
            }
            if let Some(v) = section.get("idle_timeout_ms").and_then(|v| v.as_float()) {
                if !v.is_finite() || !(1.0..=3_600_000.0).contains(&v) {
                    return Err("gateway.idle_timeout_ms must be in [1, 3600000] (ms)".into());
                }
                cfg.idle_timeout_ms = v;
            }
            if let Some(v) = section.get("rate").and_then(|v| v.as_float()) {
                if !v.is_finite() || v <= 0.0 {
                    return Err("gateway.rate must be finite and > 0 (req/s)".into());
                }
                cfg.rate = v;
            }
            if let Some(v) = section.get("burst").and_then(|v| v.as_float()) {
                if !v.is_finite() || v < 1.0 {
                    return Err("gateway.burst must be finite and >= 1 (tokens)".into());
                }
                cfg.burst = v;
            }
            if let Some(v) = section.get("breaker_window").and_then(|v| v.as_int()) {
                if !(4..=65536).contains(&v) {
                    return Err("gateway.breaker_window must be in [4, 65536]".into());
                }
                cfg.breaker_window = v as usize;
            }
            if let Some(v) = section.get("breaker_threshold").and_then(|v| v.as_float()) {
                if !v.is_finite() || v <= 0.0 || v > 1.0 {
                    return Err("gateway.breaker_threshold must be in (0, 1]".into());
                }
                cfg.breaker_threshold = v;
            }
            if let Some(v) = section.get("breaker_cooldown_ms").and_then(|v| v.as_float()) {
                if !v.is_finite() || v <= 0.0 {
                    return Err("gateway.breaker_cooldown_ms must be finite and > 0".into());
                }
                cfg.breaker_cooldown_ms = v;
            }
            if let Some(v) = section.get("half_open_probes").and_then(|v| v.as_int()) {
                if !(1..=1024).contains(&v) {
                    return Err("gateway.half_open_probes must be in [1, 1024]".into());
                }
                cfg.half_open_probes = v as u32;
            }
        }
        if let Some(keys) = doc.sections.get("gateway.tenants") {
            for (api_key, v) in keys.iter() {
                let spec = v.as_str().ok_or_else(|| {
                    format!("gateway.tenants.{api_key}: value must be a \"tenant:class\" string")
                })?;
                let (name, class) = match spec.split_once(':') {
                    Some((n, c)) => (n, IsolationClass::parse(c)?),
                    None => (spec, IsolationClass::Standard),
                };
                let tenant = tenants
                    .iter()
                    .position(|t| t.name == name)
                    .ok_or_else(|| {
                        format!("gateway.tenants.{api_key}: unknown tenant {name:?}")
                    })?;
                if cfg.tenants.iter().any(|k| k.api_key == *api_key) {
                    return Err(format!("gateway.tenants: duplicate API key {api_key:?}"));
                }
                cfg.tenants.push(GatewayTenant {
                    api_key: api_key.clone(),
                    tenant,
                    class,
                });
            }
        }
        if cfg.enabled && cfg.tenants.is_empty() {
            return Err(
                "gateway.enabled = true requires at least one [gateway.tenants] API key".into(),
            );
        }
        Ok(cfg)
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            scheduler: SchedulerKind::SpaceTime,
            max_batch: 64,
            split_exact: false,
            slo_aware: false,
            edf: false,
            deadline_slack: 0.0,
            lanes: 1,
            pipeline_depth: 2,
            steal: false,
            steal_min_queue: 1,
            batch_timeout_us: 200,
            devices: 1,
            queue_depth: 256,
            queue_cap: 4096,
            eviction_enabled: true,
            eviction_threshold: 1.15,
            eviction_strikes: 3,
            controller: ControllerConfig::default(),
            cluster: ClusterConfig::default(),
            gateway: GatewayConfig::default(),
            artifacts_dir: PathBuf::from("artifacts"),
            workers: 1,
            seed: 0,
            tenants: Vec::new(),
        }
    }
}

impl ServerConfig {
    /// Parse from a TOML-subset document.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self, String> {
        let mut cfg = ServerConfig::default();
        let server = doc.sections.get("server").unwrap_or(&doc.root);
        if let Some(v) = server.get("scheduler").and_then(|v| v.as_str()) {
            cfg.scheduler = SchedulerKind::parse(v)?;
        }
        if let Some(v) = server.get("max_batch").and_then(|v| v.as_int()) {
            if v < 1 {
                return Err("max_batch must be >= 1".into());
            }
            cfg.max_batch = v as u32;
        }
        if let Some(v) = server.get("split_exact").and_then(|v| v.as_bool()) {
            cfg.split_exact = v;
        }
        if let Some(v) = server.get("slo_aware").and_then(|v| v.as_bool()) {
            cfg.slo_aware = v;
        }
        if let Some(v) = server.get("edf").and_then(|v| v.as_bool()) {
            cfg.edf = v;
        }
        if let Some(v) = server.get("deadline_slack").and_then(|v| v.as_float()) {
            if !v.is_finite() || v < 0.0 {
                return Err("deadline_slack must be a finite number >= 0 (seconds)".into());
            }
            cfg.deadline_slack = v;
        }
        if let Some(v) = server.get("lanes").and_then(|v| v.as_int()) {
            if !(1..=16).contains(&v) {
                return Err("lanes must be in [1, 16]".into());
            }
            cfg.lanes = v as usize;
        }
        if let Some(v) = server.get("pipeline_depth").and_then(|v| v.as_int()) {
            if !(1..=8).contains(&v) {
                return Err("pipeline_depth must be in [1, 8]".into());
            }
            cfg.pipeline_depth = v as usize;
        }
        if let Some(v) = server.get("steal").and_then(|v| v.as_bool()) {
            cfg.steal = v;
        }
        if let Some(v) = server.get("steal_min_queue").and_then(|v| v.as_int()) {
            if !(1..=64).contains(&v) {
                return Err("steal_min_queue must be in [1, 64]".into());
            }
            cfg.steal_min_queue = v as usize;
        }
        if let Some(v) = server.get("batch_timeout_us").and_then(|v| v.as_int()) {
            cfg.batch_timeout_us = v as u64;
        }
        if let Some(v) = server.get("devices").and_then(|v| v.as_int()) {
            if v < 1 {
                return Err("devices must be >= 1".into());
            }
            cfg.devices = v as usize;
        }
        if let Some(v) = server.get("queue_depth").and_then(|v| v.as_int()) {
            if v < 1 {
                return Err("queue_depth must be >= 1".into());
            }
            cfg.queue_depth = v as usize;
        }
        if let Some(v) = server.get("queue_cap").and_then(|v| v.as_int()) {
            if v < 1 {
                return Err("queue_cap must be >= 1".into());
            }
            cfg.queue_cap = v as usize;
        }
        if let Some(v) = server.get("eviction_enabled").and_then(|v| v.as_bool()) {
            cfg.eviction_enabled = v;
        }
        if let Some(v) = server.get("eviction_threshold").and_then(|v| v.as_float()) {
            if v <= 1.0 {
                return Err("eviction_threshold must be > 1.0".into());
            }
            cfg.eviction_threshold = v;
        }
        if let Some(v) = server.get("eviction_strikes").and_then(|v| v.as_int()) {
            cfg.eviction_strikes = v as u32;
        }
        if let Some(v) = server.get("artifacts_dir").and_then(|v| v.as_str()) {
            cfg.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = server.get("workers").and_then(|v| v.as_int()) {
            cfg.workers = (v as usize).max(1);
        }
        if let Some(v) = server.get("seed").and_then(|v| v.as_int()) {
            cfg.seed = v as u64;
        }
        if let Some(section) = doc.sections.get("controller") {
            cfg.controller = ControllerConfig::from_table(section)?;
        }
        if let Some(section) = doc.sections.get("cluster") {
            cfg.cluster = ClusterConfig::from_table(section)?;
        }
        if let Some(tenants) = doc.lists.get("tenant") {
            cfg.tenants = tenants
                .iter()
                .enumerate()
                .map(|(i, t)| TenantConfig::from_table(t, i))
                .collect::<Result<Vec<_>, _>>()?;
        }
        // Gateway parses AFTER tenants: its API keys bind to tenant names.
        cfg.gateway = GatewayConfig::from_doc(doc, &cfg.tenants)?;
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Self, String> {
        Self::from_doc(&TomlDoc::load(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = r#"
        [server]
        scheduler = "space-time"
        max_batch = 32
        batch_timeout_us = 150
        eviction_threshold = 1.2

        [[tenant]]
        name = "a"
        model = "resnet18"
        batch = 2
        slo_ms = 50.0

        [[tenant]]
        name = "b"
        model = "sgemm:256x128x1152"
    "#;

    #[test]
    fn parses_full_config() {
        let cfg = ServerConfig::from_doc(&TomlDoc::parse(EXAMPLE).unwrap()).unwrap();
        assert_eq!(cfg.scheduler, SchedulerKind::SpaceTime);
        assert_eq!(cfg.max_batch, 32);
        assert_eq!(cfg.batch_timeout_us, 150);
        assert_eq!(cfg.eviction_threshold, 1.2);
        assert_eq!(cfg.tenants.len(), 2);
        assert_eq!(cfg.tenants[0].name, "a");
        assert_eq!(cfg.tenants[0].batch, 2);
        assert_eq!(cfg.tenants[1].model, "sgemm:256x128x1152");
        assert_eq!(cfg.tenants[1].batch, 1); // default
    }

    #[test]
    fn defaults_are_sane() {
        let cfg = ServerConfig::default();
        assert_eq!(cfg.scheduler, SchedulerKind::SpaceTime);
        assert!(cfg.max_batch >= 1);
        assert!(cfg.eviction_threshold > 1.0);
        assert_eq!(cfg.devices, 1, "single device is the default");
        assert!(cfg.queue_cap >= cfg.queue_depth);
    }

    #[test]
    fn gateway_section_parses_keys_and_validates() {
        let doc = TomlDoc::parse(
            r#"
            [gateway]
            enabled = true
            listen = "127.0.0.1:7071"
            reactor_workers = 8
            idle_timeout_ms = 5000
            rate = 100.0
            burst = 200.0
            breaker_window = 16
            breaker_threshold = 0.75
            breaker_cooldown_ms = 100.0
            half_open_probes = 2

            [gateway.tenants]
            key-a = "a:premium"
            key-b = "b"

            [[tenant]]
            name = "a"
            model = "resnet18"

            [[tenant]]
            name = "b"
            model = "resnet18"
            "#,
        )
        .unwrap();
        let cfg = ServerConfig::from_doc(&doc).unwrap();
        let g = &cfg.gateway;
        assert!(g.enabled);
        assert_eq!(g.listen.as_deref(), Some("127.0.0.1:7071"));
        assert_eq!(g.reactor_workers, 8);
        assert_eq!(g.idle_timeout_ms, 5000.0);
        assert_eq!(g.rate, 100.0);
        assert_eq!(g.breaker_window, 16);
        assert_eq!(g.half_open_probes, 2);
        assert_eq!(g.tenants.len(), 2);
        let a = g.tenants.iter().find(|k| k.api_key == "key-a").unwrap();
        assert_eq!((a.tenant, a.class), (0, IsolationClass::Premium));
        let b = g.tenants.iter().find(|k| k.api_key == "key-b").unwrap();
        // Class defaults to standard when the spec has no ":class" suffix.
        assert_eq!((b.tenant, b.class), (1, IsolationClass::Standard));
        // Defaults: disabled, no keys.
        let d = GatewayConfig::default();
        assert!(!d.enabled && d.tenants.is_empty());
    }

    #[test]
    fn gateway_section_rejects_bad_keys() {
        let bad = |s: &str| ServerConfig::from_doc(&TomlDoc::parse(s).unwrap());
        // Unknown tenant name.
        assert!(bad("[gateway.tenants]\nk = \"ghost:premium\"").is_err());
        // Unknown isolation class.
        assert!(bad(
            "[gateway.tenants]\nk = \"a:gold\"\n[[tenant]]\nname = \"a\"\nmodel = \"resnet18\""
        )
        .is_err());
        // Enabled with no keys.
        assert!(bad("[gateway]\nenabled = true").is_err());
        // Out-of-range knobs.
        assert!(bad("[gateway]\nrate = 0.0").is_err());
        assert!(bad("[gateway]\nbreaker_threshold = 1.5").is_err());
        assert!(bad("[gateway]\nbreaker_window = 2").is_err());
    }

    #[test]
    fn isolation_class_scales_and_parses() {
        assert!(IsolationClass::Premium.rate_mult() > IsolationClass::Standard.rate_mult());
        assert!(IsolationClass::Batch.rate_mult() < IsolationClass::Standard.rate_mult());
        assert!(IsolationClass::Premium.burst_mult() >= 1.0);
        assert_eq!(IsolationClass::parse("premium"), Ok(IsolationClass::Premium));
        assert_eq!(IsolationClass::parse("batch"), Ok(IsolationClass::Batch));
        assert!(IsolationClass::parse("gold").is_err());
        assert_eq!(IsolationClass::default(), IsolationClass::Standard);
        assert_eq!(IsolationClass::Premium.as_str(), "premium");
    }

    #[test]
    fn cluster_section_parses_and_validates() {
        let doc = TomlDoc::parse(
            "[cluster]\nnodes = 4\nmigrate_util = 0.8\nmigrate_sustain = 5\njournal_path = \"out/j.bin\"",
        )
        .unwrap();
        let cfg = ServerConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.cluster.nodes, 4);
        assert!((cfg.cluster.migrate_util - 0.8).abs() < 1e-12);
        assert_eq!(cfg.cluster.migrate_sustain, 5);
        assert_eq!(cfg.cluster.journal_path.as_deref(), Some(Path::new("out/j.bin")));
        // Defaults: single node, no journal.
        let d = ClusterConfig::default();
        assert_eq!((d.nodes, d.migrate_sustain), (1, 3));
        assert!(d.journal_path.is_none());
        let bad = |s: &str| ServerConfig::from_doc(&TomlDoc::parse(s).unwrap());
        assert!(bad("[cluster]\nnodes = 0").is_err());
        assert!(bad("[cluster]\nnodes = 65").is_err());
        assert!(bad("[cluster]\nmigrate_util = 0.0").is_err());
        assert!(bad("[cluster]\nmigrate_sustain = 0").is_err());
    }

    #[test]
    fn devices_and_queue_cap_parse_and_validate() {
        let doc = TomlDoc::parse("[server]\ndevices = 4\nqueue_cap = 128").unwrap();
        let cfg = ServerConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.devices, 4);
        assert_eq!(cfg.queue_cap, 128);
        let bad = |s: &str| ServerConfig::from_doc(&TomlDoc::parse(s).unwrap());
        assert!(bad("[server]\ndevices = 0").is_err());
        assert!(bad("[server]\nqueue_cap = 0").is_err());
    }

    #[test]
    fn edf_and_deadline_slack_parse_and_validate() {
        let doc =
            TomlDoc::parse("[server]\nedf = true\ndeadline_slack = 0.002").unwrap();
        let cfg = ServerConfig::from_doc(&doc).unwrap();
        assert!(cfg.edf);
        assert!((cfg.deadline_slack - 0.002).abs() < 1e-12);
        // Defaults: off, zero slack.
        let d = ServerConfig::default();
        assert!(!d.edf);
        assert_eq!(d.deadline_slack, 0.0);
        let bad = |s: &str| ServerConfig::from_doc(&TomlDoc::parse(s).unwrap());
        assert!(bad("[server]\ndeadline_slack = -0.001").is_err());
    }

    #[test]
    fn lanes_parse_and_validate() {
        let doc = TomlDoc::parse("[server]\nlanes = 4").unwrap();
        assert_eq!(ServerConfig::from_doc(&doc).unwrap().lanes, 4);
        assert_eq!(ServerConfig::default().lanes, 1, "serial rounds by default");
        let bad = |s: &str| ServerConfig::from_doc(&TomlDoc::parse(s).unwrap());
        assert!(bad("[server]\nlanes = 0").is_err());
        assert!(bad("[server]\nlanes = 17").is_err());
    }

    #[test]
    fn pipeline_depth_parses_and_validates() {
        let doc = TomlDoc::parse("[server]\npipeline_depth = 3").unwrap();
        assert_eq!(ServerConfig::from_doc(&doc).unwrap().pipeline_depth, 3);
        assert_eq!(
            ServerConfig::default().pipeline_depth,
            2,
            "pipelined round loop by default"
        );
        let one = TomlDoc::parse("[server]\npipeline_depth = 1").unwrap();
        assert_eq!(
            ServerConfig::from_doc(&one).unwrap().pipeline_depth,
            1,
            "1 = the old serial round loop"
        );
        let bad = |s: &str| ServerConfig::from_doc(&TomlDoc::parse(s).unwrap());
        assert!(bad("[server]\npipeline_depth = 0").is_err());
        assert!(bad("[server]\npipeline_depth = 9").is_err());
    }

    #[test]
    fn steal_knobs_parse_and_validate() {
        let doc =
            TomlDoc::parse("[server]\nsteal = true\nsteal_min_queue = 2").unwrap();
        let cfg = ServerConfig::from_doc(&doc).unwrap();
        assert!(cfg.steal);
        assert_eq!(cfg.steal_min_queue, 2);
        // Defaults: off — lanes stay private, bit-for-bit the old driver.
        let d = ServerConfig::default();
        assert!(!d.steal);
        assert_eq!(d.steal_min_queue, 1);
        let bad = |s: &str| ServerConfig::from_doc(&TomlDoc::parse(s).unwrap());
        assert!(bad("[server]\nsteal_min_queue = 0").is_err());
        assert!(bad("[server]\nsteal_min_queue = 65").is_err());
        // Cluster-tier knobs: off by default, journaled when on.
        let doc = TomlDoc::parse(
            "[cluster]\nnodes = 4\nsteal = true\nsteal_gap = 16\nsteal_max = 8",
        )
        .unwrap();
        let cfg = ServerConfig::from_doc(&doc).unwrap();
        assert!(cfg.cluster.steal);
        assert_eq!(cfg.cluster.steal_gap, 16);
        assert_eq!(cfg.cluster.steal_max, 8);
        let d = ClusterConfig::default();
        assert!(!d.steal, "migration-only cluster by default");
        assert!(d.steal_gap >= 1 && d.steal_max >= 1);
        assert!(bad("[cluster]\nsteal_gap = 0").is_err());
        assert!(bad("[cluster]\nsteal_max = 0").is_err());
        assert!(bad("[cluster]\nsteal_max = 4097").is_err());
    }

    #[test]
    fn controller_section_parses_and_validates() {
        let doc = TomlDoc::parse(
            "[server]\nlanes = 4\npipeline_depth = 2\n\
             [controller]\nadaptive = true\ndwell_rounds = 16\n\
             improvement = 0.1\nslo_target = 0.95\nmax_lanes = 8\nmax_depth = 3",
        )
        .unwrap();
        let cfg = ServerConfig::from_doc(&doc).unwrap();
        assert!(cfg.controller.adaptive);
        assert_eq!(cfg.controller.dwell_rounds, 16);
        assert!((cfg.controller.improvement - 0.1).abs() < 1e-12);
        assert!((cfg.controller.slo_target - 0.95).abs() < 1e-12);
        assert_eq!(cfg.controller.max_lanes, 8);
        assert_eq!(cfg.controller.max_depth, 3);
        assert_eq!(cfg.controller.max_lanes_or(cfg.lanes), 8);
        assert_eq!(cfg.controller.max_depth_or(cfg.pipeline_depth), 3);

        let bad = |s: &str| ServerConfig::from_doc(&TomlDoc::parse(s).unwrap());
        assert!(bad("[controller]\ndwell_rounds = 0").is_err());
        assert!(bad("[controller]\nimprovement = -0.1").is_err());
        assert!(bad("[controller]\nslo_target = 0.0").is_err());
        assert!(bad("[controller]\nslo_target = 1.5").is_err());
        assert!(bad("[controller]\nmax_lanes = 17").is_err());
        assert!(bad("[controller]\nmax_lanes = 0").is_err());
        assert!(bad("[controller]\nmax_depth = 9").is_err());
    }

    #[test]
    fn controller_defaults_off_and_inherit_server_caps() {
        // No [controller] section: adaptive is OFF (the static lanes/depth
        // paths run unchanged) and the caps inherit the [server] knobs.
        let doc =
            TomlDoc::parse("[server]\nlanes = 4\npipeline_depth = 3").unwrap();
        let cfg = ServerConfig::from_doc(&doc).unwrap();
        assert!(!cfg.controller.adaptive);
        assert_eq!(cfg.controller.max_lanes, 0, "0 == inherit");
        assert_eq!(cfg.controller.max_lanes_or(cfg.lanes), 4);
        assert_eq!(cfg.controller.max_depth_or(cfg.pipeline_depth), 3);
        assert_eq!(cfg.controller, ControllerConfig::default());
        // An [controller] section with adaptive omitted stays off too.
        let doc2 = TomlDoc::parse("[controller]\ndwell_rounds = 8").unwrap();
        let cfg2 = ServerConfig::from_doc(&doc2).unwrap();
        assert!(!cfg2.controller.adaptive);
        assert_eq!(cfg2.controller.dwell_rounds, 8);
    }

    #[test]
    fn scheduler_kind_parse_all() {
        assert_eq!(
            SchedulerKind::parse("exclusive").unwrap(),
            SchedulerKind::Exclusive
        );
        assert_eq!(SchedulerKind::parse("time").unwrap(), SchedulerKind::TimeMux);
        assert_eq!(
            SchedulerKind::parse("space-mux").unwrap(),
            SchedulerKind::SpaceMux
        );
        assert!(SchedulerKind::parse("warp-mux").is_err());
    }

    #[test]
    fn rejects_invalid_values() {
        let bad = |s: &str| ServerConfig::from_doc(&TomlDoc::parse(s).unwrap());
        assert!(bad("[server]\nmax_batch = 0").is_err());
        assert!(bad("[server]\neviction_threshold = 0.9").is_err());
        assert!(bad("[server]\nqueue_depth = 0").is_err());
        assert!(bad("[[tenant]]\nname = \"x\"").is_err(), "missing model");
        assert!(bad("[[tenant]]\nmodel = \"resnet18\"\nbatch = 0").is_err());
    }

    #[test]
    fn tenant_defaults_fill_in() {
        let cfg = ServerConfig::from_doc(
            &TomlDoc::parse("[[tenant]]\nmodel = \"resnet50\"").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.tenants[0].name, "tenant0");
        assert_eq!(cfg.tenants[0].slo_ms, 100.0);
    }
}
