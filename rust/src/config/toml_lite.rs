//! Minimal TOML-subset parser.
//!
//! Supports exactly what `stgpu` config files use:
//! * `[section]` and `[[array-of-tables]]` headers
//! * `key = "string" | 123 | 1.5 | true | [1, 2, 3]` pairs
//! * `#` comments and blank lines
//!
//! Not supported (rejected with an error, never silently misparsed):
//! nested inline tables, multi-line strings, dotted keys, dates.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// One `[section]` (or one element of a `[[section]]` list).
pub type TomlTable = BTreeMap<String, TomlValue>;

/// A parsed document: top-level keys, named sections, array-of-table lists.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct TomlDoc {
    pub root: TomlTable,
    pub sections: BTreeMap<String, TomlTable>,
    pub lists: BTreeMap<String, Vec<TomlTable>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<Self, String> {
        enum Target {
            Root,
            Section(String),
            ListElem(String),
        }
        let mut doc = TomlDoc::default();
        let mut target = Target::Root;
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
                let name = name.trim().to_string();
                if name.is_empty() {
                    return Err(format!("line {}: empty table name", lineno + 1));
                }
                doc.lists.entry(name.clone()).or_default().push(TomlTable::new());
                target = Target::ListElem(name);
            } else if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                let name = name.trim().to_string();
                if name.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                doc.sections.entry(name.clone()).or_default();
                target = Target::Section(name);
            } else if let Some(eq) = line.find('=') {
                let key = line[..eq].trim().to_string();
                if key.is_empty() {
                    return Err(format!("line {}: empty key", lineno + 1));
                }
                let value = parse_value(line[eq + 1..].trim())
                    .map_err(|e| format!("line {}: {e}", lineno + 1))?;
                let table = match &target {
                    Target::Root => &mut doc.root,
                    Target::Section(name) => doc.sections.get_mut(name).unwrap(),
                    Target::ListElem(name) => {
                        doc.lists.get_mut(name).unwrap().last_mut().unwrap()
                    }
                };
                table.insert(key, value);
            } else {
                return Err(format!("line {}: expected key = value", lineno + 1));
            }
        }
        Ok(doc)
    }

    /// Read a file and parse it.
    pub fn load(path: &std::path::Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        Self::parse(&text)
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string is not a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<TomlValue, String> {
    let t = text.trim();
    if t.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = t.strip_prefix('"') {
        let Some(end) = rest.find('"') else {
            return Err("unterminated string".into());
        };
        if !rest[end + 1..].trim().is_empty() {
            return Err("trailing data after string".into());
        }
        return Ok(TomlValue::Str(rest[..end].to_string()));
    }
    if t == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if t == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = t.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let items: Result<Vec<TomlValue>, String> =
            split_top_level(inner).iter().map(|s| parse_value(s)).collect();
        return Ok(TomlValue::Array(items?));
    }
    if let Ok(i) = t.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = t.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value: {t:?}"))
}

/// Split a comma-separated list, respecting quotes and nested brackets.
fn split_top_level(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut cur = String::new();
    for c in text.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth = depth.saturating_sub(1);
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                out.push(cur.trim().to_string());
                cur.clear();
            }
            c => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur.trim().to_string());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_values() {
        let doc = TomlDoc::parse(
            r#"
            # server config
            seed = 42
            [server]
            scheduler = "space-time"
            max_batch = 64
            timeout_us = 200.5
            verbose = true
            shapes = [256, 128, 1152]
            "#,
        )
        .unwrap();
        assert_eq!(doc.root["seed"].as_int(), Some(42));
        let s = &doc.sections["server"];
        assert_eq!(s["scheduler"].as_str(), Some("space-time"));
        assert_eq!(s["max_batch"].as_int(), Some(64));
        assert_eq!(s["timeout_us"].as_float(), Some(200.5));
        assert_eq!(s["verbose"].as_bool(), Some(true));
        let arr = s["shapes"].as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_int(), Some(1152));
    }

    #[test]
    fn parses_array_of_tables() {
        let doc = TomlDoc::parse(
            r#"
            [[tenant]]
            name = "resnet-a"
            batch = 4
            [[tenant]]
            name = "resnet-b"
            batch = 8
            "#,
        )
        .unwrap();
        let tenants = &doc.lists["tenant"];
        assert_eq!(tenants.len(), 2);
        assert_eq!(tenants[0]["name"].as_str(), Some("resnet-a"));
        assert_eq!(tenants[1]["batch"].as_int(), Some(8));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse(r##"label = "a#b"  # real comment"##).unwrap();
        assert_eq!(doc.root["label"].as_str(), Some("a#b"));
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(TomlDoc::parse("just some words").is_err());
        assert!(TomlDoc::parse("key = ").is_err());
        assert!(TomlDoc::parse("[]").is_err());
        assert!(TomlDoc::parse(r#"k = "unterminated"#).is_err());
        assert!(TomlDoc::parse("k = [1, ").is_err());
    }

    #[test]
    fn int_coerces_to_float_but_not_reverse() {
        let doc = TomlDoc::parse("a = 3\nb = 2.5").unwrap();
        assert_eq!(doc.root["a"].as_float(), Some(3.0));
        assert_eq!(doc.root["b"].as_int(), None);
    }

    #[test]
    fn nested_arrays() {
        let doc = TomlDoc::parse(r#"m = [[1, 2], [3, 4]]"#).unwrap();
        let outer = doc.root["m"].as_array().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[1].as_array().unwrap()[0].as_int(), Some(3));
    }
}
