//! Configuration system: a TOML-subset parser (sections, key = value,
//! strings / numbers / booleans / inline arrays) plus the typed schema the
//! server and benches consume. `toml`/`serde` are not vendored offline —
//! see DESIGN.md §7.

pub mod schema;
pub mod toml_lite;

pub use schema::{
    ClusterConfig, ControllerConfig, GatewayConfig, GatewayTenant, IsolationClass,
    SchedulerKind, ServerConfig, TenantConfig,
};
pub use toml_lite::TomlDoc;
