//! The LanePool's synchronization protocol, extracted behind a small
//! `Sync`-abstraction so the SAME generic code runs under two environments:
//!
//! * [`StdEnv`] — `std::sync::mpsc` channels + `std::thread` workers. This
//!   is what production uses; [`crate::coordinator::lanepool::LanePool`] is
//!   a thin wrapper over `LaneProtocol<StdEnv, WorkItem, Completion>`.
//! * `ModelEnv` (in [`crate::util::modelcheck`]) — cooperative virtual
//!   threads whose every channel operation is a *decision point* for a
//!   DFS schedule explorer. The model-check tests in
//!   `tests/modelcheck_protocol.rs` and `tests/modelcheck_steal.rs` run the
//!   protocol below under **every** interleaving (up to a bounded-preemption
//!   cap) and assert the conservation invariants the example-based tests can
//!   only sample.
//!
//! Since the work-stealing PR, per-lane queues are **stealable deques**, not
//! SPSC channels. The layout:
//!
//! * **Shared deque state** — one `VecDeque` per lane plus per-lane
//!   predicted-remaining sums, guarded by a single mutex. The mutex is only
//!   ever held *between* environment decision points (never across a channel
//!   op or the runner), so under the model environment every critical
//!   section is atomic per explored step and the mutex is always
//!   uncontended — the explorer still covers all orderings of the critical
//!   sections because each vthread reaches its section through a decision
//!   point.
//! * **Owner pops front, thief pops back** — a lane worker takes from the
//!   front of its own queue (FIFO per lane, exactly the pre-steal order);
//!   an idle worker whose own queue is empty steals from the *back* of the
//!   predicted-longest remaining queue (ties break to the lowest lane).
//!   [`LaneTagged::set_executed`] records where an item actually ran so
//!   completions keep their *planned* round/lane tags for cost-model
//!   attribution while reporting the executing lane for steal accounting.
//! * **Wake tokens** — all blocking goes through one wake-token channel per
//!   lane. A worker marks itself idle under the lock *before* parking on
//!   its wake receiver; anyone who makes work available (dispatch, resize,
//!   enabling steal) clears the idle flag at token-send time, so at most
//!   one token is ever outstanding per parked worker and the channel buffer
//!   makes lost wakeups impossible. A `None` from the wake receiver (its
//!   sender dropped at retire/shutdown) is just another reason to re-check
//!   the deque state — the observable condition always lives in the state,
//!   never in the token.
//! * **Round tags** — items carry their round id through dispatch and back
//!   on the completion; conservation (`collected + drained == dispatched`,
//!   per round) is the checker's core assertion, now with stealing on.
//! * **Resize grow/retire/drain** — retiring a lane moves everything still
//!   queued on it to the least-loaded survivor under the lock (no item is
//!   ever abandoned), stamps the lane's owner id so the retired worker
//!   exits at its next re-check, and only then drops its wake sender.
//!   Growing spawns fresh workers with new owner ids — a worker from an
//!   earlier life of the same lane index can never race the replacement,
//!   because its owner check fails before it touches a queue.
//! * **Panic containment** — converting executor panics to `Err` payloads
//!   is the [`ItemRunner`]'s job, so a worker thread never dies mid-round.
//!
//! Stealing is disabled around solo-calibration probe rounds (the driver
//! flips [`LaneProtocol::set_steal`]) so probe measurements stay genuinely
//! un-overlapped, and is off by default — with `steal = false` the protocol
//! behaves exactly like the pre-steal SPSC pool: owners drain their own
//! queues in FIFO order and nothing else touches them.

use crate::util::sync::lock_recover;
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

/// Payload that can flow through a protocol channel. `fingerprint` is the
/// model checker's state-hash hook: two payloads with equal fingerprints
/// are treated as equivalent when pruning visited states. Production types
/// keep the default (state hashing is only used under the checker).
pub trait ProtoPayload: Send + 'static {
    fn fingerprint(&self) -> u64 {
        0
    }
}

/// Wake token for parked lane workers. Carries no data on purpose: every
/// observable condition (work queued, steal enabled, lane retired, pool
/// closed) lives in the shared deque state, and a woken worker re-derives
/// what to do from there — tokens can be spuriously consumed or arrive
/// late without breaking anything.
pub struct Wake;

impl ProtoPayload for Wake {}

/// Sending half of a protocol channel. Cloned by the environment when a
/// worker needs its own handle (the completion channel is MPSC).
pub trait ProtoSender<T>: Clone + Send + 'static {
    /// Queue `value`; returns it back if the receiving side is gone.
    fn send(&self, value: T) -> Result<(), T>;
}

/// Receiving half of a protocol channel.
pub trait ProtoReceiver<T>: Send + 'static {
    /// Block until a value arrives; `None` once every sender is dropped
    /// and the queue is empty.
    fn recv(&self) -> Option<T>;
    /// Non-blocking variant used by the shutdown drain.
    fn try_recv(&self) -> Option<T>;
}

/// Join handle for a spawned protocol worker.
pub trait ProtoJoin {
    fn join(self);
}

/// The synchronization environment the protocol is generic over. GATs let
/// `StdEnv` hand out real `mpsc` endpoints while the model environment
/// hands out checker-instrumented ones, with the protocol code unchanged.
pub trait SyncEnv: 'static {
    type Sender<T: ProtoPayload>: ProtoSender<T>;
    type Receiver<T: ProtoPayload>: ProtoReceiver<T>;
    type Join: ProtoJoin;

    fn channel<T: ProtoPayload>() -> (Self::Sender<T>, Self::Receiver<T>);
    fn spawn(name: String, f: impl FnOnce() + Send + 'static) -> Self::Join;
    /// Cooperative scheduling point. A no-op under [`StdEnv`]; under the
    /// model environment it is an extra decision point, letting runner
    /// bodies expose intermediate states to the explorer.
    fn yield_now() {}
}

/// Work items carry their target lane; the protocol clamps and rewrites it
/// at dispatch (plans targeting retired lanes fold onto survivors).
pub trait LaneTagged {
    fn lane(&self) -> usize;
    fn set_lane(&mut self, lane: usize);
    /// Predicted execution cost, used to pick the steal victim (the lane
    /// with the largest predicted-remaining backlog) and the least-loaded
    /// survivor on a resize drain. The default treats every item as unit
    /// cost, which degrades victim selection to longest-queue — correct,
    /// just less informed.
    fn cost(&self) -> f64 {
        1.0
    }
    /// Record where the item actually executed and whether it was stolen.
    /// Called under the deque lock just before the item is handed to the
    /// runner; the *planned* lane tag from [`LaneTagged::set_lane`] is left
    /// untouched so completions attribute to the plan. Default: no-op (for
    /// payloads that don't track execution placement).
    fn set_executed(&mut self, _lane: usize, _stolen: bool) {}
}

/// What a lane worker runs per item. Implementations MUST NOT panic —
/// panic containment (catch_unwind → `Err` completion) is the runner's
/// responsibility, because a dead worker with live siblings leaves the
/// completion channel open and the driver blocked forever on a round that
/// can no longer drain.
pub trait ItemRunner<W, C>: Send + Sync + 'static {
    fn run(&self, item: W) -> C;
}

/// Owner id stamped on a retired lane so the outgoing worker's next
/// re-check fails and it exits (real ids count up from 0 and never reach
/// this).
const RETIRED: u64 = u64::MAX;

/// The shared stealable-deque state. One mutex guards all of it: lane
/// queues are touched from the driver (dispatch/resize) and every worker
/// (own pops + steals), and a single lock keeps the cross-lane invariants
/// (`rem` sums, idle flags, owner ids) atomic with the queue edits. The
/// lock is never held across a channel operation or the runner.
struct DequeState<W> {
    /// Per-lane FIFO of `(predicted cost, item)`. Owner pops front, thief
    /// pops back. Indexed by lane; retired lanes keep their (empty) slot
    /// so historical `steals` counters survive resizes.
    queues: Vec<VecDeque<(f64, W)>>,
    /// Predicted-remaining cost per lane (sum of queued costs). Steal
    /// victim selection is argmax over this; resize drains re-home items
    /// onto the argmin survivor.
    rem: Vec<f64>,
    /// Whether the lane's worker is parked on its wake channel. Set by the
    /// worker under this lock before parking; cleared by whoever sends the
    /// wake token, so at most one token is outstanding per parked worker.
    idle: Vec<bool>,
    /// Spawn id of the lane's current worker. A worker whose id no longer
    /// matches (lane retired, or retired-then-regrown) exits without
    /// touching the queues.
    owner: Vec<u64>,
    /// Items stolen BY each lane (thief-side attribution), lifetime.
    steals: Vec<u64>,
    /// Deque capacity growths (a push that found `len == capacity`).
    /// Post-warmup this must stay flat — the steal path reuses the same
    /// buffers the SPSC path warmed up.
    grows: u64,
    /// Work stealing enabled. Off: owners drain their own queues in FIFO
    /// order and nothing else touches them (bit-for-bit the pre-steal
    /// pool).
    steal: bool,
    /// Minimum victim queue length for a steal (>= 1).
    steal_min: usize,
    /// Shutdown flag: workers drain their own queue, then exit instead of
    /// parking. Set before wake senders are dropped, so a `None` recv
    /// always finds an exit condition on re-check.
    closed: bool,
}

/// What a worker should do next, decided atomically under the deque lock.
enum Step<W> {
    Run(W),
    Park,
    Exit,
}

/// One atomic scheduling decision for the worker on `lane` with owner id
/// `id`: own front first (FIFO per lane, and the drain guarantee — a
/// closing worker empties its own queue before exiting), then a steal from
/// the back of the predicted-longest other lane, then exit-or-park.
// lint: hot-path
fn take_work<W: LaneTagged>(
    state: &Mutex<DequeState<W>>,
    lane: usize,
    id: u64,
) -> Step<W> {
    let mut st = lock_recover(state);
    if st.owner[lane] != id {
        return Step::Exit; // lane retired (or retired-then-regrown)
    }
    if let Some((cost, mut item)) = st.queues[lane].pop_front() {
        st.rem[lane] -= cost;
        if st.rem[lane] < 0.0 {
            st.rem[lane] = 0.0; // float drift never goes negative
        }
        item.set_executed(lane, false);
        return Step::Run(item);
    }
    if st.steal && !st.closed {
        // Victim: the lane with the largest predicted-remaining backlog
        // whose queue clears the steal threshold; ties break low.
        let mut victim = usize::MAX;
        let mut best = 0.0f64;
        for l in 0..st.queues.len() {
            let qlen = st.queues[l].len();
            if l == lane || qlen == 0 || qlen < st.steal_min {
                continue;
            }
            if victim == usize::MAX || st.rem[l] > best {
                victim = l;
                best = st.rem[l];
            }
        }
        if victim != usize::MAX {
            let (cost, mut item) =
                st.queues[victim].pop_back().expect("victim checked nonempty");
            st.rem[victim] -= cost;
            if st.rem[victim] < 0.0 {
                st.rem[victim] = 0.0;
            }
            st.steals[lane] += 1;
            item.set_executed(lane, true);
            return Step::Run(item);
        }
    }
    if st.closed {
        return Step::Exit;
    }
    st.idle[lane] = true;
    Step::Park
}

/// One worker's loop: take a scheduling decision under the lock, run work
/// outside it, park on the wake channel when there is nothing to do. Both
/// `Some(Wake)` and `None` (wake sender dropped at retire/shutdown) just
/// re-check: state changes always precede the signal that delivers them.
fn worker_loop<E: SyncEnv, W: ProtoPayload + LaneTagged, C: ProtoPayload>(
    state: Arc<Mutex<DequeState<W>>>,
    wake_rx: E::Receiver<Wake>,
    done_tx: E::Sender<C>,
    runner: Arc<dyn ItemRunner<W, C>>,
    lane: usize,
    id: u64,
) {
    loop {
        match take_work(&state, lane, id) {
            Step::Run(item) => {
                let done = runner.run(item);
                if done_tx.send(done).is_err() {
                    return; // driver gone: nobody to report to
                }
            }
            Step::Park => {
                let _ = wake_rx.recv();
            }
            Step::Exit => return,
        }
    }
}

/// The generic persistent lane pool: `lanes` workers over stealable deques,
/// one wake channel each, one shared completion channel. See the module
/// docs for the protocol invariants; see
/// [`crate::coordinator::lanepool::LanePool`] for the production
/// instantiation and user-facing docs.
pub struct LaneProtocol<E: SyncEnv, W: ProtoPayload + LaneTagged, C: ProtoPayload> {
    state: Arc<Mutex<DequeState<W>>>,
    /// Wake-token senders, one per active lane (`wake_tx.len()` is the
    /// pool width). Dropping one (truncate on retire, clear on shutdown)
    /// unblocks the parked worker with `None`.
    wake_tx: Vec<E::Sender<Wake>>,
    completions: E::Receiver<C>,
    /// Kept so `resize` can hand fresh workers the shared channel — and so
    /// the channel stays open for the protocol's lifetime (a dead worker
    /// surfaces as items that never complete, not a closed-channel error).
    done_tx: E::Sender<C>,
    runner: Arc<dyn ItemRunner<W, C>>,
    /// Every worker ever spawned (active and retired); joined on drop.
    workers: Vec<E::Join>,
    /// Lifetime worker spawns (names and owner ids stay unique across
    /// resizes).
    spawned: u64,
    dispatched: u64,
    collected: u64,
}

impl<E: SyncEnv, W: ProtoPayload + LaneTagged, C: ProtoPayload> LaneProtocol<E, W, C> {
    pub fn new(lanes: usize, runner: Arc<dyn ItemRunner<W, C>>) -> Self {
        let (done_tx, done_rx) = E::channel::<C>();
        let mut proto = Self {
            state: Arc::new(Mutex::new(DequeState {
                queues: Vec::new(),
                rem: Vec::new(),
                idle: Vec::new(),
                owner: Vec::new(),
                steals: Vec::new(),
                grows: 0,
                steal: false,
                steal_min: 1,
                closed: false,
            })),
            wake_tx: Vec::new(),
            completions: done_rx,
            done_tx,
            runner,
            workers: Vec::new(),
            spawned: 0,
            dispatched: 0,
            collected: 0,
        };
        proto.resize(lanes);
        proto
    }

    /// Change the resident lane count (clamped to >= 1) without losing any
    /// item or in-flight completion. Shrinking re-homes everything still
    /// queued on a retiring lane onto the least-loaded survivor (rewriting
    /// the lane tag), stamps the retired owner id, and drops the wake
    /// sender — the outgoing worker finishes its current item (reported
    /// normally) and exits at its next re-check. Growing spawns fresh
    /// workers with new owner ids. Retired handles are joined lazily at
    /// shutdown/drop so a resize never blocks the round loop.
    pub fn resize(&mut self, lanes: usize) {
        let lanes = lanes.max(1);
        let cur = self.wake_tx.len();
        if lanes < cur {
            let mut wakes: Vec<usize> = Vec::new();
            {
                let mut st = lock_recover(&self.state);
                for lane in lanes..cur {
                    while let Some((cost, mut item)) = st.queues[lane].pop_front() {
                        let mut dst = 0usize;
                        for l in 1..lanes {
                            if st.rem[l] < st.rem[dst] {
                                dst = l;
                            }
                        }
                        item.set_lane(dst);
                        let q = &mut st.queues[dst];
                        if q.len() == q.capacity() {
                            st.grows += 1;
                        }
                        q.push_back((cost, item));
                        st.rem[dst] += cost;
                    }
                    st.rem[lane] = 0.0;
                    st.owner[lane] = RETIRED;
                    st.idle[lane] = false;
                }
                // Survivors that parked before the drain may now have
                // work (their own queue grew, or steal can reach the
                // re-homed backlog): clear idle at token-send decision.
                for lane in 0..lanes {
                    if st.idle[lane]
                        && (!st.queues[lane].is_empty()
                            || (st.steal
                                && st.queues.iter().any(|q| !q.is_empty())))
                    {
                        st.idle[lane] = false;
                        wakes.push(lane);
                    }
                }
            }
            // State changes above happen-before the sender drops below, so
            // a retired worker's `None` recv always finds RETIRED on
            // re-check.
            self.wake_tx.truncate(lanes);
            for lane in wakes {
                let _ = self.wake_tx[lane].send(Wake);
            }
        }
        while self.wake_tx.len() < lanes {
            let lane = self.wake_tx.len();
            let id = self.spawned;
            self.spawned += 1;
            {
                let mut st = lock_recover(&self.state);
                if st.queues.len() <= lane {
                    st.queues.push(VecDeque::new());
                    st.rem.push(0.0);
                    st.idle.push(false);
                    st.owner.push(id);
                    st.steals.push(0);
                } else {
                    // Reviving a previously retired slot: its queue was
                    // drained at retire, so only the ownership changes.
                    st.owner[lane] = id;
                    st.idle[lane] = false;
                    st.rem[lane] = 0.0;
                }
            }
            let (tx, rx) = E::channel::<Wake>();
            self.wake_tx.push(tx);
            let name = format!("stgpu-lane-{lane}.{id}");
            let done_tx = self.done_tx.clone();
            let runner = self.runner.clone();
            let state = self.state.clone();
            self.workers.push(E::spawn(name, move || {
                worker_loop::<E, W, C>(state, rx, done_tx, runner, lane, id)
            }));
        }
    }

    pub fn lanes(&self) -> usize {
        self.wake_tx.len()
    }

    /// Enable or disable work stealing. Turning it on wakes every parked
    /// worker when any backlog exists (they can now steal it); turning it
    /// off lets in-progress steals finish but prevents new ones — the next
    /// `take_work` sees the flag. The driver flips this around
    /// solo-calibration probe rounds.
    pub fn set_steal(&mut self, on: bool) {
        let mut wakes: Vec<usize> = Vec::new();
        {
            let mut st = lock_recover(&self.state);
            st.steal = on;
            if on && st.queues.iter().any(|q| !q.is_empty()) {
                for l in 0..self.wake_tx.len() {
                    if st.idle[l] {
                        st.idle[l] = false;
                        wakes.push(l);
                    }
                }
            }
        }
        for l in wakes {
            let _ = self.wake_tx[l].send(Wake);
        }
    }

    /// Whether stealing is currently enabled.
    pub fn stealing(&self) -> bool {
        lock_recover(&self.state).steal
    }

    /// Minimum victim queue length for a steal (clamped to >= 1).
    pub fn set_steal_min(&mut self, min: usize) {
        lock_recover(&self.state).steal_min = min.max(1);
    }

    /// Lifetime items stolen BY each lane (thief-side). Indexed by lane
    /// slot — may be longer than the active width after a shrink, so
    /// historical counters survive resizes.
    pub fn lane_steals(&self) -> Vec<u64> {
        lock_recover(&self.state).steals.clone()
    }

    /// Lifetime steals across all lanes.
    pub fn steals_total(&self) -> u64 {
        lock_recover(&self.state).steals.iter().sum()
    }

    /// Deque-capacity growths (pushes that found a full buffer). Flat
    /// post-warmup == the steal path allocates nothing on the hot path.
    pub fn queue_grows(&self) -> u64 {
        lock_recover(&self.state).grows
    }

    /// Queue one item on its lane (clamped to the pool width; the item's
    /// lane tag is rewritten so its completion reports the lane it was
    /// planned onto after clamping). Wakes the owner if it is parked —
    /// or, with stealing on, the first parked lane, which can steal the
    /// new backlog. Returns immediately.
    // lint: hot-path
    pub fn dispatch(&mut self, mut item: W) {
        let width = self.wake_tx.len();
        let lane = item.lane().min(width - 1);
        item.set_lane(lane);
        self.dispatched += 1;
        let cost = item.cost();
        let mut wake = usize::MAX;
        {
            let mut st = lock_recover(&self.state);
            let q = &mut st.queues[lane];
            if q.len() == q.capacity() {
                st.grows += 1;
            }
            q.push_back((cost, item));
            st.rem[lane] += cost;
            if st.idle[lane] {
                st.idle[lane] = false;
                wake = lane;
            } else if st.steal {
                for l in 0..width {
                    if st.idle[l] {
                        st.idle[l] = false;
                        wake = l;
                        break;
                    }
                }
            }
        }
        // Token sent OUTSIDE the lock: a channel op is an environment
        // decision point and the lock must never be held across one.
        if wake != usize::MAX {
            let _ = self.wake_tx[wake].send(Wake);
        }
    }

    /// Block for the next completion (any lane, any in-flight round);
    /// `None` only if every worker terminated unexpectedly.
    // lint: hot-path
    pub fn collect(&mut self) -> Option<C> {
        let c = self.completions.recv()?;
        self.collected += 1;
        Some(c)
    }

    /// Items dispatched but not yet collected.
    pub fn in_flight(&self) -> u64 {
        self.dispatched - self.collected
    }

    /// Close the pool, join every worker, and return the completions that
    /// finished but were never collected — the zero-lost-completions drain
    /// contract: `collected + leftover.len() == dispatched` as long as
    /// every dispatched item executed. Each worker drains its OWN queue
    /// before exiting (the own-front pop precedes the closed check), so
    /// backlog is executed, not dropped, even with stealing off.
    pub fn shutdown_drain(&mut self) -> Vec<C> {
        self.close();
        for w in self.workers.drain(..) {
            w.join();
        }
        let mut leftover = Vec::new();
        while let Some(c) = self.completions.try_recv() {
            self.collected += 1;
            leftover.push(c);
        }
        leftover
    }

    /// Set `closed` (under the lock) and only then drop the wake senders:
    /// a parked worker's `None` recv re-checks and finds the exit
    /// condition already visible.
    fn close(&mut self) {
        {
            let mut st = lock_recover(&self.state);
            st.closed = true;
            for i in st.idle.iter_mut() {
                *i = false;
            }
        }
        self.wake_tx.clear();
    }
}

impl<E: SyncEnv, W: ProtoPayload + LaneTagged, C: ProtoPayload> Drop
    for LaneProtocol<E, W, C>
{
    fn drop(&mut self) {
        self.close();
        for w in self.workers.drain(..) {
            w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// StdEnv: the production environment over std::sync::mpsc + std::thread.
// ---------------------------------------------------------------------------

/// Production environment: real OS threads and `std::sync::mpsc` channels.
pub struct StdEnv;

/// Newtype senders/receivers so the GAT impls stay coherent.
pub struct StdSender<T>(std::sync::mpsc::Sender<T>);

impl<T> Clone for StdSender<T> {
    fn clone(&self) -> Self {
        StdSender(self.0.clone())
    }
}

pub struct StdReceiver<T>(std::sync::mpsc::Receiver<T>);

impl<T: ProtoPayload> ProtoSender<T> for StdSender<T> {
    fn send(&self, value: T) -> Result<(), T> {
        self.0.send(value).map_err(|e| e.0)
    }
}

impl<T: ProtoPayload> ProtoReceiver<T> for StdReceiver<T> {
    fn recv(&self) -> Option<T> {
        self.0.recv().ok()
    }

    fn try_recv(&self) -> Option<T> {
        self.0.try_recv().ok()
    }
}

pub struct StdJoin(std::thread::JoinHandle<()>);

impl ProtoJoin for StdJoin {
    fn join(self) {
        let _ = self.0.join();
    }
}

impl SyncEnv for StdEnv {
    type Sender<T: ProtoPayload> = StdSender<T>;
    type Receiver<T: ProtoPayload> = StdReceiver<T>;
    type Join = StdJoin;

    fn channel<T: ProtoPayload>() -> (StdSender<T>, StdReceiver<T>) {
        let (tx, rx) = std::sync::mpsc::channel();
        (StdSender(tx), StdReceiver(rx))
    }

    fn spawn(name: String, f: impl FnOnce() + Send + 'static) -> StdJoin {
        StdJoin(
            std::thread::Builder::new()
                .name(name)
                .spawn(f)
                .expect("spawn lane worker"),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex};

    struct Item {
        round: u64,
        lane: usize,
        executed: usize,
        stolen: bool,
        gate: bool,
    }

    fn it(round: u64, lane: usize) -> Item {
        Item { round, lane, executed: usize::MAX, stolen: false, gate: false }
    }

    impl ProtoPayload for Item {}
    impl LaneTagged for Item {
        fn lane(&self) -> usize {
            self.lane
        }
        fn set_lane(&mut self, lane: usize) {
            self.lane = lane;
        }
        fn set_executed(&mut self, lane: usize, stolen: bool) {
            self.executed = lane;
            self.stolen = stolen;
        }
    }

    struct Done {
        round: u64,
        lane: usize,
        executed: usize,
        stolen: bool,
    }
    impl ProtoPayload for Done {}

    struct Echo;
    impl ItemRunner<Item, Done> for Echo {
        fn run(&self, item: Item) -> Done {
            Done {
                round: item.round,
                lane: item.lane,
                executed: item.executed,
                stolen: item.stolen,
            }
        }
    }

    /// Blocks on items with `gate = true` until the test opens the gate;
    /// signals entry so tests can wait until a worker is provably inside.
    struct GateExec {
        gate: Arc<(Mutex<(bool, u32)>, Condvar)>,
    }
    impl GateExec {
        fn new() -> (Arc<(Mutex<(bool, u32)>, Condvar)>, Self) {
            let gate = Arc::new((Mutex::new((false, 0)), Condvar::new()));
            (gate.clone(), GateExec { gate })
        }
        fn wait_entered(gate: &Arc<(Mutex<(bool, u32)>, Condvar)>, n: u32) {
            let (m, cv) = &**gate;
            let mut st = m.lock().unwrap();
            while st.1 < n {
                st = cv.wait(st).unwrap();
            }
        }
        fn open(gate: &Arc<(Mutex<(bool, u32)>, Condvar)>) {
            let (m, cv) = &**gate;
            m.lock().unwrap().0 = true;
            cv.notify_all();
        }
    }
    impl ItemRunner<Item, Done> for GateExec {
        fn run(&self, item: Item) -> Done {
            if item.gate {
                let (m, cv) = &*self.gate;
                let mut st = m.lock().unwrap();
                st.1 += 1;
                cv.notify_all();
                while !st.0 {
                    st = cv.wait(st).unwrap();
                }
            }
            Done {
                round: item.round,
                lane: item.lane,
                executed: item.executed,
                stolen: item.stolen,
            }
        }
    }

    #[test]
    fn std_env_round_trip_conserves_items() {
        let mut p: LaneProtocol<StdEnv, Item, Done> = LaneProtocol::new(2, Arc::new(Echo));
        for round in 0..6u64 {
            p.dispatch(it(round, round as usize % 2));
        }
        let mut seen = 0u64;
        for _ in 0..4 {
            let d = p.collect().expect("workers alive");
            assert!(d.round < 6 && d.lane < 2);
            seen += 1;
        }
        let leftover = p.shutdown_drain();
        assert_eq!(seen + leftover.len() as u64, 6);
        assert_eq!(p.in_flight(), 0);
    }

    #[test]
    fn std_env_dispatch_clamps_lane() {
        let mut p: LaneProtocol<StdEnv, Item, Done> = LaneProtocol::new(1, Arc::new(Echo));
        p.dispatch(it(1, 7));
        let d = p.collect().unwrap();
        assert_eq!(d.lane, 0, "lane beyond width clamps to the last lane");
        assert!(p.shutdown_drain().is_empty());
    }

    #[test]
    fn std_env_steal_drains_a_blocked_lane() {
        let (gate, exec) = GateExec::new();
        let mut p: LaneProtocol<StdEnv, Item, Done> = LaneProtocol::new(2, Arc::new(exec));
        p.set_steal(true);
        // Blocker to lane 0; wait until a worker is provably stuck in it
        // (either the owner, or the other worker that stole it).
        p.dispatch(Item { gate: true, ..it(0, 0) });
        GateExec::wait_entered(&gate, 1);
        // Backlog behind the blocker — the free worker must execute all of
        // it while the gate is closed, proving work conservation.
        for round in 1..=4u64 {
            p.dispatch(it(round, 0));
        }
        let mut got = [false; 5];
        for _ in 0..4 {
            let d = p.collect().expect("workers alive");
            assert_ne!(d.round, 0, "gate item cannot finish while closed");
            assert_eq!(d.lane, 0, "planned lane tag survives stealing");
            assert!(d.executed < 2, "executed lane recorded");
            got[d.round as usize] = true;
        }
        assert!(got[1..].iter().all(|&g| g), "all backlog executed");
        assert!(p.steals_total() >= 1, "at least one item crossed lanes");
        GateExec::open(&gate);
        let d = p.collect().unwrap();
        assert_eq!(d.round, 0);
        assert!(p.shutdown_drain().is_empty());
        assert_eq!(p.in_flight(), 0);
    }

    #[test]
    fn std_env_resize_drains_stealable_work_without_loss() {
        let (gate, exec) = GateExec::new();
        let mut p: LaneProtocol<StdEnv, Item, Done> = LaneProtocol::new(2, Arc::new(exec));
        // Steal OFF: only lane 1's owner can take the blocker, so the
        // follow-ups are provably still queued on lane 1 at resize time.
        p.dispatch(Item { gate: true, ..it(0, 1) });
        GateExec::wait_entered(&gate, 1);
        for round in 1..=3u64 {
            p.dispatch(it(round, 1));
        }
        // Retire lane 1: its queued items must re-home to lane 0 and run
        // there while the retired worker is still stuck mid-item.
        p.resize(1);
        let mut got = [false; 4];
        for _ in 0..3 {
            let d = p.collect().expect("workers alive");
            assert_ne!(d.round, 0);
            assert_eq!(d.lane, 0, "re-homed items carry the survivor lane");
            assert_eq!(d.executed, 0);
            assert!(!d.stolen, "resize drain is a re-home, not a steal");
            got[d.round as usize] = true;
        }
        assert!(got[1..].iter().all(|&g| g), "no re-homed item lost");
        GateExec::open(&gate);
        let d = p.collect().unwrap();
        assert_eq!(d.round, 0);
        assert_eq!(d.lane, 1, "in-flight item keeps its original lane tag");
        assert!(p.shutdown_drain().is_empty());
        assert_eq!(p.in_flight(), 0);
    }

    #[test]
    fn std_env_steal_off_keeps_lanes_private() {
        let (gate, exec) = GateExec::new();
        let mut p: LaneProtocol<StdEnv, Item, Done> = LaneProtocol::new(2, Arc::new(exec));
        p.dispatch(Item { gate: true, ..it(0, 0) });
        GateExec::wait_entered(&gate, 1);
        for round in 1..=3u64 {
            p.dispatch(it(round, 0));
        }
        // Lane 1 idles next to a backlog it is not allowed to touch.
        GateExec::open(&gate);
        let mut rounds = Vec::new();
        for _ in 0..4 {
            let d = p.collect().unwrap();
            assert_eq!(d.executed, 0, "steal off: only the owner executes");
            assert!(!d.stolen);
            rounds.push(d.round);
        }
        assert_eq!(rounds, vec![0, 1, 2, 3], "FIFO order per lane preserved");
        assert_eq!(p.steals_total(), 0);
        assert!(p.shutdown_drain().is_empty());
    }
}
