//! The LanePool's synchronization protocol, extracted behind a small
//! `Sync`-abstraction so the SAME generic code runs under two environments:
//!
//! * [`StdEnv`] — `std::sync::mpsc` channels + `std::thread` workers. This
//!   is what production uses; [`crate::coordinator::lanepool::LanePool`] is
//!   a thin wrapper over `LaneProtocol<StdEnv, WorkItem, Completion>`.
//! * `ModelEnv` (in [`crate::util::modelcheck`]) — cooperative virtual
//!   threads whose every channel operation is a *decision point* for a
//!   DFS schedule explorer. The model-check tests in
//!   `tests/modelcheck_protocol.rs` run the protocol below under **every**
//!   interleaving (up to a bounded-preemption cap) and assert the
//!   conservation invariants the example-based tests can only sample.
//!
//! What the protocol owns (and what the checker therefore proves):
//!
//! * **SPSC dispatch** — one FIFO queue per lane; the driver is the only
//!   sender, the lane worker the only receiver.
//! * **Shared completion channel** — every worker reports into one MPSC
//!   channel the driver collects from; the protocol keeps its own clone of
//!   the sender so the channel never closes while the pool lives.
//! * **Round tags** — items carry their round id through dispatch and back
//!   on the completion; conservation (`collected + drained == dispatched`,
//!   per round) is the checker's core assertion.
//! * **Resize grow/retire/drain** — growing spawns fresh workers onto the
//!   shared completion channel; retiring drops a lane's sender so the
//!   worker drains its queue and exits on its own, never abandoning a
//!   queued item.
//! * **Panic containment** — converting executor panics to `Err` payloads
//!   is the [`ItemRunner`]'s job, so a worker thread never dies mid-round.

/// Payload that can flow through a protocol channel. `fingerprint` is the
/// model checker's state-hash hook: two payloads with equal fingerprints
/// are treated as equivalent when pruning visited states. Production types
/// keep the default (state hashing is only used under the checker).
pub trait ProtoPayload: Send + 'static {
    fn fingerprint(&self) -> u64 {
        0
    }
}

/// Sending half of a protocol channel. Cloned by the environment when a
/// worker needs its own handle (the completion channel is MPSC).
pub trait ProtoSender<T>: Clone + Send + 'static {
    /// Queue `value`; returns it back if the receiving side is gone.
    fn send(&self, value: T) -> Result<(), T>;
}

/// Receiving half of a protocol channel.
pub trait ProtoReceiver<T>: Send + 'static {
    /// Block until a value arrives; `None` once every sender is dropped
    /// and the queue is empty.
    fn recv(&self) -> Option<T>;
    /// Non-blocking variant used by the shutdown drain.
    fn try_recv(&self) -> Option<T>;
}

/// Join handle for a spawned protocol worker.
pub trait ProtoJoin {
    fn join(self);
}

/// The synchronization environment the protocol is generic over. GATs let
/// `StdEnv` hand out real `mpsc` endpoints while the model environment
/// hands out checker-instrumented ones, with the protocol code unchanged.
pub trait SyncEnv: 'static {
    type Sender<T: ProtoPayload>: ProtoSender<T>;
    type Receiver<T: ProtoPayload>: ProtoReceiver<T>;
    type Join: ProtoJoin;

    fn channel<T: ProtoPayload>() -> (Self::Sender<T>, Self::Receiver<T>);
    fn spawn(name: String, f: impl FnOnce() + Send + 'static) -> Self::Join;
    /// Cooperative scheduling point. A no-op under [`StdEnv`]; under the
    /// model environment it is an extra decision point, letting runner
    /// bodies expose intermediate states to the explorer.
    fn yield_now() {}
}

/// Work items carry their target lane; the protocol clamps and rewrites it
/// at dispatch (plans targeting retired lanes fold onto survivors).
pub trait LaneTagged {
    fn lane(&self) -> usize;
    fn set_lane(&mut self, lane: usize);
}

/// What a lane worker runs per item. Implementations MUST NOT panic —
/// panic containment (catch_unwind → `Err` completion) is the runner's
/// responsibility, because a dead worker with live siblings leaves the
/// completion channel open and the driver blocked forever on a round that
/// can no longer drain.
pub trait ItemRunner<W, C>: Send + Sync + 'static {
    fn run(&self, item: W) -> C;
}

/// The generic persistent lane pool: `lanes` workers, one SPSC queue each,
/// one shared completion channel. See the module docs for the protocol
/// invariants; see [`crate::coordinator::lanepool::LanePool`] for the
/// production instantiation and user-facing docs.
pub struct LaneProtocol<E: SyncEnv, W: ProtoPayload + LaneTagged, C: ProtoPayload> {
    senders: Vec<E::Sender<W>>,
    completions: E::Receiver<C>,
    /// Kept so `resize` can hand fresh workers the shared channel — and so
    /// the channel stays open for the protocol's lifetime (a dead worker
    /// surfaces as items that never complete, not a closed-channel error).
    done_tx: E::Sender<C>,
    runner: std::sync::Arc<dyn ItemRunner<W, C>>,
    /// Every worker ever spawned (active and retired); joined on drop.
    workers: Vec<E::Join>,
    /// Lifetime worker spawns (names stay unique across resizes).
    spawned: u64,
    dispatched: u64,
    collected: u64,
}

/// One worker's receive loop: FIFO over its lane queue; exits when the
/// protocol drops the lane's sender (shutdown, or the lane retiring in a
/// resize) **after** draining everything already queued — the resize
/// conservation guarantee lives in this `while let`.
fn worker_loop<E: SyncEnv, W: ProtoPayload + LaneTagged, C: ProtoPayload>(
    rx: E::Receiver<W>,
    done_tx: E::Sender<C>,
    runner: std::sync::Arc<dyn ItemRunner<W, C>>,
) {
    while let Some(item) = rx.recv() {
        let done = runner.run(item);
        if done_tx.send(done).is_err() {
            return; // driver gone: nobody to report to
        }
    }
}

impl<E: SyncEnv, W: ProtoPayload + LaneTagged, C: ProtoPayload> LaneProtocol<E, W, C> {
    pub fn new(lanes: usize, runner: std::sync::Arc<dyn ItemRunner<W, C>>) -> Self {
        let (done_tx, done_rx) = E::channel::<C>();
        let mut proto = Self {
            senders: Vec::new(),
            completions: done_rx,
            done_tx,
            runner,
            workers: Vec::new(),
            spawned: 0,
            dispatched: 0,
            collected: 0,
        };
        proto.resize(lanes);
        proto
    }

    /// Change the resident lane count (clamped to >= 1) without losing any
    /// in-flight completion. Growing spawns fresh workers; shrinking
    /// retires the top lanes by dropping their senders: a retired worker
    /// drains everything already queued on its lane and exits. Retired
    /// handles are joined lazily at shutdown/drop so a resize never blocks
    /// the round loop on a lane's backlog.
    pub fn resize(&mut self, lanes: usize) {
        let lanes = lanes.max(1);
        // Shrink: dropping a sender ends that worker's receive loop after
        // its queued items (never mid-item).
        self.senders.truncate(lanes);
        // Grow: fresh workers on the shared completion channel.
        while self.senders.len() < lanes {
            let lane = self.senders.len();
            let (tx, rx) = E::channel::<W>();
            self.senders.push(tx);
            let name = format!("stgpu-lane-{lane}.{}", self.spawned);
            self.spawned += 1;
            let done_tx = self.done_tx.clone();
            let runner = self.runner.clone();
            self.workers
                .push(E::spawn(name, move || worker_loop::<E, W, C>(rx, done_tx, runner)));
        }
    }

    pub fn lanes(&self) -> usize {
        self.senders.len()
    }

    /// Queue one item on its lane (clamped to the pool width; the item's
    /// lane tag is rewritten so its completion reports the lane it actually
    /// executed on). Returns immediately.
    // lint: hot-path
    pub fn dispatch(&mut self, mut item: W) {
        let lane = item.lane().min(self.senders.len() - 1);
        item.set_lane(lane);
        self.dispatched += 1;
        // Send fails only if the worker's receive loop ended early, which
        // it never does outside shutdown: runners contain panics per item.
        let _ = self.senders[lane].send(item);
    }

    /// Block for the next completion (any lane, any in-flight round);
    /// `None` only if every worker terminated unexpectedly.
    // lint: hot-path
    pub fn collect(&mut self) -> Option<C> {
        let c = self.completions.recv()?;
        self.collected += 1;
        Some(c)
    }

    /// Items dispatched but not yet collected.
    pub fn in_flight(&self) -> u64 {
        self.dispatched - self.collected
    }

    /// Close the queues, join every worker, and return the completions
    /// that finished but were never collected — the zero-lost-completions
    /// drain contract: `collected + leftover.len() == dispatched` as long
    /// as every dispatched item executed.
    pub fn shutdown_drain(&mut self) -> Vec<C> {
        self.senders.clear(); // workers' receive loops end
        for w in self.workers.drain(..) {
            w.join();
        }
        let mut leftover = Vec::new();
        while let Some(c) = self.completions.try_recv() {
            self.collected += 1;
            leftover.push(c);
        }
        leftover
    }
}

impl<E: SyncEnv, W: ProtoPayload + LaneTagged, C: ProtoPayload> Drop
    for LaneProtocol<E, W, C>
{
    fn drop(&mut self) {
        self.senders.clear();
        for w in self.workers.drain(..) {
            w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// StdEnv: the production environment over std::sync::mpsc + std::thread.
// ---------------------------------------------------------------------------

/// Production environment: real OS threads and `std::sync::mpsc` channels.
pub struct StdEnv;

/// Newtype senders/receivers so the GAT impls stay coherent.
pub struct StdSender<T>(std::sync::mpsc::Sender<T>);

impl<T> Clone for StdSender<T> {
    fn clone(&self) -> Self {
        StdSender(self.0.clone())
    }
}

pub struct StdReceiver<T>(std::sync::mpsc::Receiver<T>);

impl<T: ProtoPayload> ProtoSender<T> for StdSender<T> {
    fn send(&self, value: T) -> Result<(), T> {
        self.0.send(value).map_err(|e| e.0)
    }
}

impl<T: ProtoPayload> ProtoReceiver<T> for StdReceiver<T> {
    fn recv(&self) -> Option<T> {
        self.0.recv().ok()
    }

    fn try_recv(&self) -> Option<T> {
        self.0.try_recv().ok()
    }
}

pub struct StdJoin(std::thread::JoinHandle<()>);

impl ProtoJoin for StdJoin {
    fn join(self) {
        let _ = self.0.join();
    }
}

impl SyncEnv for StdEnv {
    type Sender<T: ProtoPayload> = StdSender<T>;
    type Receiver<T: ProtoPayload> = StdReceiver<T>;
    type Join = StdJoin;

    fn channel<T: ProtoPayload>() -> (StdSender<T>, StdReceiver<T>) {
        let (tx, rx) = std::sync::mpsc::channel();
        (StdSender(tx), StdReceiver(rx))
    }

    fn spawn(name: String, f: impl FnOnce() + Send + 'static) -> StdJoin {
        StdJoin(
            std::thread::Builder::new()
                .name(name)
                .spawn(f)
                .expect("spawn lane worker"),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    struct Item {
        round: u64,
        lane: usize,
    }
    impl ProtoPayload for Item {}
    impl LaneTagged for Item {
        fn lane(&self) -> usize {
            self.lane
        }
        fn set_lane(&mut self, lane: usize) {
            self.lane = lane;
        }
    }

    struct Done {
        round: u64,
        lane: usize,
    }
    impl ProtoPayload for Done {}

    struct Echo;
    impl ItemRunner<Item, Done> for Echo {
        fn run(&self, item: Item) -> Done {
            Done { round: item.round, lane: item.lane }
        }
    }

    #[test]
    fn std_env_round_trip_conserves_items() {
        let mut p: LaneProtocol<StdEnv, Item, Done> = LaneProtocol::new(2, Arc::new(Echo));
        for round in 0..6u64 {
            p.dispatch(Item { round, lane: round as usize % 2 });
        }
        let mut seen = 0u64;
        for _ in 0..4 {
            let d = p.collect().expect("workers alive");
            assert!(d.round < 6 && d.lane < 2);
            seen += 1;
        }
        let leftover = p.shutdown_drain();
        assert_eq!(seen + leftover.len() as u64, 6);
        assert_eq!(p.in_flight(), 0);
    }

    #[test]
    fn std_env_dispatch_clamps_lane() {
        let mut p: LaneProtocol<StdEnv, Item, Done> = LaneProtocol::new(1, Arc::new(Echo));
        p.dispatch(Item { round: 1, lane: 7 });
        let d = p.collect().unwrap();
        assert_eq!(d.lane, 0, "lane beyond width clamps to the last lane");
        assert!(p.shutdown_drain().is_empty());
    }
}
