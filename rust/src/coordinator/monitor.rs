//! SLO monitor + straggler evictor (paper §4).
//!
//! "We preserve predictability and isolation during virtualization by
//! monitoring inference latencies per-kernel. [...] CUDA Stream scheduling
//! anomalies typically only create a few stragglers, so we can simply evict
//! degraded workers without significantly impacting total system
//! throughput."
//!
//! The monitor keeps an EWMA of per-tenant service latency; a tenant whose
//! EWMA exceeds `threshold ×` the median of all healthy tenants for
//! `strikes` consecutive observation windows is evicted. The EWMA is
//! seeded from the first observed sample — decaying up from zero would
//! under-report a tenant's latency for the first ~1/alpha samples and let
//! early windows spuriously judge a straggler healthy (or, relative to
//! correctly-seeded peers, a healthy tenant a straggler).
//!
//! Alongside eviction, the monitor counts per-tenant **deadline hits and
//! misses** (did the request complete before `arrival + SLO`?) — the
//! SLO-attainment ratio the deadline-aware planner optimizes and the
//! status endpoint reports.

use crate::coordinator::tenant::{Health, TenantRegistry};
use crate::util::stats;

/// Per-tenant latency tracking state.
#[derive(Debug, Clone)]
struct TenantTrack {
    ewma_s: f64,
    samples: u64,
    strikes: u32,
    slo_ms: f64,
    slo_violations: u64,
    /// Requests completed before their deadline.
    deadline_hits: u64,
    /// Requests completed after their deadline.
    deadline_misses: u64,
}

/// Eviction decision emitted by a check.
#[derive(Debug, Clone, PartialEq)]
pub struct Eviction {
    pub tenant: usize,
    /// EWMA / median ratio at eviction time.
    pub ratio: f64,
}

/// Monitor configuration.
#[derive(Debug, Clone, Copy)]
pub struct MonitorConfig {
    pub enabled: bool,
    /// Straggler threshold: evict when ewma > threshold * median.
    pub threshold: f64,
    /// Consecutive over-threshold windows before eviction.
    pub strikes: u32,
    /// EWMA decay (weight of the newest sample).
    pub alpha: f64,
    /// Minimum samples before a tenant can be judged.
    pub min_samples: u64,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        Self { enabled: true, threshold: 1.15, strikes: 3, alpha: 0.2, min_samples: 8 }
    }
}

/// The SLO monitor.
#[derive(Debug)]
pub struct SloMonitor {
    cfg: MonitorConfig,
    tracks: Vec<TenantTrack>,
    /// Device each tenant executes on (sharded coordinator). Straggling is
    /// judged against same-device peers: a tenant on a busy shard must not
    /// look like a straggler merely because another shard is idle. Empty
    /// map == everyone on device 0 (the single-device special case).
    device_of: Vec<usize>,
    pub evictions: Vec<Eviction>,
}

impl SloMonitor {
    pub fn new(cfg: MonitorConfig, tenants: &TenantRegistry) -> Self {
        let tracks = tenants
            .iter()
            .map(|t| TenantTrack {
                ewma_s: 0.0,
                samples: 0,
                strikes: 0,
                slo_ms: t.slo_ms,
                slo_violations: 0,
                deadline_hits: 0,
                deadline_misses: 0,
            })
            .collect();
        Self { cfg, tracks, device_of: Vec::new(), evictions: Vec::new() }
    }

    /// Group tenants by device for the straggler median (the sharded
    /// coordinator sets this from the placement layer).
    pub fn with_device_map(mut self, device_of: Vec<usize>) -> Self {
        debug_assert!(device_of.is_empty() || device_of.len() == self.tracks.len());
        self.device_of = device_of;
        self
    }

    fn device(&self, tenant: usize) -> usize {
        self.device_of.get(tenant).copied().unwrap_or(0)
    }

    /// Record one completed request's service latency.
    pub fn observe(&mut self, tenant: usize, service_s: f64) {
        let Some(t) = self.tracks.get_mut(tenant) else { return };
        if t.samples == 0 {
            t.ewma_s = service_s;
        } else {
            t.ewma_s = self.cfg.alpha * service_s + (1.0 - self.cfg.alpha) * t.ewma_s;
        }
        t.samples += 1;
        if service_s * 1e3 > t.slo_ms {
            t.slo_violations += 1;
        }
    }

    /// Forget a tenant's straggler state (re-admission path): the EWMA,
    /// sample count and strikes restart from scratch so the history that
    /// got the tenant evicted cannot immediately re-evict it. Lifetime
    /// deadline hit/miss counters are kept.
    pub fn reset(&mut self, tenant: usize) {
        if let Some(t) = self.tracks.get_mut(tenant) {
            t.ewma_s = 0.0;
            t.samples = 0;
            t.strikes = 0;
        }
    }

    /// Re-home a tenant to a new device group (re-admission may place it
    /// on a different shard than it was evicted from). No-op without a
    /// device map.
    pub fn set_device(&mut self, tenant: usize, device: usize) {
        if let Some(d) = self.device_of.get_mut(tenant) {
            *d = device;
        }
    }

    /// Record whether a completed request met its deadline (SLO
    /// attainment; the driver calls this once per response).
    pub fn observe_deadline(&mut self, tenant: usize, met: bool) {
        let Some(t) = self.tracks.get_mut(tenant) else { return };
        if met {
            t.deadline_hits += 1;
        } else {
            t.deadline_misses += 1;
        }
    }

    /// Deadline hit/miss counters for one tenant.
    pub fn deadline_counts(&self, tenant: usize) -> (u64, u64) {
        self.tracks
            .get(tenant)
            .map_or((0, 0), |t| (t.deadline_hits, t.deadline_misses))
    }

    /// SLO-attainment ratio (hits / observed); None before any completion.
    pub fn attainment(&self, tenant: usize) -> Option<f64> {
        let (h, m) = self.deadline_counts(tenant);
        let total = h + m;
        if total == 0 {
            None
        } else {
            Some(h as f64 / total as f64)
        }
    }

    pub fn ewma(&self, tenant: usize) -> Option<f64> {
        self.tracks.get(tenant).filter(|t| t.samples > 0).map(|t| t.ewma_s)
    }

    pub fn slo_violations(&self, tenant: usize) -> u64 {
        self.tracks.get(tenant).map_or(0, |t| t.slo_violations)
    }

    /// End-of-window check: update strike counts, evict offenders.
    /// Mutates `tenants` (marks Degraded/Evicted) and returns new evictions.
    ///
    /// The straggler median is computed **per device group**: each tenant
    /// is compared against the healthy tenants sharing its device. With no
    /// device map (single-device coordinator) every tenant is in one group
    /// and behaviour is identical to the classic monitor.
    pub fn check(&mut self, tenants: &mut TenantRegistry) -> Vec<Eviction> {
        if !self.cfg.enabled {
            return Vec::new();
        }
        // Median over healthy, sampled tenants, per device group.
        let n_devices = 1 + self.device_of.iter().copied().max().unwrap_or(0);
        let mut healthy_per_device: Vec<Vec<f64>> = vec![Vec::new(); n_devices];
        for (i, t) in self.tracks.iter().enumerate() {
            if t.samples >= self.cfg.min_samples
                && tenants.get(i).map_or(false, |x| x.is_servable())
            {
                healthy_per_device[self.device(i)].push(t.ewma_s);
            }
        }
        let medians: Vec<Option<f64>> = healthy_per_device
            .iter()
            .map(|h| {
                // A group needs at least two healthy tenants to define a
                // meaningful "peer" median.
                if h.len() < 2 {
                    return None;
                }
                let m = stats::percentile(h, 50.0);
                if m <= 0.0 {
                    None
                } else {
                    Some(m)
                }
            })
            .collect();
        if medians.iter().all(Option::is_none) {
            return Vec::new(); // nothing to compare against
        }
        let device_of: Vec<usize> = (0..self.tracks.len()).map(|i| self.device(i)).collect();
        let mut out = Vec::new();
        for (i, t) in self.tracks.iter_mut().enumerate() {
            let servable = tenants.get(i).map_or(false, |x| x.is_servable());
            if !servable || t.samples < self.cfg.min_samples {
                continue;
            }
            let Some(median) = medians[device_of[i]] else {
                continue;
            };
            let ratio = t.ewma_s / median;
            if ratio > self.cfg.threshold {
                t.strikes += 1;
                if t.strikes >= self.cfg.strikes {
                    tenants.evict(i);
                    out.push(Eviction { tenant: i, ratio });
                } else if let Some(x) = tenants.get_mut(i) {
                    x.health = Health::Degraded { strikes: t.strikes };
                }
            } else {
                t.strikes = 0;
                if let Some(x) = tenants.get_mut(i) {
                    if matches!(x.health, Health::Degraded { .. }) {
                        x.health = Health::Healthy;
                    }
                }
            }
        }
        self.evictions.extend(out.iter().cloned());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry(n: usize) -> TenantRegistry {
        let mut reg = TenantRegistry::new();
        for i in 0..n {
            reg.register(&format!("t{i}"), "sgemm:64x64x64", 100.0, i as u64)
                .unwrap();
        }
        reg
    }

    fn feed(mon: &mut SloMonitor, tenant: usize, latency_s: f64, n: u64) {
        for _ in 0..n {
            mon.observe(tenant, latency_s);
        }
    }

    #[test]
    fn straggler_evicted_after_strikes() {
        let mut reg = registry(4);
        let cfg = MonitorConfig { strikes: 3, ..Default::default() };
        let mut mon = SloMonitor::new(cfg, &reg);
        // Tenants 0-2 run at 1 ms, tenant 3 at 2 ms (ratio 2.0 > 1.15).
        for t in 0..3 {
            feed(&mut mon, t, 1e-3, 10);
        }
        feed(&mut mon, 3, 2e-3, 10);
        assert!(mon.check(&mut reg).is_empty()); // strike 1
        assert_eq!(reg.get(3).unwrap().health, Health::Degraded { strikes: 1 });
        assert!(mon.check(&mut reg).is_empty()); // strike 2
        let ev = mon.check(&mut reg); // strike 3 -> evict
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].tenant, 3);
        assert!(ev[0].ratio > 1.5);
        assert_eq!(reg.get(3).unwrap().health, Health::Evicted);
        // Healthy tenants untouched.
        for t in 0..3 {
            assert_eq!(reg.get(t).unwrap().health, Health::Healthy);
        }
    }

    #[test]
    fn recovery_resets_strikes() {
        let mut reg = registry(3);
        let mut mon = SloMonitor::new(MonitorConfig::default(), &reg);
        feed(&mut mon, 0, 1e-3, 10);
        feed(&mut mon, 1, 1e-3, 10);
        feed(&mut mon, 2, 2e-3, 10);
        mon.check(&mut reg);
        assert_eq!(reg.get(2).unwrap().health, Health::Degraded { strikes: 1 });
        // Tenant 2 recovers: many fast samples pull the EWMA down.
        feed(&mut mon, 2, 0.8e-3, 40);
        mon.check(&mut reg);
        assert_eq!(reg.get(2).unwrap().health, Health::Healthy);
        // It never gets evicted afterwards.
        for _ in 0..5 {
            assert!(mon.check(&mut reg).is_empty());
        }
    }

    #[test]
    fn disabled_monitor_never_evicts() {
        let mut reg = registry(2);
        let cfg = MonitorConfig { enabled: false, ..Default::default() };
        let mut mon = SloMonitor::new(cfg, &reg);
        feed(&mut mon, 0, 1e-3, 20);
        feed(&mut mon, 1, 50e-3, 20);
        assert!(mon.check(&mut reg).is_empty());
        assert_eq!(reg.evicted_count(), 0);
    }

    #[test]
    fn needs_min_samples() {
        let mut reg = registry(2);
        let mut mon = SloMonitor::new(MonitorConfig::default(), &reg);
        feed(&mut mon, 0, 1e-3, 2);
        feed(&mut mon, 1, 10e-3, 2);
        assert!(mon.check(&mut reg).is_empty(), "too few samples to judge");
    }

    #[test]
    fn single_tenant_never_self_evicts() {
        let mut reg = registry(1);
        let mut mon = SloMonitor::new(MonitorConfig::default(), &reg);
        feed(&mut mon, 0, 100e-3, 50);
        assert!(mon.check(&mut reg).is_empty());
    }

    #[test]
    fn device_groups_judge_stragglers_against_their_own_shard() {
        // Device 0 runs fast tenants, device 1 runs uniformly slow ones
        // (bigger shapes, say). With per-device medians nobody straggles;
        // a global median would wrongly evict all of device 1.
        let mut reg = registry(4);
        let mut mon = SloMonitor::new(MonitorConfig::default(), &reg)
            .with_device_map(vec![0, 0, 1, 1]);
        for _ in 0..10 {
            mon.observe(0, 1e-3);
            mon.observe(1, 1e-3);
            mon.observe(2, 5e-3);
            mon.observe(3, 5e-3);
        }
        for _ in 0..5 {
            assert!(mon.check(&mut reg).is_empty(), "no straggler in-shard");
        }
        assert_eq!(reg.evicted_count(), 0);

        // A genuine straggler WITHIN device 1 is still caught.
        for _ in 0..40 {
            mon.observe(2, 5e-3);
            mon.observe(3, 12e-3);
        }
        for _ in 0..4 {
            mon.check(&mut reg);
        }
        assert_eq!(reg.get(3).unwrap().health, Health::Evicted);
        assert_eq!(reg.evicted_count(), 1);
    }

    #[test]
    fn single_member_device_group_never_self_evicts() {
        let mut reg = registry(3);
        let mut mon = SloMonitor::new(MonitorConfig::default(), &reg)
            .with_device_map(vec![0, 0, 1]);
        for _ in 0..20 {
            mon.observe(0, 1e-3);
            mon.observe(1, 1e-3);
            mon.observe(2, 50e-3); // alone on device 1: no peers to compare
        }
        for _ in 0..5 {
            assert!(mon.check(&mut reg).is_empty());
        }
        assert_eq!(reg.evicted_count(), 0);
    }

    #[test]
    fn slo_violations_counted() {
        let reg = registry(1);
        let mut mon = SloMonitor::new(MonitorConfig::default(), &reg);
        mon.observe(0, 0.05); // 50 ms < 100 ms SLO
        mon.observe(0, 0.15); // 150 ms > SLO
        mon.observe(0, 0.2);
        assert_eq!(mon.slo_violations(0), 2);
    }

    #[test]
    fn ewma_cold_start_seeds_from_first_sample() {
        // Regression: an EWMA decayed up from zero under-reports a slow
        // tenant for the first ~1/alpha samples — with min_samples = 8 and
        // alpha = 0.2, a 10 ms straggler would show ewma ≈ 8.3 ms at the
        // first check and could dodge the threshold. Seeding from the
        // first sample makes the very first window see the true latency.
        let mut reg = registry(4);
        let mut mon = SloMonitor::new(MonitorConfig::default(), &reg);
        // One sample must seed exactly (no decay from zero).
        mon.observe(3, 10e-3);
        assert_eq!(mon.ewma(3), Some(10e-3), "first sample seeds the EWMA");
        // Exactly min_samples constant-latency samples keep the EWMA at
        // the true value — no residual zero-bias.
        for t in 0..3 {
            feed(&mut mon, t, 1e-3, 8);
        }
        feed(&mut mon, 3, 10e-3, 7); // 8 total with the seed above
        assert!((mon.ewma(3).unwrap() - 10e-3).abs() < 1e-12);
        // And the straggler is struck on the FIRST window, not only after
        // the bias has washed out.
        mon.check(&mut reg);
        assert_eq!(
            reg.get(3).unwrap().health,
            Health::Degraded { strikes: 1 },
            "cold-start must not mask the straggler in early windows"
        );
    }

    #[test]
    fn reset_forgets_straggler_state_but_keeps_attainment() {
        let mut reg = registry(3);
        let mut mon = SloMonitor::new(MonitorConfig::default(), &reg);
        feed(&mut mon, 0, 1e-3, 10);
        feed(&mut mon, 1, 1e-3, 10);
        feed(&mut mon, 2, 10e-3, 10);
        mon.observe_deadline(2, false);
        for _ in 0..3 {
            mon.check(&mut reg);
        }
        assert_eq!(reg.get(2).unwrap().health, Health::Evicted);
        // Re-admission: reset wipes EWMA/samples/strikes; deadline history
        // stays (it is lifetime reporting, not eviction state).
        mon.reset(2);
        assert_eq!(mon.ewma(2), None, "no samples after reset");
        assert_eq!(mon.deadline_counts(2), (0, 1));
        // A reset tenant needs min_samples again before it can be judged;
        // fresh healthy samples keep it clean.
        reg.get_mut(2).unwrap().health = Health::Healthy;
        feed(&mut mon, 2, 1e-3, 10);
        for _ in 0..5 {
            assert!(mon.check(&mut reg).is_empty());
        }
        assert_eq!(reg.get(2).unwrap().health, Health::Healthy);
    }

    #[test]
    fn deadline_attainment_counts_hits_and_misses() {
        let reg = registry(2);
        let mut mon = SloMonitor::new(MonitorConfig::default(), &reg);
        assert_eq!(mon.attainment(0), None, "no completions yet");
        mon.observe_deadline(0, true);
        mon.observe_deadline(0, true);
        mon.observe_deadline(0, false);
        mon.observe_deadline(1, true);
        assert_eq!(mon.deadline_counts(0), (2, 1));
        assert!((mon.attainment(0).unwrap() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(mon.attainment(1), Some(1.0));
        // Unknown tenants are inert.
        mon.observe_deadline(99, true);
        assert_eq!(mon.attainment(99), None);
    }

    #[test]
    fn ewma_tracks_recent() {
        let reg = registry(1);
        let mut mon = SloMonitor::new(MonitorConfig::default(), &reg);
        mon.observe(0, 1.0);
        assert!((mon.ewma(0).unwrap() - 1.0).abs() < 1e-12);
        for _ in 0..100 {
            mon.observe(0, 2.0);
        }
        assert!((mon.ewma(0).unwrap() - 2.0).abs() < 1e-3);
    }
}
