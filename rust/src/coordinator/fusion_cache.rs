//! Super-kernel fusion cache — the paper's §4 observation made concrete:
//! "we notice that overheads gradually decrease if we cache super-kernels
//! as workloads stabilize over time."
//!
//! A launch's *weight* operands are fully determined by (graph kind,
//! R bucket, the ordered tenant ids occupying its lanes): tenant weights
//! are immutable after registration. Under steady closed-loop load the
//! fair-drain scheduler keeps producing the same lane assignments, so we
//! cache the stacked weight operands as **device-resident PJRT buffers**
//! keyed by that tuple. A cache hit turns a launch's host→device traffic
//! from (weights + activations) into activations only — for the MLP serving
//! block that is a ~128× reduction in bytes marshaled per launch.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::batcher::Launch;
use crate::runtime::{HostTensor, PjrtEngine};

/// Cache key: kind + bucket + the exact lane assignment.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct FusionKey {
    pub kind: &'static str,
    pub r_bucket: usize,
    pub tenants: Vec<usize>,
}

impl FusionKey {
    pub fn of(launch: &Launch) -> Self {
        Self {
            kind: launch.class.kind,
            r_bucket: launch.r_bucket,
            tenants: launch.entries.iter().map(|e| e.tenant).collect(),
        }
    }
}

/// Hit/miss accounting (read by benches + EXPERIMENTS.md §Perf).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct FusionCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: u64,
    pub evictions: u64,
}

impl FusionCacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Device-resident stacked weight operands for one fusion key, handed to
/// launch executions as a shared `Arc` so concurrently-executing spatial
/// lanes can use them without holding the cache lock through a launch.
pub struct WeightSet {
    buffers: Vec<xla::PjRtBuffer>,
}

impl WeightSet {
    pub fn new(buffers: Vec<xla::PjRtBuffer>) -> Self {
        Self { buffers }
    }

    pub fn buffers(&self) -> &[xla::PjRtBuffer] {
        &self.buffers
    }
}

// SAFETY: PJRT buffers are plain device handles that the PJRT runtime
// allows concurrent executions over (same argument as `PjrtEngine`'s
// Send/Sync); a `WeightSet` is immutable after construction.
#[allow(unsafe_code)]
unsafe impl Send for WeightSet {}
// SAFETY: see the Send impl above — immutable after construction.
#[allow(unsafe_code)]
unsafe impl Sync for WeightSet {}

/// Cached entry plus its LRU stamp.
struct Entry {
    weights: Arc<WeightSet>,
    last_used: u64,
}

/// The cache. Owned by the coordinator behind a mutex; lane workers lock
/// only for the lookup/build, never across an execution.
pub struct FusionCache {
    map: HashMap<FusionKey, Entry>,
    capacity: usize,
    clock: u64,
    pub stats: FusionCacheStats,
}

// SAFETY: PJRT buffers are plain device handles; all cache mutation happens
// under the coordinator's lock (same argument as `PjrtEngine`'s Send/Sync).
#[allow(unsafe_code)]
unsafe impl Send for FusionCache {}

impl FusionCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        Self {
            map: HashMap::new(),
            capacity,
            clock: 0,
            stats: FusionCacheStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Lookup only (LRU touch + hit/miss accounting). On a miss the caller
    /// builds the weight set OUTSIDE the cache lock — a host gather plus a
    /// device upload is far too slow to serialize concurrent spatial lanes
    /// on — then races to [`FusionCache::insert`].
    pub fn get(&mut self, key: &FusionKey) -> Option<Arc<WeightSet>> {
        self.clock += 1;
        let clock = self.clock;
        match self.map.get_mut(key) {
            Some(e) => {
                self.stats.hits += 1;
                e.last_used = clock;
                Some(e.weights.clone())
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert a weight set built outside the lock. If a racing lane
    /// already inserted this key, the existing entry wins (one canonical
    /// device copy) and the duplicate build is dropped. LRU eviction at
    /// capacity.
    pub fn insert(&mut self, key: FusionKey, weights: Arc<WeightSet>) -> Arc<WeightSet> {
        self.clock += 1;
        let clock = self.clock;
        if let Some(e) = self.map.get_mut(&key) {
            e.last_used = clock;
            return e.weights.clone();
        }
        if self.map.len() >= self.capacity {
            // Evict the least-recently-used entry.
            if let Some(victim) = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&victim);
                self.stats.evictions += 1;
            }
        }
        self.stats.entries += 1;
        self.map.insert(key, Entry { weights: weights.clone(), last_used: clock });
        weights
    }

    /// Fetch the device-resident weight operands for `key`, building them
    /// with `build` (host gather + upload) on a miss. Returns a shared
    /// handle that stays valid after the cache lock is released (and
    /// across a later eviction of the entry). Single-owner convenience
    /// over [`FusionCache::get`]/[`FusionCache::insert`]; concurrent
    /// callers should use those directly so the build happens outside
    /// their lock.
    pub fn get_or_build(
        &mut self,
        engine: &PjrtEngine,
        key: FusionKey,
        build: impl FnOnce() -> Vec<HostTensor>,
    ) -> Result<Arc<WeightSet>> {
        if let Some(w) = self.get(&key) {
            return Ok(w);
        }
        let host = build();
        let buffers = host
            .iter()
            .map(|t| engine.to_device(t))
            .collect::<Result<Vec<_>>>()?;
        Ok(self.insert(key, Arc::new(WeightSet::new(buffers))))
    }

    /// Drop every entry touching `tenant` (called on eviction: its weights
    /// must not linger on device).
    pub fn invalidate_tenant(&mut self, tenant: usize) {
        let before = self.map.len();
        self.map.retain(|k, _| !k.tenants.contains(&tenant));
        self.stats.evictions += (before - self.map.len()) as u64;
    }

    pub fn clear(&mut self) {
        self.stats.evictions += self.map.len() as u64;
        self.map.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_captures_lane_assignment() {
        use crate::coordinator::request::{InferenceRequest, Priority, ShapeClass};
        use std::time::Instant;
        let mk = |tenants: &[usize]| Launch {
            class: ShapeClass::batched_gemm(8, 8, 8),
            entries: tenants
                .iter()
                .map(|&t| InferenceRequest {
                    id: t as u64,
                    tenant: t,
                    class: ShapeClass::batched_gemm(8, 8, 8),
                    payload: vec![],
                    arrived: Instant::now(),
                    deadline: Instant::now(),
                    priority: Priority::Normal,
                    trace_id: 0,
                })
                .collect(),
            r_bucket: 4,
        };
        assert_eq!(FusionKey::of(&mk(&[0, 1, 2])), FusionKey::of(&mk(&[0, 1, 2])));
        assert_ne!(FusionKey::of(&mk(&[0, 1, 2])), FusionKey::of(&mk(&[0, 2, 1])));
        assert_ne!(FusionKey::of(&mk(&[0, 1])), FusionKey::of(&mk(&[0, 1, 2])));
    }

    #[test]
    fn insert_race_keeps_first_entry_and_get_counts() {
        let key = FusionKey { kind: "mlp_block", r_bucket: 4, tenants: vec![0, 1] };
        let mut cache = FusionCache::new(4);
        assert!(cache.get(&key).is_none());
        assert_eq!(cache.stats.misses, 1);
        let first = Arc::new(WeightSet::new(vec![]));
        let kept = cache.insert(key.clone(), first.clone());
        assert!(Arc::ptr_eq(&kept, &first));
        // A racing lane that also built must get the FIRST entry back.
        let dup = Arc::new(WeightSet::new(vec![]));
        let kept2 = cache.insert(key.clone(), dup);
        assert!(Arc::ptr_eq(&kept2, &first), "first insert wins the race");
        assert_eq!(cache.stats.entries, 1, "duplicate build not stored");
        assert!(cache.get(&key).is_some());
        assert_eq!(cache.stats.hits, 1);
    }

    #[test]
    fn lru_eviction_picks_the_cold_entry_and_held_arcs_survive() {
        // Regression for the exported eviction counters: capacity 2, three
        // weight sets. Key A is touched after B's insert, so B is the LRU
        // victim when C arrives — and an Arc taken on A before the
        // eviction cycle stays valid throughout (device-resident handles
        // outlive their cache entry).
        let key = |t: usize| FusionKey { kind: "mlp_block", r_bucket: 4, tenants: vec![t] };
        let mut cache = FusionCache::new(2);
        assert!(cache.get(&key(0)).is_none());
        let a = cache.insert(key(0), Arc::new(WeightSet::new(vec![])));
        cache.insert(key(1), Arc::new(WeightSet::new(vec![])));
        let held = a.clone();
        // Touch A so B becomes least-recently-used.
        assert!(cache.get(&key(0)).is_some());
        cache.insert(key(2), Arc::new(WeightSet::new(vec![])));
        assert_eq!(cache.stats.evictions, 1);
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&key(1)).is_none(), "B was the LRU victim");
        assert!(cache.get(&key(0)).is_some(), "A survived");
        assert!(cache.get(&key(2)).is_some(), "C resident");
        // The held handle is still usable after the eviction cycle.
        assert_eq!(held.buffers().len(), 0);
        assert!(Arc::strong_count(&held) >= 2, "cache + held handle");
        assert_eq!(cache.stats.entries, 3, "three distinct builds inserted");
    }

    #[test]
    fn stats_hit_rate() {
        let s = FusionCacheStats { hits: 3, misses: 1, ..Default::default() };
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(FusionCacheStats::default().hit_rate(), 0.0);
    }
}
