//! Super-kernel fusion cache — the paper's §4 observation made concrete:
//! "we notice that overheads gradually decrease if we cache super-kernels
//! as workloads stabilize over time."
//!
//! A launch's *weight* operands are fully determined by (graph kind,
//! R bucket, the ordered tenant ids occupying its lanes): tenant weights
//! are immutable after registration. Under steady closed-loop load the
//! fair-drain scheduler keeps producing the same lane assignments, so we
//! cache the stacked weight operands as **device-resident PJRT buffers**
//! keyed by that tuple. A cache hit turns a launch's host→device traffic
//! from (weights + activations) into activations only — for the MLP serving
//! block that is a ~128× reduction in bytes marshaled per launch.

use std::collections::HashMap;

use anyhow::Result;

use crate::coordinator::batcher::Launch;
use crate::runtime::{HostTensor, PjrtEngine};

/// Cache key: kind + bucket + the exact lane assignment.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct FusionKey {
    pub kind: &'static str,
    pub r_bucket: usize,
    pub tenants: Vec<usize>,
}

impl FusionKey {
    pub fn of(launch: &Launch) -> Self {
        Self {
            kind: launch.class.kind,
            r_bucket: launch.r_bucket,
            tenants: launch.entries.iter().map(|e| e.tenant).collect(),
        }
    }
}

/// Hit/miss accounting (read by benches + EXPERIMENTS.md §Perf).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct FusionCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: u64,
    pub evictions: u64,
}

impl FusionCacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Device-resident stacked weight operands for one fusion key.
struct Entry {
    buffers: Vec<xla::PjRtBuffer>,
    last_used: u64,
}

/// The cache. Single-owner (the coordinator's leader thread).
pub struct FusionCache {
    map: HashMap<FusionKey, Entry>,
    capacity: usize,
    clock: u64,
    pub stats: FusionCacheStats,
}

// PJRT buffers are plain device handles; all mutation happens under the
// single leader thread that owns the coordinator (same argument as
// `PjrtEngine`'s Send/Sync).
unsafe impl Send for FusionCache {}

impl FusionCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        Self {
            map: HashMap::new(),
            capacity,
            clock: 0,
            stats: FusionCacheStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Fetch the device-resident weight operands for `key`, building them
    /// with `build` (host gather + upload) on a miss. LRU eviction at
    /// capacity.
    pub fn get_or_build(
        &mut self,
        engine: &PjrtEngine,
        key: FusionKey,
        build: impl FnOnce() -> Vec<HostTensor>,
    ) -> Result<&[xla::PjRtBuffer]> {
        self.clock += 1;
        let clock = self.clock;
        if self.map.contains_key(&key) {
            self.stats.hits += 1;
            let e = self.map.get_mut(&key).unwrap();
            e.last_used = clock;
            return Ok(&e.buffers);
        }
        self.stats.misses += 1;
        if self.map.len() >= self.capacity {
            // Evict the least-recently-used entry.
            if let Some(victim) = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&victim);
                self.stats.evictions += 1;
            }
        }
        let host = build();
        let buffers = host
            .iter()
            .map(|t| engine.to_device(t))
            .collect::<Result<Vec<_>>>()?;
        self.stats.entries += 1;
        let e = self.map.entry(key).or_insert(Entry { buffers, last_used: clock });
        Ok(&e.buffers)
    }

    /// Drop every entry touching `tenant` (called on eviction: its weights
    /// must not linger on device).
    pub fn invalidate_tenant(&mut self, tenant: usize) {
        let before = self.map.len();
        self.map.retain(|k, _| !k.tenants.contains(&tenant));
        self.stats.evictions += (before - self.map.len()) as u64;
    }

    pub fn clear(&mut self) {
        self.stats.evictions += self.map.len() as u64;
        self.map.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_captures_lane_assignment() {
        use crate::coordinator::request::{InferenceRequest, ShapeClass};
        use std::time::Instant;
        let mk = |tenants: &[usize]| Launch {
            class: ShapeClass::batched_gemm(8, 8, 8),
            entries: tenants
                .iter()
                .map(|&t| InferenceRequest {
                    id: t as u64,
                    tenant: t,
                    class: ShapeClass::batched_gemm(8, 8, 8),
                    payload: vec![],
                    arrived: Instant::now(),
                    deadline: Instant::now(),
                })
                .collect(),
            r_bucket: 4,
        };
        assert_eq!(FusionKey::of(&mk(&[0, 1, 2])), FusionKey::of(&mk(&[0, 1, 2])));
        assert_ne!(FusionKey::of(&mk(&[0, 1, 2])), FusionKey::of(&mk(&[0, 2, 1])));
        assert_ne!(FusionKey::of(&mk(&[0, 1])), FusionKey::of(&mk(&[0, 1, 2])));
    }

    #[test]
    fn stats_hit_rate() {
        let s = FusionCacheStats { hits: 3, misses: 1, ..Default::default() };
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(FusionCacheStats::default().hit_rate(), 0.0);
    }
}
