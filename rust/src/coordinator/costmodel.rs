//! Per-shape-class launch-latency predictor: the cost model behind the
//! deadline-aware (EDF) space-time planner.
//!
//! Deadline-aware planning needs an answer to "how long will a fused launch
//! of R problems of this class take?" *before* the launch runs. Two sources
//! are blended:
//!
//! * **Analytic seed** — the [`crate::gpusim::cost`] roofline model
//!   evaluated for a super-kernel of R problems of the class (V100 spec
//!   plus launch overhead). Available for every (class, R) from round zero.
//! * **Online correction** — an EWMA over *measured* launch durations fed
//!   back by the driver after every execution. The EWMA is seeded from the
//!   first observation (no decay-from-zero cold-start bias) and takes over
//!   as soon as a (class, R) pair has been seen. Unobserved pairs borrow a
//!   global measured/analytic ratio so one warm class calibrates the whole
//!   substrate (the CPU-PJRT path is orders of magnitude off the V100
//!   seed; the ratio transfer fixes that in a handful of launches).
//!
//! Calibration quality is tracked as an EWMA of the relative prediction
//! error and exported as a metric (`DeviceSnapshot::cost_calibration_error`),
//! the same predictor-quality signal arXiv:2512.18725 plans launches
//! against.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::coordinator::request::ShapeClass;
use crate::gpusim::cost::exclusive_time;
use crate::gpusim::device::DeviceSpec;
use crate::gpusim::kernel::{GemmShape, KernelDesc};

/// Shared handle: the driver observes measured durations, the scheduler
/// reads predictions — one model per device shard.
pub type SharedCostModel = Arc<Mutex<CostModel>>;

/// Per-(class, R) calibration state.
#[derive(Debug, Clone, Copy)]
struct ClassTrack {
    analytic_s: f64,
    ewma_s: f64,
    samples: u64,
}

/// The launch-latency predictor.
#[derive(Debug)]
pub struct CostModel {
    spec: DeviceSpec,
    /// EWMA decay (weight of the newest sample).
    alpha: f64,
    tracks: HashMap<(ShapeClass, usize), ClassTrack>,
    /// Global measured/analytic ratio (EWMA, seeded from first sample) —
    /// transfers calibration to not-yet-observed (class, R) pairs.
    ratio_ewma: f64,
    ratio_samples: u64,
    /// EWMA of |predicted - measured| / measured (seeded from first sample).
    err_ewma: f64,
    observations: u64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self::new()
    }
}

impl CostModel {
    pub fn new() -> Self {
        Self::with_spec(DeviceSpec::v100())
    }

    pub fn with_spec(spec: DeviceSpec) -> Self {
        Self {
            spec,
            alpha: 0.2,
            tracks: HashMap::new(),
            ratio_ewma: 1.0,
            ratio_samples: 0,
            err_ewma: 0.0,
            observations: 0,
        }
    }

    /// Roofline estimate for a fused launch of `r` problems of `class`
    /// (service time of the merged super-kernel plus launch overhead).
    pub fn analytic_seed(&self, class: ShapeClass, r: usize) -> f64 {
        let r = r.max(1);
        let shape = GemmShape::new(
            class.m.max(1) as u32,
            class.n.max(1) as u32,
            class.k.max(1) as u32,
        );
        // Non-GEMM kinds (mlp_block, rnn_cell) differ from the plain
        // (m, n, k) GEMM in FLOP count; scale the per-lane kernel so the
        // seed reflects the class's real work.
        let base = KernelDesc::sgemm(0, shape);
        let scale = if base.flops > 0.0 {
            (class.flops() / base.flops).max(1e-6)
        } else {
            1.0
        };
        // Equivalent to KernelDesc::superkernel over r identical scaled
        // lanes (flops/bytes/ctas are plain sums there), computed without
        // materializing the parts — predict() sits on the per-round
        // planning path and may be called once per split candidate.
        let mut merged = base;
        merged.flops *= scale * r as f64;
        merged.bytes *= scale * r as f64;
        merged.ctas = merged.ctas.saturating_mul(r as u32);
        merged.fused = r as u32;
        exclusive_time(&self.spec, &merged) + self.spec.launch_overhead_s
    }

    /// Predicted duration of a fused launch of `r` problems of `class`:
    /// the per-pair EWMA once observed, else the analytic seed corrected
    /// by the global calibration ratio.
    pub fn predict(&self, class: ShapeClass, r: usize) -> f64 {
        let r = r.max(1);
        if let Some(t) = self.tracks.get(&(class, r)) {
            if t.samples > 0 {
                return t.ewma_s;
            }
        }
        let ratio = if self.ratio_samples > 0 {
            self.ratio_ewma
        } else {
            1.0
        };
        self.analytic_seed(class, r) * ratio
    }

    /// Feed one measured launch duration back into the model.
    pub fn observe(&mut self, class: ShapeClass, r: usize, measured_s: f64) {
        if !measured_s.is_finite() || measured_s <= 0.0 {
            return;
        }
        let r = r.max(1);
        let predicted = self.predict(class, r);
        let analytic = self.analytic_seed(class, r);
        let track = self
            .tracks
            .entry((class, r))
            .or_insert(ClassTrack { analytic_s: analytic, ewma_s: 0.0, samples: 0 });
        if track.samples == 0 {
            // Seed from the first sample — decaying up from zero would
            // under-predict for the first ~1/alpha launches.
            track.ewma_s = measured_s;
        } else {
            track.ewma_s = self.alpha * measured_s + (1.0 - self.alpha) * track.ewma_s;
        }
        track.samples += 1;
        let ratio = measured_s / track.analytic_s.max(1e-12);
        if self.ratio_samples == 0 {
            self.ratio_ewma = ratio;
        } else {
            self.ratio_ewma = self.alpha * ratio + (1.0 - self.alpha) * self.ratio_ewma;
        }
        self.ratio_samples += 1;
        let err = (predicted - measured_s).abs() / measured_s;
        if self.observations == 0 {
            self.err_ewma = err;
        } else {
            self.err_ewma = self.alpha * err + (1.0 - self.alpha) * self.err_ewma;
        }
        self.observations += 1;
    }

    /// EWMA of the relative prediction error (0.0 before any observation).
    pub fn calibration_error(&self) -> f64 {
        if self.observations == 0 {
            0.0
        } else {
            self.err_ewma
        }
    }

    /// Measured launches fed back so far.
    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// Admission-time feasibility: is even an *immediate, minimal* (r = 1)
    /// launch of this class predicted to miss a deadline `slo_s` seconds
    /// out, keeping `slack_s` of safety margin? Queue-delay-blind by
    /// design — round-time planning protects against backlog; this check
    /// sheds only requests that are lost no matter what the planner does.
    pub fn deadline_infeasible(&self, class: ShapeClass, slo_s: f64, slack_s: f64) -> bool {
        self.predict(class, 1) + slack_s > slo_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CLASS: ShapeClass =
        ShapeClass { kind: "batched_gemm", m: 256, n: 128, k: 1152 };

    #[test]
    fn analytic_seed_scales_with_r_and_stays_plausible() {
        let m = CostModel::new();
        let t1 = m.analytic_seed(CLASS, 1);
        let t32 = m.analytic_seed(CLASS, 32);
        // A lone conv2_2 SGEMM lands in the cuBLAS decade (15-120 us).
        assert!((15e-6..150e-6).contains(&t1), "r=1 seed {t1}");
        // Fusing 32 problems is far cheaper than 32 serial launches but
        // strictly more work than one.
        assert!(t32 > t1, "more lanes cost more: {t32} <= {t1}");
        assert!(t32 < 32.0 * t1 / 3.0, "fusion must amortize: {t32} vs {t1}");
    }

    #[test]
    fn prediction_uses_seed_then_ewma() {
        let mut m = CostModel::new();
        let seed = m.analytic_seed(CLASS, 8);
        assert_eq!(m.predict(CLASS, 8), seed);
        // First observation seeds the EWMA exactly (no decay-from-zero).
        m.observe(CLASS, 8, 5e-3);
        assert!((m.predict(CLASS, 8) - 5e-3).abs() < 1e-12);
        // Subsequent observations blend.
        m.observe(CLASS, 8, 10e-3);
        let p = m.predict(CLASS, 8);
        assert!(p > 5e-3 && p < 10e-3, "blended prediction {p}");
        assert_eq!(m.observations(), 2);
    }

    #[test]
    fn ratio_transfers_calibration_to_unseen_buckets() {
        let mut m = CostModel::new();
        let seed_16 = m.analytic_seed(CLASS, 16);
        // Observe r=1 running 100x slower than the analytic seed (a slow
        // substrate): the unseen r=16 prediction must scale up too.
        let seed_1 = m.analytic_seed(CLASS, 1);
        m.observe(CLASS, 1, seed_1 * 100.0);
        let p16 = m.predict(CLASS, 16);
        assert!(
            p16 > seed_16 * 50.0,
            "global ratio must lift unseen buckets: {p16} vs seed {seed_16}"
        );
    }

    #[test]
    fn calibration_error_tracks_quality() {
        let mut m = CostModel::new();
        assert_eq!(m.calibration_error(), 0.0);
        let seed = m.analytic_seed(CLASS, 4);
        m.observe(CLASS, 4, seed * 2.0); // first prediction off by 50%
        assert!(m.calibration_error() > 0.4);
        // Repeated identical measurements: the EWMA converges, error decays.
        for _ in 0..50 {
            m.observe(CLASS, 4, seed * 2.0);
        }
        assert!(m.calibration_error() < 0.05, "err {}", m.calibration_error());
    }

    #[test]
    fn garbage_observations_ignored() {
        let mut m = CostModel::new();
        m.observe(CLASS, 1, -1.0);
        m.observe(CLASS, 1, f64::NAN);
        m.observe(CLASS, 1, 0.0);
        assert_eq!(m.observations(), 0);
    }

    #[test]
    fn deadline_infeasible_detects_hopeless_slos() {
        let m = CostModel::new();
        let min = m.predict(CLASS, 1);
        assert!(m.deadline_infeasible(CLASS, min / 2.0, 0.0));
        assert!(!m.deadline_infeasible(CLASS, min * 10.0, 0.0));
        // Slack tightens the bound.
        assert!(m.deadline_infeasible(CLASS, min * 1.5, min));
    }

    #[test]
    fn non_gemm_kinds_seed_positive() {
        let m = CostModel::new();
        let mlp = ShapeClass::mlp_block(8, 512, 256, 256);
        let rnn = ShapeClass::rnn_cell(512);
        assert!(m.analytic_seed(mlp, 4) > 0.0);
        assert!(m.analytic_seed(rnn, 4) > 0.0);
    }
}
