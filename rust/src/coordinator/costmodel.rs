//! Per-shape-class launch-latency predictor: the cost model behind the
//! deadline-aware (EDF) space-time planner.
//!
//! Deadline-aware planning needs an answer to "how long will a fused launch
//! of R problems of this class take?" *before* the launch runs. Two sources
//! are blended:
//!
//! * **Analytic seed** — the [`crate::gpusim::cost`] roofline model
//!   evaluated for a super-kernel of R problems of the class (V100 spec
//!   plus launch overhead). Available for every (class, R) from round zero.
//! * **Online correction** — an EWMA over *measured* launch durations fed
//!   back by the driver after every execution. The EWMA is seeded from the
//!   first observation (no decay-from-zero cold-start bias) and takes over
//!   as soon as a (class, R) pair has been seen. Unobserved pairs borrow a
//!   global measured/analytic ratio so one warm class calibrates the whole
//!   substrate (the CPU-PJRT path is orders of magnitude off the V100
//!   seed; the ratio transfer fixes that in a handful of launches).
//!
//! Calibration quality is tracked as an EWMA of the relative prediction
//! error and exported as a metric (`DeviceSnapshot::cost_calibration_error`),
//! the same predictor-quality signal arXiv:2512.18725 plans launches
//! against.
//!
//! ## Co-location interference
//!
//! With spatial lanes (`lanes > 1`) several launches are concurrently
//! resident, and each one stretches: the model carries a per-lane-count
//! **interference stretch** — seeded analytically from the device spec
//! (`1 + interference_coeff * (lanes - 1)`, the reciprocal of the gpusim
//! derate) and EWMA-corrected from measured overlapped launches
//! ([`CostModel::observe_concurrent`] factors every overlapped measurement
//! into solo duration x stretch, so the solo tracks stay clean). D-STACK
//! (arXiv:2304.13541) shows per-model GPU-share knees make this term the
//! difference between profitable and pathological co-location; the per-lane
//! calibration error is exported so an operator can see when the model has
//! actually learned it ([`CostModel::lane_calibration`]).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::coordinator::request::ShapeClass;
use crate::gpusim::cost::exclusive_time;
use crate::gpusim::device::DeviceSpec;
use crate::gpusim::kernel::{GemmShape, KernelDesc};

/// Shared handle: the driver observes measured durations, the scheduler
/// reads predictions — one model per device shard.
pub type SharedCostModel = Arc<Mutex<CostModel>>;

/// Per-(class, R) calibration state.
#[derive(Debug, Clone, Copy)]
struct ClassTrack {
    analytic_s: f64,
    ewma_s: f64,
    samples: u64,
}

/// Per-lane-count co-location calibration: the measured latency *stretch*
/// of a launch that executed with `lanes - 1` other spatial lanes
/// concurrently resident, plus the prediction-error EWMA at that lane
/// count.
#[derive(Debug, Clone, Copy)]
struct LaneTrack {
    stretch_ewma: f64,
    samples: u64,
    err_ewma: f64,
    observations: u64,
}

/// The launch-latency predictor.
#[derive(Debug)]
pub struct CostModel {
    spec: DeviceSpec,
    /// EWMA decay (weight of the newest sample).
    alpha: f64,
    tracks: HashMap<(ShapeClass, usize), ClassTrack>,
    /// Global measured/analytic ratio (EWMA, seeded from first sample) —
    /// transfers calibration to not-yet-observed (class, R) pairs.
    ratio_ewma: f64,
    ratio_samples: u64,
    /// EWMA of |predicted - measured| / measured (seeded from first sample).
    err_ewma: f64,
    observations: u64,
    /// Co-location interference: lane count -> measured-stretch EWMA.
    /// Seeded analytically from [`DeviceSpec::lane_stretch`], corrected by
    /// measured overlapped launches (see [`CostModel::observe_concurrent`]).
    lane_tracks: HashMap<usize, LaneTrack>,
}

impl Default for CostModel {
    fn default() -> Self {
        Self::new()
    }
}

impl CostModel {
    pub fn new() -> Self {
        Self::with_spec(DeviceSpec::v100())
    }

    pub fn with_spec(spec: DeviceSpec) -> Self {
        Self {
            spec,
            alpha: 0.2,
            tracks: HashMap::new(),
            ratio_ewma: 1.0,
            ratio_samples: 0,
            err_ewma: 0.0,
            observations: 0,
            lane_tracks: HashMap::new(),
        }
    }

    /// Roofline estimate for a fused launch of `r` problems of `class`
    /// (service time of the merged super-kernel plus launch overhead).
    pub fn analytic_seed(&self, class: ShapeClass, r: usize) -> f64 {
        let r = r.max(1);
        let shape = GemmShape::new(
            class.m.max(1) as u32,
            class.n.max(1) as u32,
            class.k.max(1) as u32,
        );
        // Non-GEMM kinds (mlp_block, rnn_cell) differ from the plain
        // (m, n, k) GEMM in FLOP count; scale the per-lane kernel so the
        // seed reflects the class's real work.
        let base = KernelDesc::sgemm(0, shape);
        let scale = if base.flops > 0.0 {
            (class.flops() / base.flops).max(1e-6)
        } else {
            1.0
        };
        // Equivalent to KernelDesc::superkernel over r identical scaled
        // lanes (flops/bytes/ctas are plain sums there), computed without
        // materializing the parts — predict() sits on the per-round
        // planning path and may be called once per split candidate.
        let mut merged = base;
        merged.flops *= scale * r as f64;
        merged.bytes *= scale * r as f64;
        merged.ctas = merged.ctas.saturating_mul(r as u32);
        merged.fused = r as u32;
        exclusive_time(&self.spec, &merged) + self.spec.launch_overhead_s
    }

    /// Predicted duration of a fused launch of `r` problems of `class`:
    /// the per-pair EWMA once observed, else the analytic seed corrected
    /// by the global calibration ratio.
    pub fn predict(&self, class: ShapeClass, r: usize) -> f64 {
        let r = r.max(1);
        if let Some(t) = self.tracks.get(&(class, r)) {
            if t.samples > 0 {
                return t.ewma_s;
            }
        }
        let ratio = if self.ratio_samples > 0 {
            self.ratio_ewma
        } else {
            1.0
        };
        self.analytic_seed(class, r) * ratio
    }

    /// Feed one measured launch duration back into the model.
    pub fn observe(&mut self, class: ShapeClass, r: usize, measured_s: f64) {
        if !measured_s.is_finite() || measured_s <= 0.0 {
            return;
        }
        let r = r.max(1);
        let predicted = self.predict(class, r);
        let analytic = self.analytic_seed(class, r);
        let track = self
            .tracks
            .entry((class, r))
            .or_insert(ClassTrack { analytic_s: analytic, ewma_s: 0.0, samples: 0 });
        if track.samples == 0 {
            // Seed from the first sample — decaying up from zero would
            // under-predict for the first ~1/alpha launches.
            track.ewma_s = measured_s;
        } else {
            track.ewma_s = self.alpha * measured_s + (1.0 - self.alpha) * track.ewma_s;
        }
        track.samples += 1;
        let ratio = measured_s / track.analytic_s.max(1e-12);
        if self.ratio_samples == 0 {
            self.ratio_ewma = ratio;
        } else {
            self.ratio_ewma = self.alpha * ratio + (1.0 - self.alpha) * self.ratio_ewma;
        }
        self.ratio_samples += 1;
        let err = (predicted - measured_s).abs() / measured_s;
        if self.observations == 0 {
            self.err_ewma = err;
        } else {
            self.err_ewma = self.alpha * err + (1.0 - self.alpha) * self.err_ewma;
        }
        self.observations += 1;
    }

    /// Predicted latency stretch of a launch co-resident with `lanes - 1`
    /// other spatial lanes: the measured-stretch EWMA once overlapped
    /// launches have been observed at that lane count, else the analytic
    /// seed `1 + interference_coeff * (lanes - 1)` from the device spec.
    /// Always >= 1 (co-location never speeds a single launch up).
    pub fn lane_stretch(&self, lanes: usize) -> f64 {
        if lanes <= 1 {
            return 1.0;
        }
        match self.lane_tracks.get(&lanes) {
            Some(t) if t.samples > 0 => t.stretch_ewma.max(1.0),
            _ => self.spec.lane_stretch(lanes as u32),
        }
    }

    /// Predicted duration of a fused launch of `r` problems of `class`
    /// executing with `lanes` spatial lanes concurrently resident: the solo
    /// prediction stretched by the co-location interference term.
    pub fn predict_concurrent(&self, class: ShapeClass, r: usize, lanes: usize) -> f64 {
        self.predict(class, r) * self.lane_stretch(lanes)
    }

    /// Feed one measured launch duration back, recorded while `lanes`
    /// spatial lanes were concurrently resident. The measurement is
    /// factored into (solo duration) x (co-location stretch): the stretch
    /// EWMA for this lane count absorbs the interference component and the
    /// deflated remainder calibrates the solo (class, R) track — so the
    /// base model keeps predicting un-overlapped launches correctly even
    /// when the driver runs every round multi-lane.
    pub fn observe_concurrent(
        &mut self,
        class: ShapeClass,
        r: usize,
        lanes: usize,
        measured_s: f64,
    ) {
        if lanes <= 1 {
            self.observe(class, r, measured_s);
            return;
        }
        if !measured_s.is_finite() || measured_s <= 0.0 {
            return;
        }
        let r = r.max(1);
        let predicted = self.predict_concurrent(class, r, lanes);
        let base = self.predict(class, r).max(1e-12);
        let stretch_obs = (measured_s / base).max(1.0);
        let alpha = self.alpha;
        let track = self.lane_tracks.entry(lanes).or_insert(LaneTrack {
            stretch_ewma: 0.0,
            samples: 0,
            err_ewma: 0.0,
            observations: 0,
        });
        if track.samples == 0 {
            track.stretch_ewma = stretch_obs;
        } else {
            track.stretch_ewma = alpha * stretch_obs + (1.0 - alpha) * track.stretch_ewma;
        }
        track.samples += 1;
        let err = (predicted - measured_s).abs() / measured_s;
        if track.observations == 0 {
            track.err_ewma = err;
        } else {
            track.err_ewma = alpha * err + (1.0 - alpha) * track.err_ewma;
        }
        track.observations += 1;
        // Calibrate the solo track with the interference factored out.
        let deflated = measured_s / self.lane_stretch(lanes);
        self.observe(class, r, deflated);
    }

    /// EWMA relative prediction error at one concurrent lane count (0.0
    /// before any overlapped observation at that count; `lanes <= 1` is
    /// the solo [`CostModel::calibration_error`]).
    pub fn lane_calibration_error(&self, lanes: usize) -> f64 {
        if lanes <= 1 {
            return self.calibration_error();
        }
        self.lane_tracks
            .get(&lanes)
            .filter(|t| t.observations > 0)
            .map_or(0.0, |t| t.err_ewma)
    }

    /// Lane counts with at least one overlapped observation, ascending —
    /// with the per-count calibration error (the metric exported per
    /// device in [`crate::metrics::DeviceSnapshot::lane_calibration`]).
    pub fn lane_calibration(&self) -> Vec<(usize, f64)> {
        let mut out: Vec<(usize, f64)> = self
            .lane_tracks
            .iter()
            .filter(|(_, t)| t.observations > 0)
            .map(|(&l, t)| (l, t.err_ewma))
            .collect();
        out.sort_unstable_by_key(|&(l, _)| l);
        out
    }

    /// EWMA of the relative prediction error (0.0 before any observation).
    pub fn calibration_error(&self) -> f64 {
        if self.observations == 0 {
            0.0
        } else {
            self.err_ewma
        }
    }

    /// Measured launches fed back so far.
    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// Admission-time feasibility: is even an *immediate, minimal* (r = 1)
    /// launch of this class predicted to miss a deadline `slo_s` seconds
    /// out, keeping `slack_s` of safety margin? Queue-delay-blind by
    /// design — round-time planning protects against backlog; this check
    /// sheds only requests that are lost no matter what the planner does.
    pub fn deadline_infeasible(&self, class: ShapeClass, slo_s: f64, slack_s: f64) -> bool {
        self.predict(class, 1) + slack_s > slo_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CLASS: ShapeClass =
        ShapeClass { kind: "batched_gemm", m: 256, n: 128, k: 1152 };

    #[test]
    fn analytic_seed_scales_with_r_and_stays_plausible() {
        let m = CostModel::new();
        let t1 = m.analytic_seed(CLASS, 1);
        let t32 = m.analytic_seed(CLASS, 32);
        // A lone conv2_2 SGEMM lands in the cuBLAS decade (15-120 us).
        assert!((15e-6..150e-6).contains(&t1), "r=1 seed {t1}");
        // Fusing 32 problems is far cheaper than 32 serial launches but
        // strictly more work than one.
        assert!(t32 > t1, "more lanes cost more: {t32} <= {t1}");
        assert!(t32 < 32.0 * t1 / 3.0, "fusion must amortize: {t32} vs {t1}");
    }

    #[test]
    fn prediction_uses_seed_then_ewma() {
        let mut m = CostModel::new();
        let seed = m.analytic_seed(CLASS, 8);
        assert_eq!(m.predict(CLASS, 8), seed);
        // First observation seeds the EWMA exactly (no decay-from-zero).
        m.observe(CLASS, 8, 5e-3);
        assert!((m.predict(CLASS, 8) - 5e-3).abs() < 1e-12);
        // Subsequent observations blend.
        m.observe(CLASS, 8, 10e-3);
        let p = m.predict(CLASS, 8);
        assert!(p > 5e-3 && p < 10e-3, "blended prediction {p}");
        assert_eq!(m.observations(), 2);
    }

    #[test]
    fn ratio_transfers_calibration_to_unseen_buckets() {
        let mut m = CostModel::new();
        let seed_16 = m.analytic_seed(CLASS, 16);
        // Observe r=1 running 100x slower than the analytic seed (a slow
        // substrate): the unseen r=16 prediction must scale up too.
        let seed_1 = m.analytic_seed(CLASS, 1);
        m.observe(CLASS, 1, seed_1 * 100.0);
        let p16 = m.predict(CLASS, 16);
        assert!(
            p16 > seed_16 * 50.0,
            "global ratio must lift unseen buckets: {p16} vs seed {seed_16}"
        );
    }

    #[test]
    fn calibration_error_tracks_quality() {
        let mut m = CostModel::new();
        assert_eq!(m.calibration_error(), 0.0);
        let seed = m.analytic_seed(CLASS, 4);
        m.observe(CLASS, 4, seed * 2.0); // first prediction off by 50%
        assert!(m.calibration_error() > 0.4);
        // Repeated identical measurements: the EWMA converges, error decays.
        for _ in 0..50 {
            m.observe(CLASS, 4, seed * 2.0);
        }
        assert!(m.calibration_error() < 0.05, "err {}", m.calibration_error());
    }

    #[test]
    fn garbage_observations_ignored() {
        let mut m = CostModel::new();
        m.observe(CLASS, 1, -1.0);
        m.observe(CLASS, 1, f64::NAN);
        m.observe(CLASS, 1, 0.0);
        assert_eq!(m.observations(), 0);
    }

    #[test]
    fn deadline_infeasible_detects_hopeless_slos() {
        let m = CostModel::new();
        let min = m.predict(CLASS, 1);
        assert!(m.deadline_infeasible(CLASS, min / 2.0, 0.0));
        assert!(!m.deadline_infeasible(CLASS, min * 10.0, 0.0));
        // Slack tightens the bound.
        assert!(m.deadline_infeasible(CLASS, min * 1.5, min));
    }

    #[test]
    fn non_gemm_kinds_seed_positive() {
        let m = CostModel::new();
        let mlp = ShapeClass::mlp_block(8, 512, 256, 256);
        let rnn = ShapeClass::rnn_cell(512);
        assert!(m.analytic_seed(mlp, 4) > 0.0);
        assert!(m.analytic_seed(rnn, 4) > 0.0);
    }

    #[test]
    fn lane_stretch_seeds_analytically_and_orders() {
        let m = CostModel::new();
        assert_eq!(m.lane_stretch(1), 1.0);
        // Unobserved: analytic seed from the V100 interference coefficient.
        assert!((m.lane_stretch(2) - 1.08).abs() < 1e-12);
        assert!(m.lane_stretch(4) > m.lane_stretch(2));
        let solo = m.predict(CLASS, 8);
        let dual = m.predict_concurrent(CLASS, 8, 2);
        assert!(dual > solo, "co-location must stretch: {dual} vs {solo}");
        assert_eq!(m.predict_concurrent(CLASS, 8, 1), solo);
    }

    #[test]
    fn observe_concurrent_learns_measured_stretch() {
        let mut m = CostModel::new();
        // Calibrate the solo track first.
        m.observe(CLASS, 8, 10e-3);
        // Overlapped launches at 2 lanes consistently run 1.5x the solo
        // EWMA: the learned stretch must converge to ~1.5 (far from the
        // 1.08 analytic seed).
        for _ in 0..60 {
            m.observe_concurrent(CLASS, 8, 2, 15e-3);
        }
        let s = m.lane_stretch(2);
        assert!((s - 1.5).abs() < 0.05, "learned stretch {s}");
        // Prediction error at 2 lanes converges near zero on a stationary
        // signal, and is exported per lane count.
        assert!(m.lane_calibration_error(2) < 0.05);
        let calib = m.lane_calibration();
        assert_eq!(calib.len(), 1);
        assert_eq!(calib[0].0, 2);
        // The solo track stays near the un-stretched duration: overlapped
        // measurements are deflated before they reach it.
        let solo = m.predict(CLASS, 8);
        assert!(
            (solo - 10e-3).abs() / 10e-3 < 0.1,
            "solo prediction polluted by overlapped samples: {solo}"
        );
    }

    #[test]
    fn lane_calibration_isolated_per_count() {
        let mut m = CostModel::new();
        m.observe(CLASS, 4, 1e-3);
        m.observe_concurrent(CLASS, 4, 2, 1.2e-3);
        m.observe_concurrent(CLASS, 4, 3, 1.5e-3);
        assert_eq!(m.lane_calibration().len(), 2);
        assert_eq!(m.lane_calibration_error(4), 0.0, "unobserved count");
        // Garbage overlapped observations are ignored.
        m.observe_concurrent(CLASS, 4, 2, f64::NAN);
        m.observe_concurrent(CLASS, 4, 2, -1.0);
        assert_eq!(m.lane_calibration().len(), 2);
    }
}
