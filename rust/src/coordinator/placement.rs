//! Device placement: which device of the pool each shape-class/tenant
//! lands on.
//!
//! The sharded coordinator (and the simulator's device pool) partition
//! tenants across N devices. Two forces pull against each other:
//!
//! * **Class affinity** — same-shape-class tenants fuse into one
//!   super-kernel only if they share a device; splitting a class across
//!   shards forfeits exactly the batching opportunity the space-time
//!   scheduler exists to exploit (D-STACK, arXiv:2304.13541, makes the
//!   same observation for spatio-temporal partitions).
//! * **Load balance** — a device pool only multiplies throughput if every
//!   shard stays busy; parking everything on one device serializes.
//!
//! The placer resolves them with *least-loaded with class-affinity*: each
//! class is kept whole on the least-loaded device unless the class alone
//! exceeds a fair per-device share, in which case (and only then) its
//! members spread member-by-member — a single dominant class still scales
//! to the full pool, while small classes never fragment.
//!
//! The placer is generic over the class key (`ShapeClass` in the
//! coordinator, GEMM `class_key()` tuples in the simulator pool) and fully
//! deterministic: identical inputs always produce identical assignments.
//!
//! Two layers build on the raw [`place`] function:
//!
//! * [`DevicePlacer`] — per-device live accounting across the
//!   eviction/re-admission lifecycle, with a class-affinity index (class →
//!   device → active member count) that is swept on release so re-admission
//!   never chases a device that no longer hosts the class.
//! * [`ClusterPlacer`] — the cluster tier's view: the same placer with a
//!   node liveness mask on top, plus the three cluster-only moves —
//!   forced migration ([`ClusterPlacer::migrate`], the hotspot response),
//!   fail-stop displacement ([`ClusterPlacer::set_down`]), and rejoin
//!   re-homing ([`ClusterPlacer::rehome`]) through the existing readmit
//!   path restricted to live nodes.

use std::collections::BTreeMap;
use std::hash::Hash;

/// How much a class may exceed the fair per-device share before it is
/// split across devices (1.25 = one quarter of slack).
const AFFINITY_SLACK: f64 = 1.25;

/// A computed assignment: `device_of[i]` is the device of item `i`.
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    pub device_of: Vec<usize>,
    pub load: Vec<f64>,
    pub n_devices: usize,
}

impl Placement {
    pub fn device_of(&self, item: usize) -> usize {
        self.device_of[item]
    }

    /// Items assigned to `device`, ascending.
    pub fn members(&self, device: usize) -> Vec<usize> {
        (0..self.device_of.len())
            .filter(|&i| self.device_of[i] == device)
            .collect()
    }

    /// Max/min device load ratio (1.0 = perfectly balanced). Devices with
    /// zero load count as empty; returns infinity when some device is idle
    /// while another is loaded.
    pub fn imbalance(&self) -> f64 {
        let max = self.load.iter().cloned().fold(0.0f64, f64::max);
        let min = self.load.iter().cloned().fold(f64::INFINITY, f64::min);
        if max <= 0.0 {
            1.0
        } else if min <= 0.0 {
            f64::INFINITY
        } else {
            max / min
        }
    }
}

/// Assign `items` — `(class, load)` pairs, e.g. one per tenant — to
/// `n_devices` devices, least-loaded with class affinity.
pub fn place<K: Ord + Eq + Hash + Clone>(
    items: &[(K, f64)],
    n_devices: usize,
) -> Placement {
    assert!(n_devices >= 1, "need at least one device");
    let mut device_of = vec![0usize; items.len()];
    let mut load = vec![0.0f64; n_devices];
    if n_devices == 1 {
        load[0] = items.iter().map(|(_, l)| l.max(0.0)).sum();
        return Placement { device_of, load, n_devices };
    }

    // Group by class, deterministically (BTreeMap orders by class key).
    let mut by_class: BTreeMap<&K, Vec<usize>> = BTreeMap::new();
    for (i, (k, _)) in items.iter().enumerate() {
        by_class.entry(k).or_default().push(i);
    }
    // All-zero loads would make every argmin return device 0 and collapse
    // the pool onto one shard; fall back to unit weights (pure count
    // balancing) so zero-load items still spread.
    let raw_total: f64 = items.iter().map(|(_, l)| l.max(0.0)).sum();
    let unit_weights = raw_total <= 0.0;
    let weight = |i: usize| {
        if unit_weights {
            1.0
        } else {
            items[i].1.max(0.0)
        }
    };
    let total = if unit_weights { items.len() as f64 } else { raw_total };
    let fair = total / n_devices as f64;

    // Place big classes first so small ones backfill the gaps.
    let mut classes: Vec<(&K, Vec<usize>, f64)> = by_class
        .into_iter()
        .map(|(k, members)| {
            let class_load: f64 = members.iter().map(|&i| weight(i)).sum();
            (k, members, class_load)
        })
        .collect();
    classes.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap().then(a.0.cmp(b.0)));

    let argmin = |load: &[f64]| -> usize {
        let mut best = 0;
        for (d, &l) in load.iter().enumerate() {
            if l < load[best] {
                best = d;
            }
        }
        best
    };

    for (_, members, class_load) in classes {
        if class_load <= fair * AFFINITY_SLACK {
            // Whole class to the least-loaded device: fusion stays intact.
            let d = argmin(&load);
            for &i in &members {
                device_of[i] = d;
                load[d] += weight(i);
            }
        } else {
            // Dominant class: spread member-by-member so the pool actually
            // multiplies throughput (members fuse within each shard).
            for &i in &members {
                let d = argmin(&load);
                device_of[i] = d;
                load[d] += weight(i);
            }
        }
    }
    Placement { device_of, load, n_devices }
}

/// The placer the coordinator keeps: the registration-time assignment plus
/// live load accounting across the eviction/re-admission lifecycle. A
/// tenant's device never moves while it is active; an evicted tenant's
/// load is released ([`DevicePlacer::release`]) so later placement
/// decisions see the true residual load, and a re-registered tenant
/// re-joins its shape class's device when one is still active
/// ([`DevicePlacer::readmit`]) so fusion affinity survives the round trip.
#[derive(Debug)]
pub struct DevicePlacer<K: Ord + Eq + Hash + Clone> {
    items: Vec<(K, f64)>,
    active: Vec<bool>,
    placement: Placement,
    /// class → device → count of *active* members of that class on that
    /// device. Entries are swept as they hit zero (on release/migration),
    /// so a key's presence means the device genuinely hosts the class —
    /// re-admission affinity reads this instead of scanning every tenant,
    /// and can never chase a device the class has fully left.
    class_index: BTreeMap<K, BTreeMap<usize, usize>>,
}

impl<K: Ord + Eq + Hash + Clone> DevicePlacer<K> {
    /// Place `tenants` — `(class, expected per-request load)` — on
    /// `n_devices`.
    pub fn new(tenants: &[(K, f64)], n_devices: usize) -> Self {
        let placement = place(tenants, n_devices);
        let mut class_index: BTreeMap<K, BTreeMap<usize, usize>> = BTreeMap::new();
        for (i, (k, _)) in tenants.iter().enumerate() {
            *class_index
                .entry(k.clone())
                .or_default()
                .entry(placement.device_of[i])
                .or_insert(0) += 1;
        }
        Self {
            items: tenants.to_vec(),
            active: vec![true; tenants.len()],
            placement,
            class_index,
        }
    }

    pub fn n_devices(&self) -> usize {
        self.placement.n_devices
    }

    pub fn device_of(&self, tenant: usize) -> usize {
        self.placement.device_of(tenant)
    }

    pub fn members(&self, device: usize) -> Vec<usize> {
        self.placement.members(device)
    }

    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    pub fn is_active(&self, tenant: usize) -> bool {
        self.active.get(tenant).copied().unwrap_or(false)
    }

    fn weight(&self, tenant: usize) -> f64 {
        self.items.get(tenant).map_or(0.0, |(_, l)| l.max(0.0))
    }

    /// A tenant's load weight as the placer accounts it.
    pub fn weight_of(&self, tenant: usize) -> f64 {
        self.weight(tenant)
    }

    /// The class-affinity index: class → device → active member count.
    /// Exposed for the placement-invariant property tests.
    pub fn class_index(&self) -> &BTreeMap<K, BTreeMap<usize, usize>> {
        &self.class_index
    }

    /// Release an evicted tenant's load from its device. The tenant keeps
    /// its historical `device_of` entry (callers still drain its queues
    /// there) but stops counting toward the shard's load, and its class
    /// index entry is decremented — swept entirely when it was the last
    /// active member of its class on that device, so affinity re-admission
    /// under an eviction storm never lands on a device the class has
    /// actually left. Idempotent.
    pub fn release(&mut self, tenant: usize) {
        if tenant >= self.items.len() || !self.active[tenant] {
            return;
        }
        self.active[tenant] = false;
        let d = self.placement.device_of[tenant];
        self.placement.load[d] = (self.placement.load[d] - self.weight(tenant)).max(0.0);
        let class = self.items[tenant].0.clone();
        if let Some(devices) = self.class_index.get_mut(&class) {
            if let Some(n) = devices.get_mut(&d) {
                *n -= 1;
                if *n == 0 {
                    devices.remove(&d);
                }
            }
            if devices.is_empty() {
                self.class_index.remove(&class);
            }
        }
    }

    /// Re-admit a released tenant: it re-joins the least-loaded device
    /// among those hosting *active* members of its shape class (fusion
    /// affinity), falling back to the least-loaded device overall when the
    /// class has no active member left. Returns the chosen device.
    /// A still-active tenant is a no-op returning its current device.
    pub fn readmit(&mut self, tenant: usize) -> usize {
        self.readmit_where(tenant, |_| true)
    }

    /// [`DevicePlacer::readmit`] restricted to devices for which `allowed`
    /// returns true — the cluster layer passes node liveness here. Panics
    /// if no device is allowed.
    pub fn readmit_where(
        &mut self,
        tenant: usize,
        allowed: impl Fn(usize) -> bool,
    ) -> usize {
        assert!(tenant < self.items.len(), "unknown tenant {tenant}");
        if self.active[tenant] {
            return self.placement.device_of[tenant];
        }
        // The tenant itself is inactive, so the index only holds peers.
        let class_device = self
            .class_index
            .get(&self.items[tenant].0)
            .into_iter()
            .flat_map(|devices| devices.keys().copied())
            .filter(|&d| allowed(d))
            .min_by(|&a, &b| {
                self.placement.load[a]
                    .partial_cmp(&self.placement.load[b])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
        let d = class_device.unwrap_or_else(|| {
            let mut best: Option<usize> = None;
            for (i, &l) in self.placement.load.iter().enumerate() {
                if !allowed(i) {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some(b) => l < self.placement.load[b],
                };
                if better {
                    best = Some(i);
                }
            }
            best.expect("readmit_where: no device allowed")
        });
        self.activate_on(tenant, d);
        d
    }

    /// Force-place `tenant` on `device` — the cluster tier's migration
    /// primitive. Releases it from wherever it is (if active) and
    /// re-activates it on `device`, keeping load and class-index
    /// accounting exact.
    pub fn assign(&mut self, tenant: usize, device: usize) {
        assert!(tenant < self.items.len(), "unknown tenant {tenant}");
        assert!(device < self.placement.n_devices, "unknown device {device}");
        self.release(tenant);
        self.activate_on(tenant, device);
    }

    fn activate_on(&mut self, tenant: usize, device: usize) {
        self.active[tenant] = true;
        self.placement.device_of[tenant] = device;
        self.placement.load[device] += self.weight(tenant);
        let class = self.items[tenant].0.clone();
        *self.class_index.entry(class).or_default().entry(device).or_insert(0) += 1;
    }

    /// Sum of active tenants' load weights. With real (positive) loads
    /// this equals the sum of per-device loads up to floating-point error
    /// — the accounting invariant the re-admission tests assert. (The
    /// degenerate all-zero-load placement counts unit weights instead and
    /// is excluded from the invariant.)
    pub fn active_load(&self) -> f64 {
        (0..self.items.len())
            .filter(|&i| self.active[i])
            .map(|i| self.weight(i))
            .sum()
    }
}

/// The cluster tier's placement layer: a [`DevicePlacer`] whose "devices"
/// are whole nodes, plus a liveness mask. All moves go through the
/// per-device release/readmit machinery so load and class-affinity
/// accounting stay exact across migrations, failures, and rejoins.
#[derive(Debug)]
pub struct ClusterPlacer<K: Ord + Eq + Hash + Clone> {
    placer: DevicePlacer<K>,
    live: Vec<bool>,
}

impl<K: Ord + Eq + Hash + Clone> ClusterPlacer<K> {
    /// Place `tenants` — `(class, expected load)` — across `n_nodes` live
    /// nodes.
    pub fn new(tenants: &[(K, f64)], n_nodes: usize) -> Self {
        Self { placer: DevicePlacer::new(tenants, n_nodes), live: vec![true; n_nodes] }
    }

    pub fn n_nodes(&self) -> usize {
        self.live.len()
    }

    pub fn n_live(&self) -> usize {
        self.live.iter().filter(|&&l| l).count()
    }

    pub fn is_live(&self, node: usize) -> bool {
        self.live.get(node).copied().unwrap_or(false)
    }

    /// The node currently hosting (or, for an inactive tenant, last
    /// hosting) `tenant`.
    pub fn node_of(&self, tenant: usize) -> usize {
        self.placer.device_of(tenant)
    }

    pub fn weight_of(&self, tenant: usize) -> f64 {
        self.placer.weight_of(tenant)
    }

    pub fn load_of(&self, node: usize) -> f64 {
        self.placer.placement().load[node]
    }

    /// Active tenants resident on `node`, ascending.
    pub fn tenants_on(&self, node: usize) -> Vec<usize> {
        self.placer
            .members(node)
            .into_iter()
            .filter(|&t| self.placer.is_active(t))
            .collect()
    }

    pub fn inner(&self) -> &DevicePlacer<K> {
        &self.placer
    }

    /// Move `tenant` to live node `dst` — the hotspot-migration primitive.
    pub fn migrate(&mut self, tenant: usize, dst: usize) {
        assert!(self.is_live(dst), "cannot migrate tenant {tenant} to dead node {dst}");
        self.placer.assign(tenant, dst);
    }

    /// Fail-stop `node`: every resident tenant is released and re-placed
    /// on a live node (class affinity first, least-loaded fallback).
    /// Returns `(tenant, new_node)` per displaced tenant, ascending by
    /// tenant. Panics if this would leave zero live nodes.
    pub fn set_down(&mut self, node: usize) -> Vec<(usize, usize)> {
        assert!(self.is_live(node), "node {node} is already down");
        self.live[node] = false;
        assert!(self.n_live() > 0, "cannot take the last live node down");
        let displaced = self.tenants_on(node);
        // Release the whole group first so the re-placement of the first
        // displaced tenant does not chase a class peer that is itself
        // about to be displaced from the same dead node.
        for &t in &displaced {
            self.placer.release(t);
        }
        displaced.into_iter().map(|t| (t, self.readmit_live(t))).collect()
    }

    /// Re-admit a rejoined node. Tenants do NOT move back automatically —
    /// the committer re-homes them explicitly (journaled) via
    /// [`ClusterPlacer::rehome`].
    pub fn set_up(&mut self, node: usize) {
        assert!(node < self.live.len(), "unknown node {node}");
        self.live[node] = true;
    }

    /// Re-admit an inactive tenant on the best live node.
    pub fn readmit_live(&mut self, tenant: usize) -> usize {
        let live = self.live.clone();
        self.placer.readmit_where(tenant, |n| live[n])
    }

    /// Re-run placement for a group of tenants together — the node-rejoin
    /// path. The whole group is released before any member is re-admitted:
    /// re-homing displaced tenants one at a time would anchor each to the
    /// class peers displaced alongside it, and nothing would ever migrate
    /// back to a rejoined (empty, least-loaded) node. Returns
    /// `(tenant, from, to)` ascending by tenant; `from == to` means it
    /// stayed put.
    pub fn rehome_group(&mut self, tenants: &[usize]) -> Vec<(usize, usize, usize)> {
        let mut sorted: Vec<usize> = tenants.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        let froms: Vec<usize> = sorted.iter().map(|&t| self.node_of(t)).collect();
        for &t in &sorted {
            self.placer.release(t);
        }
        sorted
            .into_iter()
            .zip(froms)
            .map(|(t, from)| (t, from, self.readmit_live(t)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_device_takes_everything() {
        let p = place(&[("a", 1.0), ("b", 2.0), ("a", 3.0)], 1);
        assert_eq!(p.device_of, vec![0, 0, 0]);
        assert_eq!(p.load, vec![6.0]);
        assert_eq!(p.imbalance(), 1.0);
    }

    #[test]
    fn small_classes_keep_affinity() {
        // 4 classes x 2 tenants, equal loads, 4 devices: each class lands
        // whole on its own device.
        let items: Vec<(u32, f64)> =
            (0..8).map(|i| (i % 4, 1.0)).collect();
        let p = place(&items, 4);
        for c in 0..4u32 {
            let devices: std::collections::BTreeSet<usize> = items
                .iter()
                .enumerate()
                .filter(|(_, (k, _))| *k == c)
                .map(|(i, _)| p.device_of(i))
                .collect();
            assert_eq!(devices.len(), 1, "class {c} split across {devices:?}");
        }
        assert!(p.imbalance() < 1.01, "imbalance {}", p.imbalance());
    }

    #[test]
    fn dominant_class_spreads_across_the_pool() {
        // One class with all the load must not collapse the pool to a
        // single device.
        let items: Vec<(u32, f64)> = (0..16).map(|_| (7u32, 1.0)).collect();
        let p = place(&items, 4);
        for d in 0..4 {
            assert_eq!(p.members(d).len(), 4, "device {d} share");
        }
        assert!(p.imbalance() < 1.01);
    }

    #[test]
    fn mixed_big_and_small_classes_balance() {
        // Class 0 dominates (spread); classes 1..4 are small (whole).
        let mut items: Vec<(u32, f64)> = (0..12).map(|_| (0u32, 2.0)).collect();
        for c in 1..4u32 {
            items.push((c, 1.0));
        }
        let p = place(&items, 3);
        // Small classes stay whole.
        for c in 1..4u32 {
            let devices: std::collections::BTreeSet<usize> = items
                .iter()
                .enumerate()
                .filter(|(_, (k, _))| *k == c)
                .map(|(i, _)| p.device_of(i))
                .collect();
            assert_eq!(devices.len(), 1);
        }
        assert!(p.imbalance() < 1.5, "imbalance {}", p.imbalance());
    }

    #[test]
    fn deterministic() {
        let items: Vec<(u32, f64)> = (0..20).map(|i| (i % 5, 1.0 + i as f64)).collect();
        assert_eq!(place(&items, 4), place(&items, 4));
    }

    #[test]
    fn zero_load_items_still_spread() {
        // Degenerate all-zero loads fall back to count balancing — the
        // pool must not collapse onto device 0.
        let p = place(&[("a", 0.0), ("b", 0.0)], 2);
        let used: std::collections::BTreeSet<usize> =
            p.device_of.iter().copied().collect();
        assert_eq!(used.len(), 2, "both devices used: {:?}", p.device_of);
        assert_eq!(p.imbalance(), 1.0);

        // A single dominant zero-load class spreads too.
        let items: Vec<(u32, f64)> = (0..8).map(|_| (1u32, 0.0)).collect();
        let p2 = place(&items, 4);
        for d in 0..4 {
            assert_eq!(p2.members(d).len(), 2, "device {d}");
        }
    }

    #[test]
    #[should_panic(expected = "at least one device")]
    fn zero_devices_rejected() {
        let _ = place(&[("a", 1.0)], 0);
    }

    #[test]
    fn release_then_readmit_restores_load_and_affinity() {
        // Two classes x two tenants on two devices: each class whole.
        let items = [("a", 2.0), ("a", 2.0), ("b", 2.0), ("b", 2.0)];
        let mut p = DevicePlacer::new(&items, 2);
        let total = p.active_load();
        assert_eq!(total, 8.0);
        let home = p.device_of(1);
        let peer_home = p.device_of(0);
        assert_eq!(home, peer_home, "class 'a' placed whole");

        p.release(1);
        assert!(!p.is_active(1));
        assert_eq!(p.active_load(), 6.0);
        let load_sum: f64 = p.placement().load.iter().sum();
        assert!((load_sum - 6.0).abs() < 1e-9, "released load leaves the device");
        // Idempotent.
        p.release(1);
        assert_eq!(p.active_load(), 6.0);

        let d = p.readmit(1);
        assert_eq!(d, peer_home, "re-admission re-joins the class's device");
        assert!(p.is_active(1));
        assert_eq!(p.active_load(), 8.0);
        let load_sum: f64 = p.placement().load.iter().sum();
        assert!((load_sum - 8.0).abs() < 1e-9, "load restored exactly");
        // Re-admitting an active tenant is a no-op.
        assert_eq!(p.readmit(1), d);
        assert_eq!(p.active_load(), 8.0);
    }

    #[test]
    fn readmit_without_class_peers_falls_back_to_least_loaded() {
        let items = [("a", 4.0), ("b", 1.0)];
        let mut p = DevicePlacer::new(&items, 2);
        p.release(1);
        // Tenant 1's class has no other member: it must land on the
        // emptier device, not blindly on its old one.
        let d = p.readmit(1);
        let other = p.device_of(0);
        assert_ne!(d, other, "least-loaded fallback avoids the busy shard");
    }

    #[test]
    fn release_sweeps_empty_class_index_entries() {
        let items = [("a", 0.5), ("a", 0.5), ("b", 5.0), ("c", 2.0)];
        let mut p = DevicePlacer::new(&items, 2);
        let home = p.device_of(0);
        assert_eq!(p.device_of(1), home, "class 'a' placed whole");
        assert_eq!(p.class_index()["a"][&home], 2);

        p.release(0);
        assert_eq!(p.class_index()["a"][&home], 1, "one member left");
        p.release(1);
        assert!(p.class_index().get("a").is_none(), "empty class entry swept");

        // Pile the remaining load onto the old home. With the stale entry
        // swept, re-admission must fall back to the genuinely least-loaded
        // device instead of chasing a device hosting zero 'a' tenants.
        p.assign(2, home);
        p.assign(3, home);
        let d = p.readmit(0);
        assert_ne!(d, home, "stale affinity entry was chased");
    }

    /// Seeded eviction/re-admission/migration storm asserting the
    /// placement invariants after every step: the class index matches a
    /// from-scratch recount (no stale or missing entries), per-device
    /// loads sum to the active tenants' total weight, and re-admission
    /// joins an active class peer whenever one exists.
    #[test]
    fn eviction_storm_preserves_placement_invariants() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(0x5eed_cafe);
        let n_devices = 4usize;
        let items: Vec<(u32, f64)> =
            (0..24).map(|i| (i as u32 % 6, 0.5 + rng.next_f64() * 3.0)).collect();
        let mut p = DevicePlacer::new(&items, n_devices);
        for step in 0..2000 {
            let t = rng.gen_range(items.len() as u64) as usize;
            match rng.gen_range(3) {
                0 => p.release(t),
                1 => {
                    let expect_affinity =
                        !p.is_active(t) && p.class_index().contains_key(&items[t].0);
                    let d = p.readmit(t);
                    if expect_affinity {
                        let has_peer = (0..items.len()).any(|i| {
                            i != t
                                && p.is_active(i)
                                && items[i].0 == items[t].0
                                && p.device_of(i) == d
                        });
                        assert!(has_peer, "step {step}: readmit({t}) -> {d} has no class peer");
                    }
                }
                _ => {
                    let d = rng.gen_range(n_devices as u64) as usize;
                    p.assign(t, d);
                    assert!(p.is_active(t));
                    assert_eq!(p.device_of(t), d);
                }
            }
            // The index must equal a recount from scratch.
            let mut want: BTreeMap<u32, BTreeMap<usize, usize>> = BTreeMap::new();
            for (i, (k, _)) in items.iter().enumerate() {
                if p.is_active(i) {
                    *want.entry(*k).or_default().entry(p.device_of(i)).or_insert(0) += 1;
                }
            }
            assert_eq!(p.class_index(), &want, "step {step}: class index drifted");
            // Load accounting stays exact (modulo float error).
            let dev_sum: f64 = p.placement().load.iter().sum();
            assert!(
                (dev_sum - p.active_load()).abs() < 1e-6,
                "step {step}: device loads {dev_sum} vs active {}",
                p.active_load()
            );
            assert!(p.placement().load.iter().all(|&l| l >= 0.0), "step {step}: negative load");
        }
        // Idempotence at the end of the storm.
        let _ = p.readmit(0);
        let before = p.active_load();
        p.release(0);
        p.release(0);
        assert!((before - p.active_load() - p.weight_of(0)).abs() < 1e-6);
        let d = p.readmit(0);
        assert_eq!(p.readmit(0), d, "re-admitting an active tenant is a no-op");
        assert!((p.active_load() - before).abs() < 1e-6);
    }

    #[test]
    fn cluster_set_down_displaces_and_rejoin_rehomes() {
        // 4 classes x 2 tenants across 4 nodes: each class whole per node.
        let items: Vec<(u32, f64)> = (0..8).map(|i| (i as u32 % 4, 1.0)).collect();
        let mut c = ClusterPlacer::new(&items, 4);
        assert_eq!((c.n_nodes(), c.n_live()), (4, 4));
        let victim = c.node_of(0);
        let residents = c.tenants_on(victim);
        assert!(!residents.is_empty());

        let moves = c.set_down(victim);
        assert!(!c.is_live(victim));
        assert_eq!(c.n_live(), 3);
        assert_eq!(moves.iter().map(|&(t, _)| t).collect::<Vec<_>>(), residents);
        for &(t, to) in &moves {
            assert_ne!(to, victim, "tenant {t} placed on the dead node");
            assert!(c.is_live(to));
            assert_eq!(c.node_of(t), to);
        }
        assert!(c.tenants_on(victim).is_empty());
        assert_eq!(c.load_of(victim), 0.0);
        // The displaced class travelled together (affinity survives).
        assert_eq!(moves[0].1, moves[1].1);

        c.set_up(victim);
        assert_eq!(c.n_live(), 4);
        // Rejoined node is empty, hence least-loaded: re-homing the
        // displaced group pulls it back there.
        let group: Vec<usize> = moves.iter().map(|&(t, _)| t).collect();
        let back = c.rehome_group(&group);
        for &(t, from, to) in &back {
            assert_eq!(from, moves.iter().find(|&&(mt, _)| mt == t).unwrap().1);
            assert_eq!(to, victim, "tenant {t} returned to the rejoined node");
        }
    }

    #[test]
    fn cluster_migrate_moves_load_between_nodes() {
        let items: Vec<(u32, f64)> = (0..4).map(|i| (i as u32, 1.0)).collect();
        let mut c = ClusterPlacer::new(&items, 2);
        let src = c.node_of(0);
        let dst = 1 - src;
        let (ls, ld) = (c.load_of(src), c.load_of(dst));
        c.migrate(0, dst);
        assert_eq!(c.node_of(0), dst);
        assert!((c.load_of(src) - (ls - 1.0)).abs() < 1e-9);
        assert!((c.load_of(dst) - (ld + 1.0)).abs() < 1e-9);
        // Migrating to the current home leaves the totals unchanged.
        c.migrate(0, dst);
        assert!((c.load_of(dst) - (ld + 1.0)).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "dead node")]
    fn cluster_migrate_to_a_dead_node_is_rejected() {
        let items: Vec<(u32, f64)> = (0..4).map(|i| (i as u32, 1.0)).collect();
        let mut c = ClusterPlacer::new(&items, 2);
        let _ = c.set_down(0);
        c.migrate(1, 0);
    }
}
