//! Device placement: which device of the pool each shape-class/tenant
//! lands on.
//!
//! The sharded coordinator (and the simulator's device pool) partition
//! tenants across N devices. Two forces pull against each other:
//!
//! * **Class affinity** — same-shape-class tenants fuse into one
//!   super-kernel only if they share a device; splitting a class across
//!   shards forfeits exactly the batching opportunity the space-time
//!   scheduler exists to exploit (D-STACK, arXiv:2304.13541, makes the
//!   same observation for spatio-temporal partitions).
//! * **Load balance** — a device pool only multiplies throughput if every
//!   shard stays busy; parking everything on one device serializes.
//!
//! The placer resolves them with *least-loaded with class-affinity*: each
//! class is kept whole on the least-loaded device unless the class alone
//! exceeds a fair per-device share, in which case (and only then) its
//! members spread member-by-member — a single dominant class still scales
//! to the full pool, while small classes never fragment.
//!
//! The placer is generic over the class key (`ShapeClass` in the
//! coordinator, GEMM `class_key()` tuples in the simulator pool) and fully
//! deterministic: identical inputs always produce identical assignments.

use std::collections::BTreeMap;
use std::hash::Hash;

/// How much a class may exceed the fair per-device share before it is
/// split across devices (1.25 = one quarter of slack).
const AFFINITY_SLACK: f64 = 1.25;

/// A computed assignment: `device_of[i]` is the device of item `i`.
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    pub device_of: Vec<usize>,
    pub load: Vec<f64>,
    pub n_devices: usize,
}

impl Placement {
    pub fn device_of(&self, item: usize) -> usize {
        self.device_of[item]
    }

    /// Items assigned to `device`, ascending.
    pub fn members(&self, device: usize) -> Vec<usize> {
        (0..self.device_of.len())
            .filter(|&i| self.device_of[i] == device)
            .collect()
    }

    /// Max/min device load ratio (1.0 = perfectly balanced). Devices with
    /// zero load count as empty; returns infinity when some device is idle
    /// while another is loaded.
    pub fn imbalance(&self) -> f64 {
        let max = self.load.iter().cloned().fold(0.0f64, f64::max);
        let min = self.load.iter().cloned().fold(f64::INFINITY, f64::min);
        if max <= 0.0 {
            1.0
        } else if min <= 0.0 {
            f64::INFINITY
        } else {
            max / min
        }
    }
}

/// Assign `items` — `(class, load)` pairs, e.g. one per tenant — to
/// `n_devices` devices, least-loaded with class affinity.
pub fn place<K: Ord + Eq + Hash + Clone>(
    items: &[(K, f64)],
    n_devices: usize,
) -> Placement {
    assert!(n_devices >= 1, "need at least one device");
    let mut device_of = vec![0usize; items.len()];
    let mut load = vec![0.0f64; n_devices];
    if n_devices == 1 {
        load[0] = items.iter().map(|(_, l)| l.max(0.0)).sum();
        return Placement { device_of, load, n_devices };
    }

    // Group by class, deterministically (BTreeMap orders by class key).
    let mut by_class: BTreeMap<&K, Vec<usize>> = BTreeMap::new();
    for (i, (k, _)) in items.iter().enumerate() {
        by_class.entry(k).or_default().push(i);
    }
    // All-zero loads would make every argmin return device 0 and collapse
    // the pool onto one shard; fall back to unit weights (pure count
    // balancing) so zero-load items still spread.
    let raw_total: f64 = items.iter().map(|(_, l)| l.max(0.0)).sum();
    let unit_weights = raw_total <= 0.0;
    let weight = |i: usize| {
        if unit_weights {
            1.0
        } else {
            items[i].1.max(0.0)
        }
    };
    let total = if unit_weights { items.len() as f64 } else { raw_total };
    let fair = total / n_devices as f64;

    // Place big classes first so small ones backfill the gaps.
    let mut classes: Vec<(&K, Vec<usize>, f64)> = by_class
        .into_iter()
        .map(|(k, members)| {
            let class_load: f64 = members.iter().map(|&i| weight(i)).sum();
            (k, members, class_load)
        })
        .collect();
    classes.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap().then(a.0.cmp(b.0)));

    let argmin = |load: &[f64]| -> usize {
        let mut best = 0;
        for (d, &l) in load.iter().enumerate() {
            if l < load[best] {
                best = d;
            }
        }
        best
    };

    for (_, members, class_load) in classes {
        if class_load <= fair * AFFINITY_SLACK {
            // Whole class to the least-loaded device: fusion stays intact.
            let d = argmin(&load);
            for &i in &members {
                device_of[i] = d;
                load[d] += weight(i);
            }
        } else {
            // Dominant class: spread member-by-member so the pool actually
            // multiplies throughput (members fuse within each shard).
            for &i in &members {
                let d = argmin(&load);
                device_of[i] = d;
                load[d] += weight(i);
            }
        }
    }
    Placement { device_of, load, n_devices }
}

/// The placer the coordinator keeps: the registration-time assignment plus
/// live load accounting across the eviction/re-admission lifecycle. A
/// tenant's device never moves while it is active; an evicted tenant's
/// load is released ([`DevicePlacer::release`]) so later placement
/// decisions see the true residual load, and a re-registered tenant
/// re-joins its shape class's device when one is still active
/// ([`DevicePlacer::readmit`]) so fusion affinity survives the round trip.
#[derive(Debug)]
pub struct DevicePlacer<K: Ord + Eq + Hash + Clone> {
    items: Vec<(K, f64)>,
    active: Vec<bool>,
    placement: Placement,
}

impl<K: Ord + Eq + Hash + Clone> DevicePlacer<K> {
    /// Place `tenants` — `(class, expected per-request load)` — on
    /// `n_devices`.
    pub fn new(tenants: &[(K, f64)], n_devices: usize) -> Self {
        Self {
            items: tenants.to_vec(),
            active: vec![true; tenants.len()],
            placement: place(tenants, n_devices),
        }
    }

    pub fn n_devices(&self) -> usize {
        self.placement.n_devices
    }

    pub fn device_of(&self, tenant: usize) -> usize {
        self.placement.device_of(tenant)
    }

    pub fn members(&self, device: usize) -> Vec<usize> {
        self.placement.members(device)
    }

    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    pub fn is_active(&self, tenant: usize) -> bool {
        self.active.get(tenant).copied().unwrap_or(false)
    }

    fn weight(&self, tenant: usize) -> f64 {
        self.items.get(tenant).map_or(0.0, |(_, l)| l.max(0.0))
    }

    /// Release an evicted tenant's load from its device. The tenant keeps
    /// its historical `device_of` entry (callers still drain its queues
    /// there) but stops counting toward the shard's load. Idempotent.
    pub fn release(&mut self, tenant: usize) {
        if tenant >= self.items.len() || !self.active[tenant] {
            return;
        }
        self.active[tenant] = false;
        let d = self.placement.device_of[tenant];
        self.placement.load[d] = (self.placement.load[d] - self.weight(tenant)).max(0.0);
    }

    /// Re-admit a released tenant: it re-joins the least-loaded device
    /// among those hosting *active* members of its shape class (fusion
    /// affinity), falling back to the least-loaded device overall when the
    /// class has no active member left. Returns the chosen device.
    /// A still-active tenant is a no-op returning its current device.
    pub fn readmit(&mut self, tenant: usize) -> usize {
        assert!(tenant < self.items.len(), "unknown tenant {tenant}");
        if self.active[tenant] {
            return self.placement.device_of[tenant];
        }
        let class = &self.items[tenant].0;
        let class_device = (0..self.items.len())
            .filter(|&i| i != tenant && self.active[i] && &self.items[i].0 == class)
            .map(|i| self.placement.device_of[i])
            .min_by(|&a, &b| {
                self.placement.load[a]
                    .partial_cmp(&self.placement.load[b])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
        let d = class_device.unwrap_or_else(|| {
            let mut best = 0;
            for (i, &l) in self.placement.load.iter().enumerate() {
                if l < self.placement.load[best] {
                    best = i;
                }
            }
            best
        });
        self.active[tenant] = true;
        self.placement.device_of[tenant] = d;
        self.placement.load[d] += self.weight(tenant);
        d
    }

    /// Sum of active tenants' load weights. With real (positive) loads
    /// this equals the sum of per-device loads up to floating-point error
    /// — the accounting invariant the re-admission tests assert. (The
    /// degenerate all-zero-load placement counts unit weights instead and
    /// is excluded from the invariant.)
    pub fn active_load(&self) -> f64 {
        (0..self.items.len())
            .filter(|&i| self.active[i])
            .map(|i| self.weight(i))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_device_takes_everything() {
        let p = place(&[("a", 1.0), ("b", 2.0), ("a", 3.0)], 1);
        assert_eq!(p.device_of, vec![0, 0, 0]);
        assert_eq!(p.load, vec![6.0]);
        assert_eq!(p.imbalance(), 1.0);
    }

    #[test]
    fn small_classes_keep_affinity() {
        // 4 classes x 2 tenants, equal loads, 4 devices: each class lands
        // whole on its own device.
        let items: Vec<(u32, f64)> =
            (0..8).map(|i| (i % 4, 1.0)).collect();
        let p = place(&items, 4);
        for c in 0..4u32 {
            let devices: std::collections::BTreeSet<usize> = items
                .iter()
                .enumerate()
                .filter(|(_, (k, _))| *k == c)
                .map(|(i, _)| p.device_of(i))
                .collect();
            assert_eq!(devices.len(), 1, "class {c} split across {devices:?}");
        }
        assert!(p.imbalance() < 1.01, "imbalance {}", p.imbalance());
    }

    #[test]
    fn dominant_class_spreads_across_the_pool() {
        // One class with all the load must not collapse the pool to a
        // single device.
        let items: Vec<(u32, f64)> = (0..16).map(|_| (7u32, 1.0)).collect();
        let p = place(&items, 4);
        for d in 0..4 {
            assert_eq!(p.members(d).len(), 4, "device {d} share");
        }
        assert!(p.imbalance() < 1.01);
    }

    #[test]
    fn mixed_big_and_small_classes_balance() {
        // Class 0 dominates (spread); classes 1..4 are small (whole).
        let mut items: Vec<(u32, f64)> = (0..12).map(|_| (0u32, 2.0)).collect();
        for c in 1..4u32 {
            items.push((c, 1.0));
        }
        let p = place(&items, 3);
        // Small classes stay whole.
        for c in 1..4u32 {
            let devices: std::collections::BTreeSet<usize> = items
                .iter()
                .enumerate()
                .filter(|(_, (k, _))| *k == c)
                .map(|(i, _)| p.device_of(i))
                .collect();
            assert_eq!(devices.len(), 1);
        }
        assert!(p.imbalance() < 1.5, "imbalance {}", p.imbalance());
    }

    #[test]
    fn deterministic() {
        let items: Vec<(u32, f64)> = (0..20).map(|i| (i % 5, 1.0 + i as f64)).collect();
        assert_eq!(place(&items, 4), place(&items, 4));
    }

    #[test]
    fn zero_load_items_still_spread() {
        // Degenerate all-zero loads fall back to count balancing — the
        // pool must not collapse onto device 0.
        let p = place(&[("a", 0.0), ("b", 0.0)], 2);
        let used: std::collections::BTreeSet<usize> =
            p.device_of.iter().copied().collect();
        assert_eq!(used.len(), 2, "both devices used: {:?}", p.device_of);
        assert_eq!(p.imbalance(), 1.0);

        // A single dominant zero-load class spreads too.
        let items: Vec<(u32, f64)> = (0..8).map(|_| (1u32, 0.0)).collect();
        let p2 = place(&items, 4);
        for d in 0..4 {
            assert_eq!(p2.members(d).len(), 2, "device {d}");
        }
    }

    #[test]
    #[should_panic(expected = "at least one device")]
    fn zero_devices_rejected() {
        let _ = place(&[("a", 1.0)], 0);
    }

    #[test]
    fn release_then_readmit_restores_load_and_affinity() {
        // Two classes x two tenants on two devices: each class whole.
        let items = [("a", 2.0), ("a", 2.0), ("b", 2.0), ("b", 2.0)];
        let mut p = DevicePlacer::new(&items, 2);
        let total = p.active_load();
        assert_eq!(total, 8.0);
        let home = p.device_of(1);
        let peer_home = p.device_of(0);
        assert_eq!(home, peer_home, "class 'a' placed whole");

        p.release(1);
        assert!(!p.is_active(1));
        assert_eq!(p.active_load(), 6.0);
        let load_sum: f64 = p.placement().load.iter().sum();
        assert!((load_sum - 6.0).abs() < 1e-9, "released load leaves the device");
        // Idempotent.
        p.release(1);
        assert_eq!(p.active_load(), 6.0);

        let d = p.readmit(1);
        assert_eq!(d, peer_home, "re-admission re-joins the class's device");
        assert!(p.is_active(1));
        assert_eq!(p.active_load(), 8.0);
        let load_sum: f64 = p.placement().load.iter().sum();
        assert!((load_sum - 8.0).abs() < 1e-9, "load restored exactly");
        // Re-admitting an active tenant is a no-op.
        assert_eq!(p.readmit(1), d);
        assert_eq!(p.active_load(), 8.0);
    }

    #[test]
    fn readmit_without_class_peers_falls_back_to_least_loaded() {
        let items = [("a", 4.0), ("b", 1.0)];
        let mut p = DevicePlacer::new(&items, 2);
        p.release(1);
        // Tenant 1's class has no other member: it must land on the
        // emptier device, not blindly on its old one.
        let d = p.readmit(1);
        let other = p.device_of(0);
        assert_ne!(d, other, "least-loaded fallback avoids the busy shard");
    }
}
