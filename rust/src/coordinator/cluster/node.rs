//! A simulated cluster node: one per-device coordinator stack (EDF queues,
//! `SpaceTimeSched`, `AdaptiveController`, `SignalTracker`) driven round by
//! round over a virtual clock, wrapped as a
//! [`TicketRunner`](super::ticket::TicketRunner) so the
//! [`WorkerPool`](super::ticket::WorkerPool) can run N of them in parallel.
//!
//! The worker is a *pure function of its command stream*: every input that
//! could vary between runs — the round's virtual time, the arrivals to
//! admit, tenant queues migrating in or out, the rejoin reset — arrives in
//! the [`NodeCmd`]; the worker owns only queue/scheduler/controller state
//! and a per-lane `busy_until` horizon. Launch durations come from the
//! gpusim cost model (ground truth, same construction as the fig10/fig12
//! benches), so two runs fed identical command streams produce bitwise
//! identical [`NodeRoundResult`]s — the property the cluster journal's
//! replay check rests on. Times are carried as `f64` seconds relative to a
//! per-worker epoch; all `Instant` arithmetic is exact integer-nanosecond
//! math on top of that epoch, which cancels out of every comparison.

use std::time::{Duration, Instant};

use crate::coordinator::journal::{fnv1a64, FNV64_OFFSET};
use crate::coordinator::protocol::ProtoPayload;
use crate::coordinator::scheduler::SpaceTimeSched;
use crate::coordinator::{
    AdaptiveController, ControlSignals, ControllerParams, Decision, InferenceRequest, Priority,
    QueueSet, Scheduler, ShapeClass, SignalTracker,
};
use crate::gpusim::cost::{kernel_service_time, CostCtx};
use crate::gpusim::{DeviceSpec, GemmShape, KernelDesc};

use super::ticket::{TicketRunner, Ticketed};

/// One request admission, in committer coordinates: ids are assigned by
/// the committer (globally unique, stable across migrations) and times are
/// virtual seconds since the run epoch.
#[derive(Debug, Clone)]
pub struct ArrivalMsg {
    pub tenant: usize,
    pub id: u64,
    pub arr_s: f64,
}

/// A tenant's queued requests in flight between nodes (drained from the
/// source node's queue on migration, replayed into the destination's).
#[derive(Debug, Clone)]
pub struct TenantTransfer {
    pub tenant: usize,
    pub backlog: Vec<ArrivalMsg>,
}

/// One round's command to a node worker, stamped with its sequencer
/// ticket.
#[derive(Debug, Clone)]
pub struct NodeCmd {
    pub ticket: u64,
    pub round: u64,
    /// Virtual time of this round's start, seconds since the run epoch.
    pub now_s: f64,
    /// Rejoin after a failure: drop all queued state (the committer counts
    /// the drained requests as lost) and clear the lane horizon first.
    pub reset: bool,
    /// New arrivals to admit (tenants resident on this node only).
    pub arrivals: Vec<ArrivalMsg>,
    /// Tenant queues migrating IN (committed transfers routed here).
    pub add_tenants: Vec<TenantTransfer>,
    /// Tenants migrating OUT: drain their queues into
    /// [`NodeRoundResult::evicted`] before planning.
    pub drop_tenants: Vec<usize>,
    /// Work-stealing yield: after this round's admissions, surrender up to
    /// this many of the latest-deadline pending requests into
    /// [`NodeRoundResult::yielded`] (0 = no steal this round).
    pub yield_n: usize,
    /// Requests stolen FROM another node, delivered here by the committer.
    /// Admitted like arrivals, with their original arrival times, so
    /// latency keeps accruing across the move.
    pub steal_in: Vec<ArrivalMsg>,
}

impl ProtoPayload for NodeCmd {}

/// What one node did for one ticketed round.
#[derive(Debug, Clone)]
pub struct NodeRoundResult {
    pub ticket: u64,
    pub node: usize,
    pub round: u64,
    /// FNV-1a-64 over the round plan's launch composition (class, fused
    /// bucket, lane, entry ids) — the journal's per-round fingerprint.
    pub plan_digest: u64,
    /// Lane of each launch, parallel to the plan's launch order.
    pub lane_map: Vec<usize>,
    pub drained: usize,
    pub completed: u64,
    pub hits: u64,
    pub misses: u64,
    /// Requests lost to a rejoin reset (or shed at admission).
    pub dropped: u64,
    /// Pending requests left after planning.
    pub backlog: usize,
    /// Device-busy seconds this round's launches added.
    pub busy_s: f64,
    /// The controller's resident operating point after this round.
    pub decision: Decision,
    /// Cumulative controller reconfigurations on this node.
    pub reconfigs: u64,
    /// Tenant queues drained for migration this round.
    pub evicted: Vec<TenantTransfer>,
    /// Requests surrendered to the committer's work-stealing path this
    /// round ([`NodeCmd::yield_n`] victims, latest deadlines first out).
    pub yielded: Vec<ArrivalMsg>,
    /// Completion latencies (seconds) of requests finished this round.
    pub latencies: Vec<f64>,
}

impl ProtoPayload for NodeRoundResult {}

impl Ticketed for NodeRoundResult {
    fn ticket(&self) -> u64 {
        self.ticket
    }
}

/// The per-node stack: queues + scheduler + controller on a virtual clock.
pub struct NodeWorker {
    node: usize,
    spec: DeviceSpec,
    /// Global tenant table: `(shape class, slo_s)` per tenant id. Every
    /// node knows every tenant, so a migrated-in queue needs no metadata
    /// beyond its backlog.
    tenants: Vec<(ShapeClass, f64)>,
    min_slo_s: f64,
    sched: SpaceTimeSched,
    ctl: AdaptiveController,
    tracker: SignalTracker,
    queues: QueueSet,
    base: Instant,
    max_lanes: usize,
    lanes_now: usize,
    /// Per-lane busy-until horizon, virtual seconds. A launch starts at
    /// `max(now, busy[lane])`; the horizon persists across rounds so
    /// overload shows up as queueing delay instead of vanishing.
    busy: Vec<f64>,
    win_hits: u64,
    win_misses: u64,
    reconfigs_base: u64,
}

impl NodeWorker {
    pub fn new(
        node: usize,
        tenants: Vec<(ShapeClass, f64)>,
        max_lanes: usize,
        max_batch: usize,
        dwell_rounds: u32,
        base: Instant,
    ) -> Self {
        let mut sched = SpaceTimeSched::new(vec![1, 2, 4, 8, 16], max_batch)
            .spatial_lanes(1, None);
        sched.set_lanes(1);
        let ctl = AdaptiveController::new(
            ControllerParams {
                max_lanes: max_lanes.max(1),
                max_depth: 1, // the cluster replay models no pipeline
                dwell_rounds,
                improvement: 0.10,
                slo_target: 0.99,
            },
            Decision { lanes: 1, depth: 1 },
        );
        let queues = QueueSet::new(tenants.len(), 1 << 16);
        let min_slo_s =
            tenants.iter().map(|&(_, s)| s).fold(f64::INFINITY, f64::min);
        let min_slo_s = if min_slo_s.is_finite() { min_slo_s } else { 0.0 };
        Self {
            node,
            spec: DeviceSpec::v100(),
            tenants,
            min_slo_s,
            sched,
            ctl,
            tracker: SignalTracker::default(),
            queues,
            base,
            max_lanes: max_lanes.max(1),
            lanes_now: 1,
            busy: vec![0.0],
            win_hits: 0,
            win_misses: 0,
            reconfigs_base: 0,
        }
    }

    pub fn node(&self) -> usize {
        self.node
    }

    /// Admit one committer-issued arrival; returns false if admission shed
    /// it (bounded queues).
    fn admit(&mut self, a: &ArrivalMsg) -> bool {
        let (class, slo_s) = self.tenants[a.tenant];
        let arrived = self.base + Duration::from_secs_f64(a.arr_s);
        self.queues
            .push(InferenceRequest {
                id: a.id,
                tenant: a.tenant,
                class,
                payload: vec![],
                arrived,
                deadline: arrived + Duration::from_secs_f64(slo_s),
                priority: Priority::Normal,
                trace_id: 0,
            })
            .is_ok()
    }

    /// Execute one ticketed round: apply migrations, admit arrivals, run
    /// the controller at its dwell boundary, plan with the real scheduler,
    /// and price every launch with gpusim ground truth.
    pub fn run_round(&mut self, cmd: &NodeCmd) -> NodeRoundResult {
        let now = self.base + Duration::from_secs_f64(cmd.now_s);
        let mut dropped = 0u64;

        if cmd.reset {
            // Fail-stop rejoin: whatever the dead node still queued is
            // lost; report it so the committer's accounting stays exact.
            for t in 0..self.queues.n_tenants() {
                dropped += self.queues.drain_tenant(t).len() as u64;
            }
            for b in &mut self.busy {
                *b = cmd.now_s;
            }
            self.win_hits = 0;
            self.win_misses = 0;
        }

        let mut evicted = Vec::with_capacity(cmd.drop_tenants.len());
        for &t in &cmd.drop_tenants {
            let backlog: Vec<ArrivalMsg> = self
                .queues
                .drain_tenant(t)
                .iter()
                .map(|r| ArrivalMsg {
                    tenant: r.tenant,
                    id: r.id,
                    arr_s: r.arrived.duration_since(self.base).as_secs_f64(),
                })
                .collect();
            evicted.push(TenantTransfer { tenant: t, backlog });
        }
        for tr in &cmd.add_tenants {
            for a in &tr.backlog {
                if !self.admit(a) {
                    dropped += 1;
                }
            }
        }
        for a in &cmd.arrivals {
            if !self.admit(a) {
                dropped += 1;
            }
        }
        for a in &cmd.steal_in {
            if !self.admit(a) {
                dropped += 1;
            }
        }

        // Work-stealing yield, after every admission and before the
        // controller reads its signals: the backlog the controller (and
        // the committer's next steal decision) sees already excludes the
        // surrendered work.
        let yielded: Vec<ArrivalMsg> = if cmd.yield_n > 0 {
            self.queues
                .steal_latest(cmd.yield_n)
                .iter()
                .map(|r| ArrivalMsg {
                    tenant: r.tenant,
                    id: r.id,
                    arr_s: r.arrived.duration_since(self.base).as_secs_f64(),
                })
                .collect()
        } else {
            Vec::new()
        };

        // Controller dwell boundary — the same signal wiring as the
        // driver's `plan_control` (worker-side planning half).
        if self.ctl.tick() {
            let signals = ControlSignals {
                backlog: self.queues.total_pending(),
                arrival_rate: self.queues.arrival_rate(now),
                launches_per_round: self.tracker.launches_per_round(),
                requests_per_round: self.tracker.requests_per_round(),
                mean_launch_s: self.tracker.mean_launch_s(),
                plan_s: 0.0,
                stretch: self
                    .tracker
                    .stretch_table(self.max_lanes, |n| self.spec.lane_stretch(n as u32)),
                slo_attainment: if self.win_hits + self.win_misses > 0 {
                    Some(self.win_hits as f64 / (self.win_hits + self.win_misses) as f64)
                } else {
                    None
                },
                min_slo_s: self.min_slo_s,
                steal_rate: 0.0,
            };
            let decision = self.ctl.decide(&signals);
            self.win_hits = 0;
            self.win_misses = 0;
            if decision.lanes != self.lanes_now {
                self.lanes_now = decision.lanes;
                self.sched.set_lanes(decision.lanes);
            }
        }

        let plan = self.sched.plan_round_at(&mut self.queues, now);
        let active = plan.lanes_used().max(1);
        if self.busy.len() < plan.n_lanes.max(1) {
            self.busy.resize(plan.n_lanes.max(1), cmd.now_s);
        }

        let mut digest = FNV64_OFFSET;
        digest = fnv1a64(digest, &cmd.round.to_le_bytes());
        digest = fnv1a64(digest, &(self.node as u64).to_le_bytes());
        let mut lane_map = Vec::with_capacity(plan.launches.len());
        let mut busy_s = 0.0f64;
        let (mut completed, mut hits, mut misses) = (0u64, 0u64, 0u64);
        let mut latencies = Vec::new();
        for (i, launch) in plan.launches.iter().enumerate() {
            let lane = plan.lane(i).min(self.busy.len() - 1);
            lane_map.push(lane);
            let dur = ground_cost(&self.spec, launch.class, launch.r_bucket, active);
            let solo = ground_cost(&self.spec, launch.class, launch.r_bucket, 1);
            self.tracker.observe_launch(solo);
            if active > 1 {
                self.tracker.observe_stretch(active, dur / solo.max(1e-12));
            }
            let start = self.busy[lane].max(cmd.now_s);
            let done_s = start + dur;
            self.busy[lane] = done_s;
            busy_s += dur;
            digest = fnv1a64(digest, launch.class.kind.as_bytes());
            for v in [
                launch.class.m as u64,
                launch.class.n as u64,
                launch.class.k as u64,
                launch.r_bucket as u64,
                lane as u64,
            ] {
                digest = fnv1a64(digest, &v.to_le_bytes());
            }
            let done = self.base + Duration::from_secs_f64(done_s);
            for e in &launch.entries {
                digest = fnv1a64(digest, &e.id.to_le_bytes());
                completed += 1;
                latencies.push(done.duration_since(e.arrived).as_secs_f64());
                if done <= e.deadline {
                    hits += 1;
                    self.win_hits += 1;
                } else {
                    misses += 1;
                    self.win_misses += 1;
                }
            }
        }
        self.tracker.observe_round(plan.launches.len(), plan.drained, 0.0);

        NodeRoundResult {
            ticket: cmd.ticket,
            node: self.node,
            round: cmd.round,
            plan_digest: digest,
            lane_map,
            drained: plan.drained,
            completed,
            hits,
            misses,
            dropped,
            backlog: self.queues.total_pending(),
            busy_s,
            decision: Decision { lanes: self.lanes_now, depth: 1 },
            reconfigs: self.reconfigs_base + self.ctl.reconfigs(),
            evicted,
            yielded,
            latencies,
        }
    }
}

impl TicketRunner<NodeCmd, NodeRoundResult> for NodeWorker {
    fn run(&mut self, cmd: NodeCmd) -> NodeRoundResult {
        self.run_round(&cmd)
    }
}

/// gpusim ground truth for a fused launch of `r` problems of `class` with
/// `active` lanes concurrently resident (same construction as fig10/12).
fn ground_cost(spec: &DeviceSpec, class: ShapeClass, r: usize, active: usize) -> f64 {
    let shape =
        GemmShape::new(class.m.max(1) as u32, class.n.max(1) as u32, class.k.max(1) as u32);
    let mut merged = KernelDesc::sgemm(0, shape);
    let r = r.max(1);
    merged.flops *= r as f64;
    merged.bytes *= r as f64;
    merged.ctas = merged.ctas.saturating_mul(r as u32);
    merged.fused = r as u32;
    let active = active.max(1);
    spec.launch_overhead_s
        + kernel_service_time(
            spec,
            &merged,
            &CostCtx {
                sms: spec.sms as f64 / active as f64,
                concurrency: active as u32,
                static_bw_partition: false,
            },
        )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn worker(base: Instant) -> NodeWorker {
        let tenants: Vec<(ShapeClass, f64)> =
            (0..4).map(|t| (super::super::demo_class(t), 0.025)).collect();
        NodeWorker::new(0, tenants, 2, 16, 8, base)
    }

    fn cmd(ticket: u64, round: u64, now_s: f64, arrivals: Vec<ArrivalMsg>) -> NodeCmd {
        NodeCmd {
            ticket,
            round,
            now_s,
            reset: false,
            arrivals,
            add_tenants: vec![],
            drop_tenants: vec![],
            yield_n: 0,
            steal_in: vec![],
        }
    }

    #[test]
    fn identical_command_streams_produce_identical_results() {
        let run = |base: Instant| -> Vec<NodeRoundResult> {
            let mut w = worker(base);
            (0..6u64)
                .map(|r| {
                    let now_s = r as f64 * 0.002;
                    let arrivals = (0..3)
                        .map(|i| ArrivalMsg {
                            tenant: (i % 4) as usize,
                            id: r * 100 + i,
                            arr_s: now_s - 1e-4 * (i + 1) as f64,
                        })
                        .filter(|a| a.arr_s >= 0.0)
                        .collect();
                    w.run_round(&cmd(r, r, now_s, arrivals))
                })
                .collect()
        };
        // Different epochs: relative-time math must cancel the base out.
        let a = run(Instant::now());
        let b = run(Instant::now() + Duration::from_secs(7));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.plan_digest, y.plan_digest, "round {}", x.round);
            assert_eq!((x.hits, x.misses, x.completed), (y.hits, y.misses, y.completed));
            assert_eq!(x.lane_map, y.lane_map);
            assert_eq!(x.busy_s.to_bits(), y.busy_s.to_bits(), "busy_s diverged");
        }
    }

    #[test]
    fn drop_runs_before_admission_and_round_trips_through_a_transfer() {
        let base = Instant::now();
        // Node A: the drop drains tenant 1 BEFORE this round's arrivals
        // are admitted, so a same-round drop produces an empty transfer
        // and the arrivals are planned locally.
        let mut a = worker(base);
        let mut c = cmd(0, 0, 0.001, vec![]);
        c.arrivals = vec![
            ArrivalMsg { tenant: 1, id: 10, arr_s: 0.0005 },
            ArrivalMsg { tenant: 1, id: 11, arr_s: 0.0008 },
        ];
        c.drop_tenants = vec![1];
        let r0 = a.run_round(&c);
        assert_eq!(r0.evicted.len(), 1);
        assert_eq!(r0.evicted[0].tenant, 1);
        assert!(r0.evicted[0].backlog.is_empty(), "nothing was queued before round 0");
        assert_eq!(r0.completed, 2, "this round's arrivals were planned locally");

        // Node B: replaying a non-empty transfer admits and plans the
        // migrated backlog with its ORIGINAL arrival times (the latency
        // keeps accruing across the move).
        let mut b = worker(base);
        let mut c1 = cmd(0, 0, 0.010, vec![]);
        c1.add_tenants = vec![TenantTransfer {
            tenant: 2,
            backlog: vec![ArrivalMsg { tenant: 2, id: 20, arr_s: 0.0004 }],
        }];
        let r1 = b.run_round(&c1);
        assert_eq!(r1.completed, 1, "the migrated-in backlog was planned");
        assert!(
            r1.latencies[0] > 0.009,
            "latency must count from the original arrival: {}",
            r1.latencies[0]
        );
    }

    #[test]
    fn yield_surrenders_newest_work_and_round_trips_as_steal_in() {
        let base = Instant::now();
        // Victim: four same-SLO arrivals, told to yield two. The yield
        // runs after admission, so the two NEWEST arrivals (latest
        // deadlines) go and the two oldest are planned locally.
        let mut v = worker(base);
        let mut c = cmd(0, 0, 0.002, vec![]);
        c.arrivals = (0..4)
            .map(|i| ArrivalMsg { tenant: i % 4, id: 30 + i as u64, arr_s: 0.0002 * (i + 1) as f64 })
            .collect();
        c.yield_n = 2;
        let r = v.run_round(&c);
        assert_eq!(r.yielded.len(), 2);
        assert_eq!(
            r.yielded.iter().map(|a| a.id).collect::<Vec<_>>(),
            vec![32, 33],
            "the latest-deadline requests are the ones surrendered"
        );
        assert_eq!(r.completed, 2, "the urgent front stays and is planned");
        // The yielded arrival stamps survive the move exactly.
        assert!((r.yielded[0].arr_s - 0.0006).abs() < 1e-12);

        // Thief: the same messages delivered as `steal_in` plan with
        // their ORIGINAL arrival times — latency accrues across the move.
        let mut t = worker(base);
        let mut c1 = cmd(0, 0, 0.010, vec![]);
        c1.steal_in = r.yielded.clone();
        let r1 = t.run_round(&c1);
        assert_eq!(r1.completed, 2, "stolen work is planned by the thief");
        assert!(
            r1.latencies.iter().all(|&l| l > 0.009),
            "latency counts from the original arrivals: {:?}",
            r1.latencies
        );
    }

    #[test]
    fn reset_drops_queued_state_and_reports_it() {
        let base = Instant::now();
        let mut w = worker(base);
        // Seed a backlog by admitting arrivals, then reset in the next
        // round BEFORE planning can touch them: admit + drop_tenants in
        // the same round would plan them, so instead admit via a transfer
        // into a resetting round — reset precedes the transfer replay, so
        // the transfer survives and only pre-reset state is dropped.
        let mut c0 = cmd(0, 0, 0.002, vec![]);
        c0.arrivals = vec![ArrivalMsg { tenant: 0, id: 1, arr_s: 0.001 }];
        let r0 = w.run_round(&c0);
        assert_eq!(r0.completed, 1);
        let mut c1 = cmd(1, 1, 0.004, vec![]);
        c1.reset = true;
        c1.add_tenants =
            vec![TenantTransfer { tenant: 2, backlog: vec![ArrivalMsg { tenant: 2, id: 5, arr_s: 0.003 }] }];
        let r1 = w.run_round(&c1);
        assert_eq!(r1.dropped, 0, "queue was empty at reset");
        assert_eq!(r1.completed, 1, "the migrated-in backlog was planned");
    }
}
