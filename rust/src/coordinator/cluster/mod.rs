//! The cluster tier: sequencer → node workers → committer.
//!
//! The single-process coordinator schedules one device pool; this module
//! scales the same stack across N simulated nodes (in-process, no network
//! dependency) while keeping every cluster-level decision deterministic
//! and replayable:
//!
//! ```text
//!                 ┌────────────┐   round tickets (dense, monotonic)
//!                 │  Sequencer │──────────────┐
//!                 └────────────┘              ▼
//!   ┌─────────┐  NodeCmd   ┌──────────┐  NodeRoundResult  ┌───────────┐
//!   │committer│──per node──▶ N node   │───any order──────▶│ in-order  │
//!   │ (routes │            │ workers  │                   │ committer │
//!   │arrivals,│            │(scheduler│                   └─────┬─────┘
//!   │ owns    │            │+ctrl+EDF │        commits in ticket│order
//!   │ placer) │            │ queues)  │                         ▼
//!   └────▲────┘            └──────────┘                ┌────────────────┐
//!        └───────── placement / migration / faults ────│ decision journal│
//!                                                      └────────────────┘
//! ```
//!
//! * The **committer** owns everything global: the pre-generated arrival
//!   streams, the [`ClusterPlacer`], the hotspot detector, and the fault
//!   plan. Each round it issues one [`NodeCmd`] per live node carrying
//!   that node's admissions and queue migrations; node workers are pure
//!   functions of their command streams (see [`node`]).
//! * Results may arrive in any order but **commit strictly in ticket
//!   order** through [`InOrderCommitter`], each committed round appending
//!   one record to the decision [`Journal`]. Cluster events (migration,
//!   node down/up) append in a fixed order at the round boundary.
//! * **Hotspot migration**: per node, the committer tracks an offered-load
//!   EWMA (arrivals at issue time) and a predicted service-rate EWMA
//!   (completions per busy-second at commit time); when offered sustains
//!   above `migrate_util x service` for `migrate_sustain` committed
//!   rounds, the heaviest resident tenant migrates to the least-loaded
//!   other live node: the placer re-homes it immediately (new arrivals
//!   reroute), a drop command drains its queue from the source next
//!   round, and the evicted backlog is routed to its new home at commit.
//! * **Work stealing** (`steal = true`) rebalances *below* the migration
//!   threshold: once per round the committer compares committed backlogs
//!   and tells the most-loaded live node to yield half its lead over the
//!   least-loaded (capped at `steal_max`, only past `steal_gap`). The
//!   victim surrenders its latest-deadline requests — the back of its EDF
//!   order, the same end a lane thief takes — and the committer delivers
//!   them to the thief next round with their original arrival stamps.
//!   Tenants never move, so placement, dwell, and the migration detector
//!   are untouched; every decision is journaled as a `steal` record, and
//!   with stealing off the journal is byte-identical to pre-steal builds.
//! * **Failure/rejoin** is fail-stop: a killed node's resident tenants
//!   re-place onto live nodes (class affinity first), its queued requests
//!   are simply lost until rejoin, when the node's first command carries
//!   `reset` — the worker drains the stale state and reports it as
//!   dropped, so the committer's conservation accounting stays exact —
//!   and the displaced group re-homes through the readmit path.
//!
//! Because commands for round R are computed *before* any worker runs R
//! (snapshot semantics) and commit order equals issue order, the parallel
//! run ([`WorkerPool`] on OS threads) and the serial run (same workers
//! inline, ticket order) produce **bitwise identical journals** —
//! [`replay_journal`] re-executes a journal's header configuration
//! through the serial path and compares digests, which is what
//! `stgpu replay` and the CI replay smoke assert.

pub mod node;
pub mod ticket;

use std::collections::BTreeSet;
use std::time::Instant;

use crate::coordinator::journal::Journal;
use crate::coordinator::placement::ClusterPlacer;
use crate::coordinator::protocol::StdEnv;
use crate::coordinator::ShapeClass;
use crate::util::json::Json;
use crate::util::prng::Rng;

pub use node::{ArrivalMsg, NodeCmd, NodeRoundResult, NodeWorker, TenantTransfer};
pub use ticket::{InOrderCommitter, Sequencer, TicketRunner, Ticketed, WorkerPool};

/// The demo workload's shape class for tenant `t` (the fig10/fig12
/// batch-class mix, cycled). Used by [`ClusterOpts`]-driven runs and the
/// node-worker tests.
pub fn demo_class(t: usize) -> ShapeClass {
    const CLASSES: [ShapeClass; 4] = [
        ShapeClass { kind: "batched_gemm", m: 256, n: 128, k: 1152 },
        ShapeClass { kind: "batched_gemm", m: 128, n: 256, k: 1152 },
        ShapeClass { kind: "batched_gemm", m: 256, n: 128, k: 1024 },
        ShapeClass { kind: "batched_gemm", m: 128, n: 256, k: 1024 },
    ];
    CLASSES[t % CLASSES.len()]
}

/// A load spike: tenants initially resident on `node` arrive `factor`x
/// faster during rounds `[from_round, to_round)`.
#[derive(Debug, Clone, PartialEq)]
pub struct HotspotOpts {
    pub node: usize,
    pub from_round: u64,
    pub to_round: u64,
    pub factor: f64,
}

/// A fail-stop fault: `node` dies before round `kill_round` and rejoins
/// before round `rejoin_round`.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultOpts {
    pub node: usize,
    pub kill_round: u64,
    pub rejoin_round: u64,
}

/// Full configuration of a cluster run. Serialized into the journal's
/// header record, so a journal is self-contained for replay.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterOpts {
    pub nodes: usize,
    pub tenants_per_node: usize,
    pub rounds: u64,
    /// Virtual seconds per round (the lockstep tick).
    pub round_s: f64,
    pub seed: u64,
    /// Per-tenant base Poisson arrival rate, requests/second.
    pub rate_rps: f64,
    pub slo_s: f64,
    pub max_lanes: usize,
    pub max_batch: usize,
    pub dwell_rounds: u32,
    /// Hotspot threshold: a node is hot while its offered-load EWMA
    /// exceeds `migrate_util x` its predicted service rate.
    pub migrate_util: f64,
    /// Consecutive hot rounds before a migration fires.
    pub migrate_sustain: u32,
    /// Work-conserving cross-node stealing: queued requests (not tenants)
    /// move from the most- to the least-backlogged live node, below the
    /// migration threshold (see the module docs). Off by default.
    pub steal: bool,
    /// Minimum backlog gap (victim minus thief, requests) before a steal
    /// fires.
    pub steal_gap: usize,
    /// Upper bound on requests moved per steal decision.
    pub steal_max: usize,
    pub hotspot: Option<HotspotOpts>,
    pub fault: Option<FaultOpts>,
}

impl ClusterOpts {
    /// A small, comfortably-under-SLO demo configuration.
    pub fn demo(nodes: usize) -> Self {
        Self {
            nodes,
            tenants_per_node: 4,
            rounds: 240,
            round_s: 0.0025,
            seed: 42,
            rate_rps: 40.0,
            slo_s: 0.025,
            max_lanes: 2,
            max_batch: 16,
            dwell_rounds: 8,
            migrate_util: 0.9,
            migrate_sustain: 3,
            steal: false,
            steal_gap: 8,
            steal_max: 32,
            hotspot: None,
            fault: None,
        }
    }

    pub fn n_tenants(&self) -> usize {
        self.nodes * self.tenants_per_node
    }

    /// Arrivals are generated strictly before the last round's start, so
    /// every generated request is delivered by the final round.
    pub fn horizon_s(&self) -> f64 {
        self.rounds.saturating_sub(1) as f64 * self.round_s
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.nodes < 1 || self.nodes > 64 {
            return Err(format!("nodes must be in [1, 64], got {}", self.nodes));
        }
        if self.tenants_per_node < 1 {
            return Err("tenants_per_node must be >= 1".into());
        }
        if self.rounds < 2 {
            return Err("rounds must be >= 2".into());
        }
        if !(self.round_s > 0.0) {
            return Err("round_s must be > 0".into());
        }
        if !(self.rate_rps > 0.0) {
            return Err("rate_rps must be > 0".into());
        }
        if !(self.slo_s > 0.0) {
            return Err("slo_s must be > 0".into());
        }
        if self.max_lanes < 1 || self.max_batch < 1 || self.dwell_rounds < 1 {
            return Err("max_lanes, max_batch, dwell_rounds must be >= 1".into());
        }
        if !(self.migrate_util > 0.0) {
            return Err("migrate_util must be > 0".into());
        }
        if self.migrate_sustain < 1 {
            return Err("migrate_sustain must be >= 1".into());
        }
        if self.steal && (self.steal_gap < 1 || self.steal_max < 1) {
            return Err("steal_gap and steal_max must be >= 1 when steal is on".into());
        }
        if let Some(h) = &self.hotspot {
            if h.node >= self.nodes {
                return Err(format!("hotspot.node {} out of range", h.node));
            }
            if h.from_round >= h.to_round || !(h.factor > 0.0) {
                return Err("hotspot window/factor invalid".into());
            }
        }
        if let Some(f) = &self.fault {
            if f.node >= self.nodes {
                return Err(format!("fault.node {} out of range", f.node));
            }
            if self.nodes < 2 {
                return Err("fault requires >= 2 nodes".into());
            }
            if f.kill_round < 1 || f.kill_round >= f.rejoin_round || f.rejoin_round > self.rounds {
                return Err(format!(
                    "fault rounds invalid: need 1 <= kill ({}) < rejoin ({}) <= rounds ({})",
                    f.kill_round, f.rejoin_round, self.rounds
                ));
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let hotspot = match &self.hotspot {
            Some(h) => Json::obj(vec![
                ("node", Json::num(h.node as f64)),
                ("from_round", Json::num(h.from_round as f64)),
                ("to_round", Json::num(h.to_round as f64)),
                ("factor", Json::num(h.factor)),
            ]),
            None => Json::Null,
        };
        let fault = match &self.fault {
            Some(f) => Json::obj(vec![
                ("node", Json::num(f.node as f64)),
                ("kill_round", Json::num(f.kill_round as f64)),
                ("rejoin_round", Json::num(f.rejoin_round as f64)),
            ]),
            None => Json::Null,
        };
        let mut fields = vec![
            ("nodes", Json::num(self.nodes as f64)),
            ("tenants_per_node", Json::num(self.tenants_per_node as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("round_s", Json::num(self.round_s)),
            ("seed", Json::num(self.seed as f64)),
            ("rate_rps", Json::num(self.rate_rps)),
            ("slo_s", Json::num(self.slo_s)),
            ("max_lanes", Json::num(self.max_lanes as f64)),
            ("max_batch", Json::num(self.max_batch as f64)),
            ("dwell_rounds", Json::num(self.dwell_rounds as f64)),
            ("migrate_util", Json::num(self.migrate_util)),
            ("migrate_sustain", Json::num(self.migrate_sustain as f64)),
            ("hotspot", hotspot),
            ("fault", fault),
        ];
        // Steal knobs are emitted only when stealing is on: a steal-off
        // header is byte-identical to one written before the feature
        // existed, so journals recorded by older builds still replay.
        if self.steal {
            fields.push(("steal", Json::Bool(true)));
            fields.push(("steal_gap", Json::num(self.steal_gap as f64)));
            fields.push(("steal_max", Json::num(self.steal_max as f64)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<ClusterOpts, String> {
        fn num(j: &Json, k: &str) -> Result<f64, String> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("cluster opts: missing numeric field '{k}'"))
        }
        let hotspot = match j.get("hotspot") {
            Some(h @ Json::Obj(_)) => Some(HotspotOpts {
                node: num(h, "node")? as usize,
                from_round: num(h, "from_round")? as u64,
                to_round: num(h, "to_round")? as u64,
                factor: num(h, "factor")?,
            }),
            _ => None,
        };
        let fault = match j.get("fault") {
            Some(f @ Json::Obj(_)) => Some(FaultOpts {
                node: num(f, "node")? as usize,
                kill_round: num(f, "kill_round")? as u64,
                rejoin_round: num(f, "rejoin_round")? as u64,
            }),
            _ => None,
        };
        let opts = ClusterOpts {
            nodes: num(j, "nodes")? as usize,
            tenants_per_node: num(j, "tenants_per_node")? as usize,
            rounds: num(j, "rounds")? as u64,
            round_s: num(j, "round_s")?,
            seed: num(j, "seed")? as u64,
            rate_rps: num(j, "rate_rps")?,
            slo_s: num(j, "slo_s")?,
            max_lanes: num(j, "max_lanes")? as usize,
            max_batch: num(j, "max_batch")? as usize,
            dwell_rounds: num(j, "dwell_rounds")? as u32,
            migrate_util: num(j, "migrate_util")?,
            migrate_sustain: num(j, "migrate_sustain")? as u32,
            // Absent in pre-steal journals: default off, demo knobs.
            steal: j.get("steal").and_then(Json::as_bool).unwrap_or(false),
            steal_gap: j.get("steal_gap").and_then(Json::as_usize).unwrap_or(8),
            steal_max: j.get("steal_max").and_then(Json::as_usize).unwrap_or(32),
            hotspot,
            fault,
        };
        opts.validate()?;
        Ok(opts)
    }
}

/// Aggregate counters for one committed round across all nodes.
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundStats {
    pub round: u64,
    pub offered: u64,
    pub completed: u64,
    pub hits: u64,
    pub misses: u64,
    pub dropped: u64,
}

/// Per-node totals across the run.
#[derive(Debug, Clone, Default)]
pub struct NodeSummary {
    pub node: usize,
    pub offered: u64,
    pub completed: u64,
    pub hits: u64,
    pub misses: u64,
    pub dropped: u64,
    /// Backlog after the node's last committed round.
    pub backlog: u64,
    pub busy_s: f64,
    pub reconfigs: u64,
    pub rounds: u64,
}

impl NodeSummary {
    /// Snapshot shape consumed by `server::status::aggregate_nodes`.
    pub fn to_json(&self) -> Json {
        let att = if self.completed > 0 {
            self.hits as f64 / self.completed as f64
        } else {
            1.0
        };
        Json::obj(vec![
            ("node", Json::num(self.node as f64)),
            ("offered", Json::num(self.offered as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("hits", Json::num(self.hits as f64)),
            ("misses", Json::num(self.misses as f64)),
            ("dropped", Json::num(self.dropped as f64)),
            ("backlog", Json::num(self.backlog as f64)),
            ("busy_s", Json::num(self.busy_s)),
            ("reconfigs", Json::num(self.reconfigs as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("slo_attainment", Json::num(att)),
        ])
    }
}

/// The outcome of a cluster run: the journal plus enough statistics for
/// the scale-out bench and the CLI to report without re-parsing it.
#[derive(Debug)]
pub struct ClusterReport {
    pub opts: ClusterOpts,
    pub journal: Journal,
    pub rounds: Vec<RoundStats>,
    pub nodes: Vec<NodeSummary>,
    pub offered: u64,
    pub completed: u64,
    pub hits: u64,
    pub misses: u64,
    pub dropped: u64,
    pub migrations: u64,
    pub node_downs: u64,
    pub node_ups: u64,
    /// Cross-node steal decisions fired (0 unless `opts.steal`).
    pub steals: u64,
    /// Requests moved by those steals.
    pub stolen_requests: u64,
    pub backlog_end: u64,
    pub in_transfer_end: u64,
}

impl ClusterReport {
    /// Fraction of completed requests that met their deadline.
    pub fn attainment(&self) -> f64 {
        if self.completed > 0 {
            self.hits as f64 / self.completed as f64
        } else {
            1.0
        }
    }

    /// SLO-met goodput over the whole run, requests/second.
    pub fn goodput_rps(&self) -> f64 {
        let dur = self.opts.rounds as f64 * self.opts.round_s;
        if dur > 0.0 {
            self.hits as f64 / dur
        } else {
            0.0
        }
    }

    /// Every offered request is accounted for: completed, dropped, still
    /// queued, or mid-transfer.
    pub fn conservation_ok(&self) -> bool {
        self.offered == self.completed + self.dropped + self.backlog_end + self.in_transfer_end
    }

    pub fn node_json(&self) -> Vec<Json> {
        self.nodes.iter().map(NodeSummary::to_json).collect()
    }
}

/// Committer-side state of a cluster run: arrival streams, placement,
/// hotspot/fault machinery, statistics, and the journal.
pub struct ClusterSim {
    opts: ClusterOpts,
    placer: ClusterPlacer<ShapeClass>,
    seq: Sequencer,
    journal: Journal,
    /// Pre-generated per-tenant arrival times (virtual seconds, sorted).
    arrivals: Vec<Vec<f64>>,
    cursor: Vec<usize>,
    /// Per-node staging for the NEXT issued command.
    pending_add: Vec<Vec<TenantTransfer>>,
    pending_drop: Vec<Vec<usize>>,
    pending_reset: Vec<bool>,
    /// Tenants with a migration decided but the backlog not yet delivered
    /// (guards against re-migrating a tenant mid-move).
    in_flight: BTreeSet<usize>,
    /// Work-stealing staging: how many requests each node must yield in
    /// its NEXT command, where each victim's surrendered requests go, and
    /// stolen requests committed but not yet delivered to the thief.
    /// Deliberately separate from `pending_add`/`in_flight`: stealing
    /// moves requests, never tenants, so it must not touch the migration
    /// machinery.
    pending_yield: Vec<usize>,
    steal_dst: Vec<usize>,
    pending_steal_add: Vec<Vec<ArrivalMsg>>,
    steals: u64,
    stolen_requests: u64,
    /// Tenants displaced by the current fault, for rejoin re-homing.
    displaced: Vec<usize>,
    offered_ewma: Vec<f64>,
    service_rps: Vec<f64>,
    hot_rounds: Vec<u32>,
    round_stats: Vec<RoundStats>,
    node_stats: Vec<NodeSummary>,
    migrations: u64,
    node_downs: u64,
    node_ups: u64,
    offered_total: u64,
}

/// Offered-load / service-rate EWMA smoothing.
const EWMA_ALPHA: f64 = 0.3;

impl ClusterSim {
    pub fn new(opts: ClusterOpts) -> Result<Self, String> {
        opts.validate()?;
        let n = opts.n_tenants();
        let tenants: Vec<(ShapeClass, f64)> = (0..n).map(|t| (demo_class(t), 1.0)).collect();
        let placer = ClusterPlacer::new(&tenants, opts.nodes);

        // Hotspot targets are the tenants INITIALLY resident on the hot
        // node — a deterministic function of the opts, so the arrival
        // streams are too.
        let hot_tenants: BTreeSet<usize> = match &opts.hotspot {
            Some(h) => placer.tenants_on(h.node).into_iter().collect(),
            None => BTreeSet::new(),
        };
        let horizon = opts.horizon_s();
        let mut arrivals = Vec::with_capacity(n);
        for t in 0..n {
            let mut rng =
                Rng::new(opts.seed ^ (t as u64 + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15));
            let mut ts = Vec::new();
            let mut now = 0.0f64;
            loop {
                let boosted = match &opts.hotspot {
                    Some(h) if hot_tenants.contains(&t) => {
                        let (from, to) =
                            (h.from_round as f64 * opts.round_s, h.to_round as f64 * opts.round_s);
                        now >= from && now < to
                    }
                    _ => false,
                };
                let rate = if boosted { opts.rate_rps * opts.hotspot.as_ref().unwrap().factor } else { opts.rate_rps };
                now += rng.gen_exp(rate);
                if now >= horizon {
                    break;
                }
                ts.push(now);
            }
            arrivals.push(ts);
        }

        let mut journal = Journal::new();
        journal.append(Json::obj(vec![
            ("kind", Json::str("header")),
            ("version", Json::num(1)),
            ("opts", opts.to_json()),
        ]));

        let nodes = opts.nodes;
        let rounds = opts.rounds as usize;
        Ok(Self {
            placer,
            seq: Sequencer::new(),
            journal,
            arrivals,
            cursor: vec![0; n],
            pending_add: vec![Vec::new(); nodes],
            pending_drop: vec![Vec::new(); nodes],
            pending_reset: vec![false; nodes],
            in_flight: BTreeSet::new(),
            pending_yield: vec![0; nodes],
            steal_dst: vec![0; nodes],
            pending_steal_add: vec![Vec::new(); nodes],
            steals: 0,
            stolen_requests: 0,
            displaced: Vec::new(),
            offered_ewma: vec![0.0; nodes],
            service_rps: vec![0.0; nodes],
            hot_rounds: vec![0; nodes],
            round_stats: (0..rounds)
                .map(|r| RoundStats { round: r as u64, ..RoundStats::default() })
                .collect(),
            node_stats: (0..nodes)
                .map(|d| NodeSummary { node: d, ..NodeSummary::default() })
                .collect(),
            migrations: 0,
            node_downs: 0,
            node_ups: 0,
            offered_total: 0,
            opts,
        })
    }

    /// Issue round `round`'s commands, one per live node in ascending node
    /// order (== ticket order). All commands are computed before any
    /// worker runs — snapshot semantics, identical for the parallel and
    /// serial paths.
    // lint: pure
    pub fn issue_round(&mut self, round: u64) -> Vec<(usize, NodeCmd)> {
        let now_s = round as f64 * self.opts.round_s;
        let mut cmds = Vec::new();
        for node in 0..self.opts.nodes {
            if !self.placer.is_live(node) {
                continue;
            }
            let ticket = self.seq.issue();
            let reset = std::mem::take(&mut self.pending_reset[node]);
            let add_tenants = std::mem::take(&mut self.pending_add[node]);
            let drop_tenants = std::mem::take(&mut self.pending_drop[node]);
            // Delivery completes a migration: the tenant may move again.
            for tr in &add_tenants {
                self.in_flight.remove(&tr.tenant);
            }
            let mut arrivals = Vec::new();
            for t in self.placer.tenants_on(node) {
                while self.cursor[t] < self.arrivals[t].len()
                    && self.arrivals[t][self.cursor[t]] <= now_s
                {
                    let k = self.cursor[t];
                    arrivals.push(ArrivalMsg {
                        tenant: t,
                        id: ((t as u64) << 32) | k as u64,
                        arr_s: self.arrivals[t][k],
                    });
                    self.cursor[t] += 1;
                }
            }
            let inst = arrivals.len() as f64 / self.opts.round_s;
            self.offered_ewma[node] =
                EWMA_ALPHA * inst + (1.0 - EWMA_ALPHA) * self.offered_ewma[node];
            let n_arr = arrivals.len() as u64;
            self.offered_total += n_arr;
            self.round_stats[round as usize].offered += n_arr;
            self.node_stats[node].offered += n_arr;
            let yield_n = std::mem::take(&mut self.pending_yield[node]);
            let steal_in = std::mem::take(&mut self.pending_steal_add[node]);
            cmds.push((
                node,
                NodeCmd {
                    ticket,
                    round,
                    now_s,
                    reset,
                    arrivals,
                    add_tenants,
                    drop_tenants,
                    yield_n,
                    steal_in,
                },
            ));
        }
        cmds
    }

    /// Apply one committed result: append its journal record, fold its
    /// counters into the statistics, update the node's service-rate
    /// estimate, and route evicted tenant queues to their current homes.
    /// MUST be called in ticket order (the in-order committer guarantees
    /// it on the parallel path).
    // lint: pure
    pub fn apply_committed(&mut self, r: &NodeRoundResult) {
        self.journal.append(Json::obj(vec![
            ("kind", Json::str("round")),
            ("ticket", Json::num(r.ticket as f64)),
            ("round", Json::num(r.round as f64)),
            ("node", Json::num(r.node as f64)),
            ("plan", Json::str(format!("{:016x}", r.plan_digest))),
            ("lanes", Json::num(r.decision.lanes as f64)),
            ("depth", Json::num(r.decision.depth as f64)),
            (
                "lane_map",
                Json::Arr(r.lane_map.iter().map(|&l| Json::num(l as f64)).collect()),
            ),
            ("reconfigs", Json::num(r.reconfigs as f64)),
            ("launches", Json::num(r.lane_map.len() as f64)),
            ("drained", Json::num(r.drained as f64)),
            ("completed", Json::num(r.completed as f64)),
            ("hits", Json::num(r.hits as f64)),
            ("misses", Json::num(r.misses as f64)),
            ("dropped", Json::num(r.dropped as f64)),
            ("backlog", Json::num(r.backlog as f64)),
        ]));

        let rs = &mut self.round_stats[r.round as usize];
        rs.completed += r.completed;
        rs.hits += r.hits;
        rs.misses += r.misses;
        rs.dropped += r.dropped;

        let ns = &mut self.node_stats[r.node];
        ns.completed += r.completed;
        ns.hits += r.hits;
        ns.misses += r.misses;
        ns.dropped += r.dropped;
        ns.backlog = r.backlog as u64;
        ns.busy_s += r.busy_s;
        ns.reconfigs = r.reconfigs;
        ns.rounds += 1;

        if r.busy_s > 1e-9 {
            let inst = r.completed as f64 / r.busy_s;
            self.service_rps[r.node] = if self.service_rps[r.node] <= 0.0 {
                inst
            } else {
                EWMA_ALPHA * inst + (1.0 - EWMA_ALPHA) * self.service_rps[r.node]
            };
        }

        // Route evicted queues to the tenant's CURRENT home (the placer
        // moved it when the migration was decided). Empty transfers are
        // routed too: delivery is what completes the migration.
        for tr in &r.evicted {
            let dst = self.placer.node_of(tr.tenant);
            self.pending_add[dst].push(tr.clone());
        }

        // Route stolen requests to the thief chosen when the steal was
        // decided — or, if it died in the meantime, to each request's
        // tenant's current home.
        for a in &r.yielded {
            let chosen = self.steal_dst[r.node];
            let dst = if self.placer.is_live(chosen) {
                chosen
            } else {
                self.placer.node_of(a.tenant)
            };
            self.pending_steal_add[dst].push(a.clone());
            self.stolen_requests += 1;
        }
    }

    /// Round boundary, after every result of `round` has committed:
    /// hotspot detection/migration, then the work-stealing decision, then
    /// fault events, each journaled in a fixed deterministic order
    /// (migrations ascending by source node, then steal, then node_down,
    /// then node_up).
    // lint: pure
    pub fn end_round(&mut self, round: u64) {
        // Hotspot detection per live node, ascending.
        for node in 0..self.opts.nodes {
            if !self.placer.is_live(node) {
                continue;
            }
            let hot = self.service_rps[node] > 0.0
                && self.offered_ewma[node] > self.opts.migrate_util * self.service_rps[node];
            if hot {
                self.hot_rounds[node] += 1;
            } else {
                self.hot_rounds[node] = 0;
            }
            if self.hot_rounds[node] < self.opts.migrate_sustain {
                continue;
            }
            let movable: Vec<usize> = self
                .placer
                .tenants_on(node)
                .into_iter()
                .filter(|t| !self.in_flight.contains(t))
                .collect();
            let dst = (0..self.opts.nodes)
                .filter(|&d| d != node && self.placer.is_live(d))
                .min_by(|&a, &b| {
                    self.placer
                        .load_of(a)
                        .partial_cmp(&self.placer.load_of(b))
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(&b))
                });
            let (Some(dst), true) = (dst, movable.len() >= 2) else {
                continue;
            };
            // Heaviest movable tenant; ties break to the lowest id.
            let mut pick = movable[0];
            for &t in &movable[1..] {
                if self.placer.weight_of(t) > self.placer.weight_of(pick) {
                    pick = t;
                }
            }
            self.placer.migrate(pick, dst);
            self.pending_drop[node].push(pick);
            self.in_flight.insert(pick);
            self.hot_rounds[node] = 0;
            self.migrations += 1;
            self.journal.append(Json::obj(vec![
                ("kind", Json::str("migrate")),
                ("round", Json::num(round as f64)),
                ("tenant", Json::num(pick as f64)),
                ("from", Json::num(node as f64)),
                ("to", Json::num(dst as f64)),
            ]));
        }

        // Work stealing below the migration threshold: queued requests
        // (not tenants) move from the most- to the least-backlogged live
        // node. One decision per round, taken from the same committed
        // backlogs both the serial and parallel paths see, so the journal
        // stays bitwise replayable. Runs after migration has had its
        // chance: a sustained hotspot re-homes a tenant, a brief or small
        // imbalance is absorbed here without churning placement.
        if self.opts.steal {
            let mut victim = usize::MAX;
            let mut thief = usize::MAX;
            for node in 0..self.opts.nodes {
                if !self.placer.is_live(node) {
                    continue;
                }
                let b = self.node_stats[node].backlog;
                if victim == usize::MAX || b > self.node_stats[victim].backlog {
                    victim = node;
                }
                if thief == usize::MAX || b < self.node_stats[thief].backlog {
                    thief = node;
                }
            }
            if victim != usize::MAX && thief != victim && self.pending_yield[victim] == 0 {
                let gap = (self.node_stats[victim].backlog - self.node_stats[thief].backlog)
                    as usize;
                if gap >= self.opts.steal_gap {
                    // Move half the gap (never past the cap): enough to
                    // close the imbalance without ping-ponging work back
                    // next round.
                    let n = (gap / 2).clamp(1, self.opts.steal_max);
                    self.pending_yield[victim] = n;
                    self.steal_dst[victim] = thief;
                    self.steals += 1;
                    self.journal.append(Json::obj(vec![
                        ("kind", Json::str("steal")),
                        ("round", Json::num(round as f64)),
                        ("from", Json::num(victim as f64)),
                        ("to", Json::num(thief as f64)),
                        ("n", Json::num(n as f64)),
                    ]));
                }
            }
        }

        let Some(f) = self.opts.fault.clone() else {
            return;
        };
        if round + 1 == f.kill_round && self.placer.is_live(f.node) {
            let moves = self.placer.set_down(f.node);
            self.displaced = moves.iter().map(|&(t, _)| t).collect();
            // Transfers staged for the dead node re-route to the tenants'
            // new homes; drop commands it will never run are cancelled
            // (the backlog they would have drained is lost — the rejoin
            // reset counts it).
            let stranded = std::mem::take(&mut self.pending_add[f.node]);
            for tr in stranded {
                let dst = self.placer.node_of(tr.tenant);
                self.pending_add[dst].push(tr);
            }
            for t in std::mem::take(&mut self.pending_drop[f.node]) {
                self.in_flight.remove(&t);
            }
            // Stolen requests staged for the dead thief re-route to their
            // tenants' current homes; a staged yield the victim will never
            // run is cancelled (its queue is lost to the reset anyway).
            for a in std::mem::take(&mut self.pending_steal_add[f.node]) {
                let dst = self.placer.node_of(a.tenant);
                self.pending_steal_add[dst].push(a);
            }
            self.pending_yield[f.node] = 0;
            self.offered_ewma[f.node] = 0.0;
            self.service_rps[f.node] = 0.0;
            self.hot_rounds[f.node] = 0;
            self.node_downs += 1;
            self.journal.append(Json::obj(vec![
                ("kind", Json::str("node_down")),
                ("round", Json::num(round as f64)),
                ("node", Json::num(f.node as f64)),
                (
                    "replaced",
                    Json::Arr(
                        moves
                            .iter()
                            .map(|&(t, to)| {
                                Json::Arr(vec![Json::num(t as f64), Json::num(to as f64)])
                            })
                            .collect(),
                    ),
                ),
            ]));
        }
        if round + 1 == f.rejoin_round && !self.placer.is_live(f.node) {
            self.placer.set_up(f.node);
            self.pending_reset[f.node] = true;
            let group: Vec<usize> = std::mem::take(&mut self.displaced)
                .into_iter()
                .filter(|t| !self.in_flight.contains(t))
                .collect();
            let returned: Vec<(usize, usize, usize)> = self
                .placer
                .rehome_group(&group)
                .into_iter()
                .filter(|&(_, from, to)| from != to)
                .collect();
            for &(t, from, _) in &returned {
                self.pending_drop[from].push(t);
                self.in_flight.insert(t);
            }
            self.node_ups += 1;
            self.journal.append(Json::obj(vec![
                ("kind", Json::str("node_up")),
                ("round", Json::num(round as f64)),
                ("node", Json::num(f.node as f64)),
                (
                    "returned",
                    Json::Arr(
                        returned
                            .iter()
                            .map(|&(t, from, _)| {
                                Json::Arr(vec![Json::num(t as f64), Json::num(from as f64)])
                            })
                            .collect(),
                    ),
                ),
            ]));
        }
    }

    /// Append the summary record and produce the report.
    pub fn finish(mut self) -> ClusterReport {
        let offered = self.offered_total;
        let completed: u64 = self.node_stats.iter().map(|n| n.completed).sum();
        let hits: u64 = self.node_stats.iter().map(|n| n.hits).sum();
        let misses: u64 = self.node_stats.iter().map(|n| n.misses).sum();
        let dropped: u64 = self.node_stats.iter().map(|n| n.dropped).sum();
        let backlog_end: u64 = self.node_stats.iter().map(|n| n.backlog).sum();
        let in_transfer_end: u64 = self
            .pending_add
            .iter()
            .flatten()
            .map(|tr| tr.backlog.len() as u64)
            .sum::<u64>()
            + self.pending_steal_add.iter().map(|v| v.len() as u64).sum::<u64>();
        let mut summary = vec![
            ("kind", Json::str("summary")),
            ("rounds", Json::num(self.opts.rounds as f64)),
            ("offered", Json::num(offered as f64)),
            ("completed", Json::num(completed as f64)),
            ("hits", Json::num(hits as f64)),
            ("misses", Json::num(misses as f64)),
            ("dropped", Json::num(dropped as f64)),
            ("migrations", Json::num(self.migrations as f64)),
            ("node_downs", Json::num(self.node_downs as f64)),
            ("node_ups", Json::num(self.node_ups as f64)),
            ("backlog", Json::num(backlog_end as f64)),
            ("in_transfer", Json::num(in_transfer_end as f64)),
        ];
        // Same compatibility rule as the header: steal-off summaries are
        // byte-identical to pre-steal builds.
        if self.opts.steal {
            summary.push(("steals", Json::num(self.steals as f64)));
            summary.push(("stolen", Json::num(self.stolen_requests as f64)));
        }
        self.journal.append(Json::obj(summary));
        ClusterReport {
            opts: self.opts,
            journal: self.journal,
            rounds: self.round_stats,
            nodes: self.node_stats,
            offered,
            completed,
            hits,
            misses,
            dropped,
            migrations: self.migrations,
            node_downs: self.node_downs,
            node_ups: self.node_ups,
            steals: self.steals,
            stolen_requests: self.stolen_requests,
            backlog_end,
            in_transfer_end,
        }
    }
}

/// Run a full cluster simulation. `parallel` runs one OS thread per node
/// behind the [`WorkerPool`]; otherwise the same workers run inline in
/// ticket order. Both paths produce bitwise identical journals.
pub fn run_cluster(opts: &ClusterOpts, parallel: bool) -> Result<ClusterReport, String> {
    let mut sim = ClusterSim::new(opts.clone())?;
    let tenants: Vec<(ShapeClass, f64)> =
        (0..opts.n_tenants()).map(|t| (demo_class(t), opts.slo_s)).collect();
    let base = Instant::now();
    let make = |node: usize| {
        NodeWorker::new(node, tenants.clone(), opts.max_lanes, opts.max_batch, opts.dwell_rounds, base)
    };
    if parallel {
        let workers: Vec<NodeWorker> = (0..opts.nodes).map(make).collect();
        let mut pool: WorkerPool<StdEnv, NodeCmd, NodeRoundResult> = WorkerPool::spawn(workers);
        let mut com: InOrderCommitter<NodeRoundResult> = InOrderCommitter::new();
        for round in 0..opts.rounds {
            let cmds = sim.issue_round(round);
            let expect = cmds.len();
            for (node, cmd) in cmds {
                if !pool.send(node, cmd) {
                    return Err(format!("node {node} worker is gone"));
                }
            }
            for _ in 0..expect {
                let res = pool.recv().ok_or("worker pool died mid-round")?;
                for (_, r) in com.offer(res.ticket(), res) {
                    sim.apply_committed(&r);
                }
            }
            sim.end_round(round);
        }
        pool.shutdown();
    } else {
        let mut workers: Vec<NodeWorker> = (0..opts.nodes).map(make).collect();
        for round in 0..opts.rounds {
            // Snapshot semantics: ALL commands are computed before any
            // worker runs, exactly as on the parallel path.
            for (node, cmd) in sim.issue_round(round) {
                let res = workers[node].run_round(&cmd);
                sim.apply_committed(&res);
            }
            sim.end_round(round);
        }
    }
    Ok(sim.finish())
}

/// What [`replay_journal`] found.
#[derive(Debug)]
pub struct ReplayOutcome {
    pub rounds: u64,
    pub nodes: usize,
    pub original: String,
    pub replayed: String,
    pub matches: bool,
}

/// Re-execute a journal's header configuration through the serial path
/// and compare digests. A match proves the journal's parallel producer
/// committed exactly the serial (sequencer-order) decision sequence.
// lint: pure
pub fn replay_journal(journal: &Journal) -> Result<ReplayOutcome, String> {
    let header = journal.records().first().ok_or("empty journal")?;
    if header.get("kind").and_then(Json::as_str) != Some("header") {
        return Err("first record is not a header".into());
    }
    let opts_json = header.get("opts").ok_or("header record has no 'opts'")?;
    let opts = ClusterOpts::from_json(opts_json)?;
    let report = run_cluster(&opts, false)?;
    Ok(ReplayOutcome {
        rounds: opts.rounds,
        nodes: opts.nodes,
        original: format!("{:016x}", journal.digest()),
        replayed: report.journal.digest_hex(),
        matches: journal.digest() == report.journal.digest()
            && journal.bytes().len() == report.journal.bytes().len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small(nodes: usize) -> ClusterOpts {
        ClusterOpts { rounds: 60, ..ClusterOpts::demo(nodes) }
    }

    fn kinds(j: &Journal) -> Vec<String> {
        j.records()
            .iter()
            .filter_map(|r| r.get("kind").and_then(Json::as_str).map(str::to_string))
            .collect()
    }

    #[test]
    fn opts_round_trip_through_json() {
        let mut o = small(3);
        o.hotspot =
            Some(HotspotOpts { node: 1, from_round: 10, to_round: 30, factor: 6.5 });
        o.fault = Some(FaultOpts { node: 2, kill_round: 20, rejoin_round: 40 });
        o.steal = true;
        o.steal_gap = 5;
        o.steal_max = 10;
        let back = ClusterOpts::from_json(&o.to_json()).expect("parse");
        assert_eq!(back, o);
        // And the header emission is stable across the round trip.
        assert_eq!(back.to_json().to_string(), o.to_json().to_string());
    }

    #[test]
    fn steal_off_header_is_byte_identical_to_the_legacy_shape() {
        // The serialized opts of a steal-off run must not mention stealing
        // at all: journals written before the feature existed parse AND
        // re-serialize to the same bytes, so `stgpu replay` still matches
        // them digest-for-digest.
        let o = small(2);
        let j = o.to_json().to_string();
        assert!(!j.contains("steal"), "steal-off header leaks steal knobs: {j}");
        let back = ClusterOpts::from_json(&o.to_json()).expect("parse");
        assert!(!back.steal);
        assert_eq!(back.to_json().to_string(), j);
    }

    #[test]
    fn validation_rejects_bad_opts() {
        let mut o = small(2);
        o.rounds = 1;
        assert!(o.validate().unwrap_err().contains("rounds"));
        let mut o = small(1);
        o.fault = Some(FaultOpts { node: 0, kill_round: 5, rejoin_round: 10 });
        assert!(o.validate().unwrap_err().contains(">= 2 nodes"));
        let mut o = small(2);
        o.fault = Some(FaultOpts { node: 0, kill_round: 10, rejoin_round: 5 });
        assert!(o.validate().is_err());
    }

    #[test]
    fn parallel_and_serial_journals_are_bitwise_identical() {
        let opts = small(2);
        let par = run_cluster(&opts, true).expect("parallel");
        let ser = run_cluster(&opts, false).expect("serial");
        assert!(par.completed > 0, "work happened");
        assert_eq!(par.journal.digest_hex(), ser.journal.digest_hex());
        assert_eq!(par.journal.bytes(), ser.journal.bytes());
    }

    #[test]
    fn replay_matches_a_parallel_run() {
        let opts = small(2);
        let par = run_cluster(&opts, true).expect("parallel");
        let out = replay_journal(&par.journal).expect("replay");
        assert!(out.matches, "original {} vs replayed {}", out.original, out.replayed);
        assert_eq!(out.nodes, 2);
    }

    #[test]
    fn sustained_hotspot_triggers_a_journaled_migration() {
        let mut opts = small(2);
        // Make every busy round look overloaded so the detector must
        // fire: any positive offered EWMA beats util * service.
        opts.migrate_util = 1e-9;
        opts.migrate_sustain = 2;
        let rep = run_cluster(&opts, false).expect("run");
        assert!(rep.migrations >= 1, "no migration fired");
        assert!(kinds(&rep.journal).iter().any(|k| k == "migrate"));
        assert!(rep.conservation_ok(), "requests leaked across migration");
    }

    #[test]
    fn kill_and_rejoin_are_journaled_and_conserve_requests() {
        let mut opts = small(3);
        opts.fault = Some(FaultOpts { node: 0, kill_round: 20, rejoin_round: 40 });
        let rep = run_cluster(&opts, true).expect("run");
        assert_eq!((rep.node_downs, rep.node_ups), (1, 1));
        let ks = kinds(&rep.journal);
        assert!(ks.iter().any(|k| k == "node_down"));
        assert!(ks.iter().any(|k| k == "node_up"));
        assert!(
            rep.conservation_ok(),
            "offered {} != completed {} + dropped {} + backlog {} + transfer {}",
            rep.offered,
            rep.completed,
            rep.dropped,
            rep.backlog_end,
            rep.in_transfer_end
        );
        // The dead node planned nothing during the outage: it committed
        // fewer rounds than the survivors.
        assert!(rep.nodes[0].rounds < rep.nodes[1].rounds);
        // Replay reproduces the faulted run bit for bit too.
        assert!(replay_journal(&rep.journal).expect("replay").matches);
    }

    /// A four-node run with one node hammered hard enough that its
    /// round-capped scheduler cannot drain the spike, while the migration
    /// detector is disabled — stealing is the only rebalancer.
    fn steal_opts() -> ClusterOpts {
        ClusterOpts {
            rounds: 80,
            steal: true,
            steal_gap: 4,
            steal_max: 16,
            migrate_util: 1e9,
            hotspot: Some(HotspotOpts { node: 0, from_round: 5, to_round: 70, factor: 60.0 }),
            ..ClusterOpts::demo(4)
        }
    }

    #[test]
    fn stealing_fires_and_replays_bitwise_on_four_nodes() {
        let opts = steal_opts();
        let par = run_cluster(&opts, true).expect("parallel");
        assert!(par.steals >= 1, "overload never triggered a steal");
        assert!(par.stolen_requests >= 1, "steals moved no requests");
        assert!(kinds(&par.journal).iter().any(|k| k == "steal"));
        assert!(
            par.conservation_ok(),
            "requests leaked across steals: offered {} != completed {} + dropped {} \
             + backlog {} + transfer {}",
            par.offered,
            par.completed,
            par.dropped,
            par.backlog_end,
            par.in_transfer_end
        );
        // Thieves did real work: some node other than the hot one
        // completed more than its own offered load... at minimum, the
        // journal must replay bitwise through the serial path, parallel
        // and serial runs byte-equal.
        let ser = run_cluster(&opts, false).expect("serial");
        assert_eq!(par.journal.bytes(), ser.journal.bytes());
        let out = replay_journal(&par.journal).expect("replay");
        assert!(out.matches, "original {} vs replayed {}", out.original, out.replayed);
        assert_eq!(out.nodes, 4);
    }

    #[test]
    fn stealing_beats_no_stealing_on_goodput_under_the_same_spike() {
        let on = run_cluster(&steal_opts(), false).expect("steal on");
        let off = run_cluster(
            &ClusterOpts { steal: false, ..steal_opts() },
            false,
        )
        .expect("steal off");
        assert_eq!(off.steals, 0);
        assert!(!kinds(&off.journal).iter().any(|k| k == "steal"));
        assert!(
            on.hits > off.hits,
            "work-conserving stealing should lift SLO-met goodput: on {} vs off {}",
            on.hits,
            off.hits
        );
    }

    #[test]
    fn every_round_commits_exactly_the_live_nodes() {
        let mut opts = small(2);
        opts.rounds = 30;
        let rep = run_cluster(&opts, false).expect("run");
        let round_records = rep
            .journal
            .records()
            .iter()
            .filter(|r| r.get("kind").and_then(Json::as_str) == Some("round"))
            .count() as u64;
        assert_eq!(round_records, 30 * 2);
        assert!(rep.conservation_ok());
        // Ticket order in the journal is strictly increasing.
        let tickets: Vec<u64> = rep
            .journal
            .records()
            .iter()
            .filter(|r| r.get("kind").and_then(Json::as_str) == Some("round"))
            .map(|r| r.get("ticket").and_then(Json::as_f64).unwrap() as u64)
            .collect();
        assert!(tickets.windows(2).all(|w| w[1] == w[0] + 1), "tickets not dense");
    }
}
