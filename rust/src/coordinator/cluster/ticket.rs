//! The sequencer → workers → committer ticket protocol.
//!
//! Three pieces, kept deliberately tiny so the whole protocol fits under
//! the model checker ([`crate::util::modelcheck`], exercised by
//! `tests/modelcheck_cluster.rs`):
//!
//! * [`Sequencer`] — hands out globally monotonically increasing round
//!   tickets. A ticket is the cluster's only ordering primitive: results
//!   may *arrive* in any order, but they *commit* in ticket order.
//! * [`WorkerPool`] — N stateful node workers, one SPSC command queue
//!   each, one shared MPSC results channel. Generic over
//!   [`SyncEnv`](crate::coordinator::protocol::SyncEnv), so the SAME code
//!   runs on OS threads in production (`StdEnv`) and under the
//!   schedule-exhaustive model environment in tests (`ModelEnv`). Unlike
//!   [`LaneProtocol`](crate::coordinator::protocol::LaneProtocol) — whose
//!   lanes share one stateless `ItemRunner` — each worker here OWNS its
//!   runner: a node worker is a whole scheduler/controller/queue stack and
//!   must mutate it across rounds.
//! * [`InOrderCommitter`] — the reorder buffer between the results channel
//!   and the journal: results are offered as they arrive and released
//!   strictly in ticket order, with no ticket skipped, duplicated, or
//!   committed before all of its predecessors.

use std::collections::BTreeMap;

use crate::coordinator::protocol::{ProtoJoin, ProtoPayload, ProtoReceiver, ProtoSender, SyncEnv};

/// A result that knows which ticket produced it.
pub trait Ticketed {
    fn ticket(&self) -> u64;
}

/// Issues globally monotonically increasing round tickets.
#[derive(Default)]
pub struct Sequencer {
    next: u64,
}

impl Sequencer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Take the next ticket. Tickets are dense: every issued ticket must
    /// eventually be offered to the committer or the round stalls.
    // lint: pure
    pub fn issue(&mut self) -> u64 {
        let t = self.next;
        self.next += 1;
        t
    }

    /// Tickets issued so far (the next ticket to be handed out).
    pub fn issued(&self) -> u64 {
        self.next
    }
}

/// Reorder buffer releasing results strictly in ticket order.
pub struct InOrderCommitter<T> {
    next: u64,
    pending: BTreeMap<u64, T>,
}

impl<T> Default for InOrderCommitter<T> {
    fn default() -> Self {
        Self { next: 0, pending: BTreeMap::new() }
    }
}

impl<T> InOrderCommitter<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// The ticket the next commit is waiting on.
    pub fn next_ticket(&self) -> u64 {
        self.next
    }

    /// Results buffered behind a missing predecessor.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Offer one out-of-order result; returns every `(ticket, result)` that
    /// became committable, in ticket order (empty while a predecessor is
    /// still outstanding). Panics on a duplicated or already-committed
    /// ticket — both are protocol violations, not recoverable conditions.
    // lint: pure
    pub fn offer(&mut self, ticket: u64, result: T) -> Vec<(u64, T)> {
        assert!(ticket >= self.next, "ticket {ticket} was already committed");
        let dup = self.pending.insert(ticket, result);
        assert!(dup.is_none(), "ticket {ticket} offered twice");
        let mut out = Vec::new();
        while let Some(r) = self.pending.remove(&self.next) {
            out.push((self.next, r));
            self.next += 1;
        }
        out
    }
}

/// What a node worker runs per command. Owned (`&mut self`) — a node's
/// scheduler/controller/queue state persists across rounds.
pub trait TicketRunner<W, R>: Send + 'static {
    fn run(&mut self, cmd: W) -> R;
}

/// N stateful workers behind SPSC command queues and one shared results
/// channel. `send` targets a worker; `recv` surfaces results in arrival
/// (NOT ticket) order — feed them through an [`InOrderCommitter`].
pub struct WorkerPool<E: SyncEnv, W: ProtoPayload, R: ProtoPayload> {
    /// `None` == that worker's queue is closed (shutdown).
    cmd_txs: Vec<Option<E::Sender<W>>>,
    results: E::Receiver<R>,
    workers: Vec<E::Join>,
}

impl<E: SyncEnv, W: ProtoPayload, R: ProtoPayload> WorkerPool<E, W, R> {
    /// Spawn one worker per runner. The pool keeps NO clone of the results
    /// sender: once every worker exits (all command queues closed and
    /// drained), `recv` returns `None`.
    pub fn spawn<S: TicketRunner<W, R>>(runners: Vec<S>) -> Self {
        let (done_tx, done_rx) = E::channel::<R>();
        let mut cmd_txs = Vec::with_capacity(runners.len());
        let mut workers = Vec::with_capacity(runners.len());
        for (node, mut runner) in runners.into_iter().enumerate() {
            let (tx, rx) = E::channel::<W>();
            let done = done_tx.clone();
            workers.push(E::spawn(format!("stgpu-node-{node}"), move || {
                while let Some(cmd) = rx.recv() {
                    let res = runner.run(cmd);
                    if done.send(res).is_err() {
                        return; // committer gone: nobody to report to
                    }
                }
            }));
            cmd_txs.push(Some(tx));
        }
        drop(done_tx);
        Self { cmd_txs, results: done_rx, workers }
    }

    pub fn n_workers(&self) -> usize {
        self.cmd_txs.len()
    }

    /// Queue one command on `worker`'s SPSC queue. `false` if that worker
    /// was already shut down.
    pub fn send(&self, worker: usize, cmd: W) -> bool {
        match &self.cmd_txs[worker] {
            Some(tx) => tx.send(cmd).is_ok(),
            None => false,
        }
    }

    /// Block for the next result from any worker; `None` once every worker
    /// has exited.
    pub fn recv(&mut self) -> Option<R> {
        self.results.recv()
    }

    /// Close every command queue and join every worker. Workers drain what
    /// is already queued before exiting (the `while let` in their loop),
    /// so no accepted command is abandoned.
    pub fn shutdown(&mut self) {
        for tx in &mut self.cmd_txs {
            *tx = None;
        }
        for w in self.workers.drain(..) {
            w.join();
        }
    }
}

impl<E: SyncEnv, W: ProtoPayload, R: ProtoPayload> Drop for WorkerPool<E, W, R> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::StdEnv;

    #[test]
    fn sequencer_is_dense_and_monotonic() {
        let mut s = Sequencer::new();
        assert_eq!((s.issue(), s.issue(), s.issue()), (0, 1, 2));
        assert_eq!(s.issued(), 3);
    }

    #[test]
    fn committer_releases_strictly_in_ticket_order() {
        let mut c = InOrderCommitter::new();
        assert!(c.offer(2, "c").is_empty());
        assert!(c.offer(1, "b").is_empty());
        assert_eq!(c.pending(), 2);
        let out = c.offer(0, "a");
        assert_eq!(out, vec![(0, "a"), (1, "b"), (2, "c")]);
        assert_eq!(c.pending(), 0);
        assert_eq!(c.next_ticket(), 3);
        assert_eq!(c.offer(3, "d"), vec![(3, "d")]);
    }

    #[test]
    #[should_panic(expected = "offered twice")]
    fn committer_panics_on_a_duplicated_ticket() {
        let mut c = InOrderCommitter::new();
        let _ = c.offer(5, ());
        let _ = c.offer(5, ());
    }

    #[test]
    #[should_panic(expected = "already committed")]
    fn committer_panics_on_a_stale_ticket() {
        let mut c = InOrderCommitter::new();
        let _ = c.offer(0, ());
        let _ = c.offer(0, ());
    }

    struct Cmd {
        ticket: u64,
        x: u64,
    }
    impl ProtoPayload for Cmd {}

    struct Res {
        ticket: u64,
        node: usize,
        x: u64,
    }
    impl ProtoPayload for Res {}
    impl Ticketed for Res {
        fn ticket(&self) -> u64 {
            self.ticket
        }
    }

    /// A stateful runner: proves the pool supports per-worker owned state.
    struct Acc {
        node: usize,
        sum: u64,
    }
    impl TicketRunner<Cmd, Res> for Acc {
        fn run(&mut self, cmd: Cmd) -> Res {
            self.sum += cmd.x;
            Res { ticket: cmd.ticket, node: self.node, x: self.sum }
        }
    }

    #[test]
    fn std_pool_round_trips_and_commits_in_ticket_order() {
        let mut pool: WorkerPool<StdEnv, Cmd, Res> =
            WorkerPool::spawn(vec![Acc { node: 0, sum: 0 }, Acc { node: 1, sum: 0 }]);
        let mut seq = Sequencer::new();
        let mut com = InOrderCommitter::new();
        let mut committed: Vec<u64> = Vec::new();
        for round in 0..3u64 {
            for node in 0..2 {
                let t = seq.issue();
                assert!(pool.send(node, Cmd { ticket: t, x: round + 1 }));
            }
            for _ in 0..2 {
                let r = pool.recv().expect("workers alive");
                assert!(r.node < 2 && r.x > 0);
                for (t, _) in com.offer(r.ticket(), r) {
                    assert_eq!(t, committed.len() as u64, "commit out of ticket order");
                    committed.push(t);
                }
            }
        }
        assert_eq!(committed, (0..6).collect::<Vec<_>>());
        assert_eq!(com.pending(), 0);
        pool.shutdown();
        assert!(pool.recv().is_none(), "results channel closes after shutdown");
        assert!(!pool.send(0, Cmd { ticket: 99, x: 0 }), "closed queue refuses sends");
    }
}
