//! Bounded admission front: per-tenant earliest-deadline-first queues with
//! a per-tenant depth cap and a global cap across the whole set.
//!
//! Each tenant's queue is ordered by absolute request deadline (a binary
//! heap keyed by `(deadline, priority rank, seq)`): `pop`/`peek` always
//! surface the most urgent pending request. The deadline is the one the
//! request's [`crate::coordinator::request::RequestContext`] resolved —
//! wire-supplied when the client sent one, the tenant SLO only as the
//! explicit default — so the heap orders by what the client asked for,
//! not by a config constant. Priority breaks deadline ties
//! (`High < Normal < Batch`); insertion sequence breaks the rest, so for
//! same-priority traffic of one tenant (deadlines ascend with arrival
//! order) the EDF order degenerates to FIFO for the paper's §3 baselines
//! exactly as before.
//!
//! The paper's §2 model saturates queues; the per-tenant bound keeps an
//! overloaded or evicted tenant from consuming unbounded memory, and the
//! global cap (DARIS-style admission control, arXiv:2504.08795) makes the
//! coordinator shed load with an explicit [`Reject`] outcome — a 429-style
//! signal the frontend surfaces — instead of letting latency grow without
//! bound under oversubscription. A saturated front rejects; it never grows.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::Instant;

use crate::coordinator::request::{InferenceRequest, Reject};

/// Exponentially-decaying arrival-rate estimator over the *offered* load
/// (admitted, depth-rejected AND cap-shed requests all count — shedding is
/// precisely when a controller most needs to know the demand it is not
/// serving). Each observation blends the instantaneous rate `1/dt` with
/// weight `1 - exp(-dt/tau)`; reading the rate applies the idle decay
/// since the last event, so a burst-then-silence workload reports a rate
/// that dies off instead of freezing at the burst's peak — the latent gap
/// this estimator closes (previously shed events updated no estimate at
/// all, so a fully-shedding front looked idle).
#[derive(Debug)]
pub struct ArrivalRate {
    rate: f64,
    last: Option<Instant>,
    tau_s: f64,
}

impl ArrivalRate {
    /// `tau_s` is the decay time constant (seconds): the horizon over
    /// which old arrivals stop mattering.
    pub fn new(tau_s: f64) -> Self {
        assert!(tau_s > 0.0);
        Self { rate: 0.0, last: None, tau_s }
    }

    /// Account one arrival at `now`. Out-of-order timestamps are treated
    /// as simultaneous (saturating), contributing negligible weight.
    pub fn observe(&mut self, now: Instant) {
        match self.last {
            None => self.last = Some(now),
            Some(prev) => {
                let dt = now.saturating_duration_since(prev).as_secs_f64().max(1e-9);
                let alpha = 1.0 - (-dt / self.tau_s).exp();
                self.rate = alpha * (1.0 / dt) + (1.0 - alpha) * self.rate;
                if now > prev {
                    self.last = Some(now);
                }
            }
        }
    }

    /// The rate estimate at `now`, req/s — decayed for the idle time since
    /// the last arrival (0.0 before any arrival interval).
    pub fn rate_at(&self, now: Instant) -> f64 {
        match self.last {
            None => 0.0,
            Some(prev) => {
                let idle = now.saturating_duration_since(prev).as_secs_f64();
                self.rate * (-idle / self.tau_s).exp()
            }
        }
    }
}

/// Heap entry: min-heap by `(deadline, priority rank, seq)` via reversed
/// `Ord`. `rank` is the request's [`Priority`] tie-break rank (0 most
/// urgent); `seq` is a per-queue insertion counter, so equal
/// deadline+priority pops in FIFO order.
#[derive(Debug)]
struct EdfEntry {
    deadline: Instant,
    rank: u8,
    seq: u64,
    req: InferenceRequest,
}

impl PartialEq for EdfEntry {
    fn eq(&self, other: &Self) -> bool {
        self.deadline == other.deadline && self.rank == other.rank && self.seq == other.seq
    }
}

impl Eq for EdfEntry {}

impl PartialOrd for EdfEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for EdfEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want the earliest deadline
        // (then the most urgent priority, then the lowest seq) on top.
        other
            .deadline
            .cmp(&self.deadline)
            .then_with(|| other.rank.cmp(&self.rank))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// A bounded earliest-deadline-first queue of pending requests for one
/// tenant (FIFO among equal deadlines — see the module docs).
#[derive(Debug)]
pub struct TenantQueue {
    items: BinaryHeap<EdfEntry>,
    next_seq: u64,
    depth: usize,
    /// Lifetime counters for metrics/backpressure analysis.
    pub enqueued: u64,
    pub rejected: u64,
}

impl TenantQueue {
    pub fn new(depth: usize) -> Self {
        assert!(depth >= 1);
        Self {
            items: BinaryHeap::with_capacity(depth.min(1024)),
            next_seq: 0,
            depth,
            enqueued: 0,
            rejected: 0,
        }
    }

    pub fn push(&mut self, req: InferenceRequest) -> Result<(), Reject> {
        if self.items.len() >= self.depth {
            self.rejected += 1;
            return Err(Reject::QueueFull);
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.items
            .push(EdfEntry { deadline: req.deadline, rank: req.priority.rank(), seq, req });
        self.enqueued += 1;
        Ok(())
    }

    /// Pop the earliest-deadline request (FIFO among equal deadlines).
    pub fn pop(&mut self) -> Option<InferenceRequest> {
        self.items.pop().map(|e| e.req)
    }

    /// The earliest-deadline request without removing it.
    pub fn peek(&self) -> Option<&InferenceRequest> {
        self.items.peek().map(|e| &e.req)
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Drop everything (tenant eviction); returns the drained requests in
    /// deadline order so the caller can complete them with
    /// `Reject::TenantEvicted`.
    pub fn drain(&mut self) -> Vec<InferenceRequest> {
        let mut out = Vec::with_capacity(self.items.len());
        while let Some(e) = self.items.pop() {
            out.push(e.req);
        }
        out
    }

    /// Re-insert a request previously removed from this queue (the
    /// work-stealing keep-side re-queue): identical to `push` except the
    /// lifetime `enqueued` counter does not advance, so admission metrics
    /// count each request exactly once.
    fn restore(&mut self, req: InferenceRequest) -> Result<(), Reject> {
        if self.items.len() >= self.depth {
            return Err(Reject::QueueFull);
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.items
            .push(EdfEntry { deadline: req.deadline, rank: req.priority.rank(), seq, req });
        Ok(())
    }
}

/// All tenants' queues; index == tenant id. Admission enforces the
/// per-tenant depth AND a global cap across the set.
///
/// NB: in the sharded coordinator the pool-wide cap spans several
/// `QueueSet`s, so the driver performs the cap check itself and records
/// sheds here via [`QueueSet::record_shed`] — per-shard sets are built
/// effectively unbounded. A standalone single-front deployment uses
/// [`QueueSet::with_global_cap`] directly and gets the same behaviour
/// from `push`.
#[derive(Debug)]
pub struct QueueSet {
    queues: Vec<TenantQueue>,
    depth: usize,
    /// Global cap on total pending requests across all tenants.
    global_cap: usize,
    /// Total pending across all tenant queues, maintained incrementally so
    /// the admission check is O(1) (every dequeue goes through
    /// `pop_tenant`/`drain_tenant`).
    pending: usize,
    /// Requests shed because the global cap was hit (load-shed counter,
    /// distinct from per-tenant `rejected`).
    pub shed: u64,
    /// Offered-load estimator (admitted + rejected + shed) feeding the
    /// adaptive controller's demand signal.
    arrivals: ArrivalRate,
}

/// Arrival-rate decay horizon: long enough to smooth round-to-round
/// jitter, short enough that the controller sees a phase shift within a
/// couple of dwell windows.
const ARRIVAL_TAU_S: f64 = 0.1;

impl QueueSet {
    pub fn new(n_tenants: usize, depth: usize) -> Self {
        Self::with_global_cap(n_tenants, depth, usize::MAX)
    }

    /// A bounded admission front: per-tenant `depth` plus `global_cap`
    /// total pending across all tenants.
    pub fn with_global_cap(n_tenants: usize, depth: usize, global_cap: usize) -> Self {
        assert!(global_cap >= 1);
        Self {
            queues: (0..n_tenants).map(|_| TenantQueue::new(depth)).collect(),
            depth,
            global_cap,
            pending: 0,
            shed: 0,
            arrivals: ArrivalRate::new(ARRIVAL_TAU_S),
        }
    }

    pub fn global_cap(&self) -> usize {
        self.global_cap
    }

    /// Count one request shed by an external admission check (the sharded
    /// coordinator's pool-wide cap) so `shed` stays truthful regardless of
    /// which layer enforced the bound.
    pub fn record_shed(&mut self) {
        self.record_shed_at(Instant::now());
    }

    /// [`QueueSet::record_shed`] with an explicit timestamp: the shed
    /// request still counts toward the offered-load rate estimate — a
    /// front shedding 100% of its arrivals is overloaded, not idle.
    pub fn record_shed_at(&mut self, now: Instant) {
        self.shed += 1;
        self.arrivals.observe(now);
    }

    /// Feed the offered-load estimator one arrival that never reached
    /// `push` (e.g. requests rejected upstream at admission, like the
    /// EDF feasibility shed).
    pub fn note_arrival(&mut self, now: Instant) {
        self.arrivals.observe(now);
    }

    /// Offered-load EWMA at `now`, req/s (decays while idle). Covers every
    /// arrival seen by `push`, `record_shed_at`, and `note_arrival`.
    pub fn arrival_rate(&self, now: Instant) -> f64 {
        self.arrivals.rate_at(now)
    }

    /// Add a queue for a late-registered tenant; returns its index.
    pub fn add_tenant(&mut self) -> usize {
        self.queues.push(TenantQueue::new(self.depth));
        self.queues.len() - 1
    }

    pub fn push(&mut self, req: InferenceRequest) -> Result<(), Reject> {
        let t = req.tenant;
        if t >= self.queues.len() {
            return Err(Reject::BadRequest(format!("unknown tenant {t}")));
        }
        // Offered load counts whatever the admission outcome is (the
        // request's own arrival stamp keeps simulated-clock replays and
        // tests deterministic).
        self.arrivals.observe(req.arrived);
        if self.pending >= self.global_cap {
            self.shed += 1;
            return Err(Reject::Overloaded);
        }
        let res = self.queues[t].push(req);
        if res.is_ok() {
            self.pending += 1;
        }
        res
    }

    pub fn tenant(&self, id: usize) -> Option<&TenantQueue> {
        self.queues.get(id)
    }

    /// Pop the head of one tenant's queue (None when empty/unknown).
    /// All dequeueing goes through here so `pending` stays exact.
    pub fn pop_tenant(&mut self, id: usize) -> Option<InferenceRequest> {
        let r = self.queues.get_mut(id)?.pop();
        if r.is_some() {
            self.pending -= 1;
        }
        r
    }

    /// Drop everything a tenant has queued (eviction); returns the drained
    /// requests so the caller can fail them crisply.
    pub fn drain_tenant(&mut self, id: usize) -> Vec<InferenceRequest> {
        let drained = self
            .queues
            .get_mut(id)
            .map(TenantQueue::drain)
            .unwrap_or_default();
        self.pending -= drained.len();
        drained
    }

    /// Yield up to `n` pending requests for a cross-node steal. The
    /// victims are the **latest-deadline** requests across all tenants —
    /// the back of the global EDF order, mirroring the lane deque's
    /// back-of-queue steal — so this front keeps exactly the work it was
    /// about to run and surrenders the work with the most slack left to
    /// survive a move. Ties on deadline break by tenant id, then by each
    /// tenant's own EDF insertion order, so the selection is fully
    /// deterministic. Returns the stolen requests in deadline order.
    ///
    /// This is a dequeue path like `pop_tenant`/`drain_tenant`: `pending`
    /// stays exact. It runs at most once per cluster round on a steal
    /// victim, never on the per-request hot path, so the drain-and-restore
    /// pass is deliberately simple.
    pub fn steal_latest(&mut self, n: usize) -> Vec<InferenceRequest> {
        if n == 0 || self.pending == 0 {
            return Vec::new();
        }
        let mut all: Vec<InferenceRequest> = Vec::with_capacity(self.pending);
        for q in &mut self.queues {
            all.append(&mut q.drain());
        }
        // Stable sort: within a tenant, `drain` already yields EDF order.
        all.sort_by(|a, b| a.deadline.cmp(&b.deadline).then(a.tenant.cmp(&b.tenant)));
        let stolen = all.split_off(all.len().saturating_sub(n));
        self.pending = all.len();
        for r in all {
            let t = r.tenant;
            self.queues[t]
                .restore(r)
                .expect("re-queueing drained requests cannot exceed depth");
        }
        stolen
    }

    pub fn n_tenants(&self) -> usize {
        self.queues.len()
    }

    pub fn total_pending(&self) -> usize {
        debug_assert_eq!(
            self.pending,
            self.queues.iter().map(TenantQueue::len).sum::<usize>()
        );
        self.pending
    }

    pub fn is_empty(&self) -> bool {
        self.pending == 0
    }

    /// Tenants with at least one pending request, ascending id.
    pub fn backlogged(&self) -> Vec<usize> {
        let mut out = Vec::new();
        self.backlogged_into(&mut out);
        out
    }

    /// [`QueueSet::backlogged`] into a recycled buffer — the schedulers
    /// call this once per drain pass, so reusing the caller's scratch
    /// keeps the round hot path allocation-free.
    // lint: hot-path
    // lint: pure
    pub fn backlogged_into(&self, out: &mut Vec<usize>) {
        out.clear();
        out.extend((0..self.queues.len()).filter(|&i| !self.queues[i].is_empty()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Priority, ShapeClass};
    use std::time::Instant;

    fn req(id: u64, tenant: usize) -> InferenceRequest {
        InferenceRequest {
            id,
            tenant,
            class: ShapeClass::batched_gemm(8, 8, 8),
            payload: vec![],
            arrived: Instant::now(),
            deadline: Instant::now(),
            priority: Priority::Normal,
            trace_id: 0,
        }
    }

    #[test]
    fn priority_breaks_deadline_ties_then_fifo() {
        let now = Instant::now();
        let deadline = now + std::time::Duration::from_millis(10);
        let at = |id: u64, priority: Priority| InferenceRequest {
            id,
            tenant: 0,
            class: ShapeClass::batched_gemm(8, 8, 8),
            payload: vec![],
            arrived: now,
            deadline,
            priority,
            trace_id: 0,
        };
        let mut q = TenantQueue::new(8);
        q.push(at(1, Priority::Batch)).unwrap();
        q.push(at(2, Priority::Normal)).unwrap();
        q.push(at(3, Priority::High)).unwrap();
        q.push(at(4, Priority::High)).unwrap();
        // Equal deadlines: High first (FIFO within High), Batch last.
        assert_eq!(q.pop().unwrap().id, 3);
        assert_eq!(q.pop().unwrap().id, 4);
        assert_eq!(q.pop().unwrap().id, 2);
        assert_eq!(q.pop().unwrap().id, 1);
        // An earlier deadline still beats a higher priority.
        let mut q = TenantQueue::new(8);
        let mut early = at(5, Priority::Batch);
        early.deadline = now + std::time::Duration::from_millis(1);
        q.push(early).unwrap();
        q.push(at(6, Priority::High)).unwrap();
        assert_eq!(q.pop().unwrap().id, 5, "deadline remains the primary EDF key");
    }

    #[test]
    fn fifo_order() {
        let mut q = TenantQueue::new(4);
        q.push(req(1, 0)).unwrap();
        q.push(req(2, 0)).unwrap();
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 2);
        assert!(q.pop().is_none());
    }

    #[test]
    fn backpressure_at_depth() {
        let mut q = TenantQueue::new(2);
        q.push(req(1, 0)).unwrap();
        q.push(req(2, 0)).unwrap();
        assert_eq!(q.push(req(3, 0)), Err(Reject::QueueFull));
        assert_eq!(q.rejected, 1);
        assert_eq!(q.enqueued, 2);
        // Popping frees a slot.
        q.pop();
        assert!(q.push(req(3, 0)).is_ok());
    }

    #[test]
    fn drain_empties() {
        let mut q = TenantQueue::new(8);
        for i in 0..5 {
            q.push(req(i, 0)).unwrap();
        }
        let drained = q.drain();
        assert_eq!(drained.len(), 5);
        assert!(q.is_empty());
    }

    #[test]
    fn queue_set_routes_by_tenant() {
        let mut qs = QueueSet::new(3, 4);
        qs.push(req(1, 0)).unwrap();
        qs.push(req(2, 2)).unwrap();
        assert_eq!(qs.tenant(0).unwrap().len(), 1);
        assert_eq!(qs.tenant(1).unwrap().len(), 0);
        assert_eq!(qs.tenant(2).unwrap().len(), 1);
        assert_eq!(qs.total_pending(), 2);
        assert_eq!(qs.backlogged(), vec![0, 2]);
        assert!(matches!(qs.push(req(3, 9)), Err(Reject::BadRequest(_))));
    }

    #[test]
    fn global_cap_sheds_with_explicit_outcome() {
        // 4 tenants x depth 8 would admit 32, but the global cap is 5:
        // request #6 onward is shed with `Overloaded`, and pending never
        // exceeds the cap (bounded admission, not unbounded growth).
        let mut qs = QueueSet::with_global_cap(4, 8, 5);
        let mut admitted = 0;
        let mut shed = 0;
        for i in 0..20u64 {
            match qs.push(req(i, (i % 4) as usize)) {
                Ok(()) => admitted += 1,
                Err(Reject::Overloaded) => shed += 1,
                Err(e) => panic!("unexpected rejection {e:?}"),
            }
            assert!(qs.total_pending() <= 5, "cap violated");
        }
        assert_eq!(admitted, 5);
        assert_eq!(shed, 15);
        assert_eq!(qs.shed, 15);
        // Draining restores admission capacity.
        assert!(qs.pop_tenant(0).is_some());
        assert!(qs.push(req(99, 1)).is_ok());
    }

    #[test]
    fn per_tenant_depth_still_applies_under_global_cap() {
        let mut qs = QueueSet::with_global_cap(2, 1, 100);
        qs.push(req(0, 0)).unwrap();
        assert_eq!(qs.push(req(1, 0)), Err(Reject::QueueFull));
        assert!(qs.push(req(2, 1)).is_ok());
        assert_eq!(qs.shed, 0, "depth rejections are not shed");
    }

    #[test]
    fn add_tenant_grows() {
        let mut qs = QueueSet::new(1, 4);
        let id = qs.add_tenant();
        assert_eq!(id, 1);
        qs.push(req(1, 1)).unwrap();
        assert_eq!(qs.tenant(1).unwrap().len(), 1);
    }

    #[test]
    fn pending_counter_tracks_push_pop_drain() {
        let mut qs = QueueSet::new(3, 8);
        for i in 0..7u64 {
            qs.push(req(i, (i % 3) as usize)).unwrap();
        }
        assert_eq!(qs.total_pending(), 7);
        assert!(qs.pop_tenant(0).is_some());
        assert_eq!(qs.total_pending(), 6);
        assert!(qs.pop_tenant(9).is_none(), "unknown tenant pops nothing");
        let drained = qs.drain_tenant(1);
        assert_eq!(qs.total_pending(), 6 - drained.len());
        qs.drain_tenant(0);
        qs.drain_tenant(2);
        assert_eq!(qs.total_pending(), 0);
        assert!(qs.is_empty());
        // Popping an empty queue leaves the counter alone.
        assert!(qs.pop_tenant(0).is_none());
        assert_eq!(qs.total_pending(), 0);
    }

    #[test]
    fn steal_latest_takes_the_back_of_the_edf_order() {
        use std::time::Duration;
        let base = Instant::now();
        let mut qs = QueueSet::new(3, 16);
        // Interleave tenants so the latest deadlines are spread across
        // queues: request i has deadline base + i ms.
        for i in 0..9u64 {
            qs.push(req_at(i, (i % 3) as usize, base + Duration::from_millis(i)))
                .unwrap();
        }
        let stolen = qs.steal_latest(4);
        // The four latest deadlines (ids 5..9) go, in deadline order.
        assert_eq!(stolen.iter().map(|r| r.id).collect::<Vec<_>>(), vec![5, 6, 7, 8]);
        assert_eq!(qs.total_pending(), 5);
        // The urgent front is untouched and still pops in EDF order.
        assert_eq!(qs.pop_tenant(0).unwrap().id, 0);
        assert_eq!(qs.pop_tenant(1).unwrap().id, 1);
        assert_eq!(qs.pop_tenant(2).unwrap().id, 2);
        assert_eq!(qs.total_pending(), 2);
        // Oversteal drains everything; understeal of an empty set is a
        // no-op.
        assert_eq!(qs.steal_latest(10).len(), 2);
        assert!(qs.is_empty());
        assert!(qs.steal_latest(3).is_empty());
    }

    #[test]
    fn record_shed_counts_external_sheds() {
        let mut qs = QueueSet::new(1, 4);
        qs.record_shed();
        qs.record_shed();
        assert_eq!(qs.shed, 2);
    }

    fn req_at(id: u64, tenant: usize, arrived: Instant) -> InferenceRequest {
        InferenceRequest {
            id,
            tenant,
            class: ShapeClass::batched_gemm(8, 8, 8),
            payload: vec![],
            arrived,
            deadline: arrived,
            priority: Priority::Normal,
            trace_id: 0,
        }
    }

    #[test]
    fn arrival_rate_tracks_burst_then_decays_when_idle() {
        use std::time::Duration;
        // Deterministic clock: 1 ms spacing == 1000 req/s offered.
        let base = Instant::now();
        let mut est = ArrivalRate::new(0.1);
        assert_eq!(est.rate_at(base), 0.0, "no arrivals yet");
        let mut t = base;
        for _ in 0..600 {
            t += Duration::from_millis(1);
            est.observe(t);
        }
        let burst = est.rate_at(t);
        assert!(
            (800.0..1200.0).contains(&burst),
            "burst rate {burst} should approach 1000 req/s"
        );
        // Idle: the estimate must DECAY when read, not freeze at the peak
        // (the latent gap: an estimator updated only on events reports the
        // burst rate forever once arrivals stop).
        let later = t + Duration::from_secs(1);
        let idled = est.rate_at(later);
        assert!(idled < 1.0, "after 1 s idle (10 tau) rate {idled} ~ 0");
        assert!(est.rate_at(t + Duration::from_millis(100)) < burst * 0.5);
        // Out-of-order stamps are inert, not a panic or a spike.
        est.observe(t - Duration::from_secs(5));
        assert!(est.rate_at(later) <= burst);
    }

    #[test]
    fn shed_events_keep_the_offered_load_estimate_alive() {
        use std::time::Duration;
        let base = Instant::now();
        // Cap 2: the front admits two requests and sheds the rest of a
        // 1 ms-spaced burst. The offered-load estimate must reflect the
        // full burst — a 100%-shedding front is overloaded, not idle.
        let mut qs = QueueSet::with_global_cap(1, 8, 2);
        let mut t = base;
        for i in 0..600u64 {
            t += Duration::from_millis(1);
            let _ = qs.push(req_at(i, 0, t));
        }
        assert_eq!(qs.total_pending(), 2);
        assert!(qs.shed > 0);
        let rate = qs.arrival_rate(t);
        assert!(
            (800.0..1200.0).contains(&rate),
            "shed arrivals must count toward offered load, got {rate}"
        );
        // Driver-level (external cap) sheds and upstream rejects feed the
        // same estimator.
        let mut qs2 = QueueSet::new(1, 8);
        let mut t2 = base;
        for _ in 0..600 {
            t2 += Duration::from_millis(1);
            if t2.duration_since(base).as_millis() % 2 == 0 {
                qs2.record_shed_at(t2);
            } else {
                qs2.note_arrival(t2);
            }
        }
        let r2 = qs2.arrival_rate(t2);
        assert!((800.0..1200.0).contains(&r2), "external sheds count: {r2}");
        // And the burst decays once the sheds stop.
        assert!(qs2.arrival_rate(t2 + Duration::from_secs(1)) < 1.0);
    }

    #[test]
    fn prop_arrival_rate_estimator_invariants() {
        // Randomized schedules of arrivals and idle reads against the EWMA
        // estimator's core invariants:
        //   1. the estimate is always finite and non-negative;
        //   2. it never exceeds the fastest instantaneous rate observed
        //      (each update is a convex blend of 1/dt samples, seeded at 0);
        //   3. idle decay is monotone non-increasing in the idle time;
        //   4. a long silence (>= 20 tau) drives the estimate to ~0 — the
        //      burst must never freeze at its peak;
        //   5. out-of-order timestamps never produce a spike or NaN.
        use crate::util::prop::run_prop;
        use std::time::Duration;
        run_prop("arrival-rate EWMA invariants", 0xA22, 96, |rng| {
            let base = Instant::now();
            let tau_ms = 20 + rng.gen_range(200); // 20..220 ms horizon
            let tau_s = tau_ms as f64 / 1e3;
            let mut est = ArrivalRate::new(tau_s);
            assert_eq!(est.rate_at(base), 0.0);
            // Run the virtual clock well ahead of `base` so the
            // out-of-order branch can step backwards without ever
            // underflowing the platform's monotonic-clock epoch.
            let mut t = base + Duration::from_secs(10);
            let mut fastest = 0.0f64; // max over observed 1/dt samples
            let n = 2 + rng.gen_range(120);
            for _ in 0..n {
                if rng.gen_bool(0.1) {
                    // Out-of-order stamp (invariant 5): saturates to a
                    // simultaneous arrival, never a spike.
                    est.observe(t - Duration::from_millis(1 + rng.gen_range(500)));
                    fastest = fastest.max(1e9); // dt clamps at 1e-9 s
                } else {
                    let gap_us = 200 + rng.gen_range(30_000); // 0.2..30.2 ms
                    t += Duration::from_micros(gap_us);
                    est.observe(t);
                    fastest = fastest.max(1e6 / gap_us as f64);
                }
                let r = est.rate_at(t);
                assert!(r.is_finite() && r >= 0.0, "rate {r} out of range");
                assert!(
                    r <= fastest * (1.0 + 1e-9),
                    "estimate {r} exceeds fastest instantaneous rate {fastest}"
                );
            }
            // Invariant 3: decay is monotone in the idle time.
            let mut prev = est.rate_at(t);
            for step in 1..=10u64 {
                let idled = est.rate_at(t + Duration::from_millis(step * tau_ms / 2));
                assert!(
                    idled <= prev * (1.0 + 1e-9),
                    "idle decay not monotone: {idled} after {prev}"
                );
                prev = idled;
            }
            // Invariant 4: 20 tau of silence ~ e^-20 of the peak.
            let silent = est.rate_at(t + Duration::from_millis(20 * tau_ms));
            assert!(
                silent <= fastest * 3e-9 + 1e-9,
                "estimate {silent} survived 20 tau of silence (peak {fastest})"
            );
        });
    }

    fn req_deadline(id: u64, deadline: Instant) -> InferenceRequest {
        InferenceRequest {
            id,
            tenant: 0,
            class: ShapeClass::batched_gemm(8, 8, 8),
            payload: vec![],
            arrived: Instant::now(),
            deadline,
            priority: Priority::Normal,
            trace_id: 0,
        }
    }

    #[test]
    fn prop_pending_always_equals_sum_of_tenant_lengths() {
        // Interleave every mutation path — push (admitted, depth-rejected,
        // cap-shed), pop_tenant (known, unknown, empty), drain_tenant,
        // record_shed, add_tenant — and assert after each step that the
        // incremental `pending` counter matches the ground truth (the sum
        // of per-tenant queue lengths) and never exceeds the global cap.
        use crate::util::prop::run_prop;
        run_prop("queue pending counter exact", 0xD2, 128, |rng| {
            let n0 = 1 + rng.gen_range(4) as usize;
            let depth = 1 + rng.gen_range(6) as usize;
            let cap = 1 + rng.gen_range(24) as usize;
            let mut qs = QueueSet::with_global_cap(n0, depth, cap);
            let mut id = 0u64;
            let mut external_sheds = 0u64;
            for _ in 0..300 {
                match rng.gen_range(8) {
                    0..=3 => {
                        // Bias to pushes so queues actually fill; target an
                        // unknown tenant occasionally (BadRequest path).
                        let t = rng.gen_range(qs.n_tenants() as u64 + 1) as usize;
                        let _ = qs.push(req(id, t));
                        id += 1;
                    }
                    4 | 5 => {
                        let t = rng.gen_range(qs.n_tenants() as u64 + 1) as usize;
                        let _ = qs.pop_tenant(t);
                    }
                    6 => {
                        let t = rng.gen_range(qs.n_tenants() as u64 + 1) as usize;
                        let _ = qs.drain_tenant(t);
                    }
                    _ => {
                        if rng.gen_bool(0.3) {
                            // A late-registered (readmitted) tenant joins.
                            qs.add_tenant();
                        } else {
                            qs.record_shed();
                            external_sheds += 1;
                        }
                    }
                }
                let truth: usize = (0..qs.n_tenants())
                    .map(|t| qs.tenant(t).unwrap().len())
                    .sum();
                assert_eq!(
                    qs.total_pending(),
                    truth,
                    "pending counter drifted from per-tenant lengths"
                );
                assert!(qs.total_pending() <= cap, "global cap violated");
            }
            assert!(qs.shed >= external_sheds, "external sheds lost");
        });
    }

    #[test]
    fn edf_pops_earliest_deadline_first() {
        use std::time::Duration;
        let now = Instant::now();
        let mut q = TenantQueue::new(8);
        // Pushed loose-first: the tighter deadline must still pop first.
        q.push(req_deadline(1, now + Duration::from_millis(300))).unwrap();
        q.push(req_deadline(2, now + Duration::from_millis(10))).unwrap();
        q.push(req_deadline(3, now + Duration::from_millis(100))).unwrap();
        assert_eq!(q.peek().unwrap().id, 2);
        assert_eq!(q.pop().unwrap().id, 2);
        assert_eq!(q.pop().unwrap().id, 3);
        assert_eq!(q.pop().unwrap().id, 1);
    }

    #[test]
    fn edf_ties_break_fifo() {
        let now = Instant::now();
        let deadline = now + std::time::Duration::from_millis(50);
        let mut q = TenantQueue::new(8);
        for id in 0..5u64 {
            q.push(req_deadline(id, deadline)).unwrap();
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop().map(|r| r.id)).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4], "equal deadlines pop FIFO");
    }

    #[test]
    fn edf_drain_is_deadline_ordered() {
        use std::time::Duration;
        let now = Instant::now();
        let mut q = TenantQueue::new(8);
        q.push(req_deadline(1, now + Duration::from_millis(30))).unwrap();
        q.push(req_deadline(2, now + Duration::from_millis(10))).unwrap();
        q.push(req_deadline(3, now + Duration::from_millis(20))).unwrap();
        let ids: Vec<u64> = q.drain().into_iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 3, 1]);
        assert!(q.is_empty());
    }
}
