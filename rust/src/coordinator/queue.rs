//! Per-tenant admission queues with bounded depth (backpressure).
//!
//! The paper's §2 model saturates queues; the bound keeps an overloaded or
//! evicted tenant from consuming unbounded memory and gives the frontend a
//! crisp rejection signal.

use std::collections::VecDeque;

use crate::coordinator::request::{InferenceRequest, Reject};

/// A bounded FIFO of pending requests for one tenant.
#[derive(Debug)]
pub struct TenantQueue {
    items: VecDeque<InferenceRequest>,
    depth: usize,
    /// Lifetime counters for metrics/backpressure analysis.
    pub enqueued: u64,
    pub rejected: u64,
}

impl TenantQueue {
    pub fn new(depth: usize) -> Self {
        assert!(depth >= 1);
        Self {
            items: VecDeque::with_capacity(depth.min(1024)),
            depth,
            enqueued: 0,
            rejected: 0,
        }
    }

    pub fn push(&mut self, req: InferenceRequest) -> Result<(), Reject> {
        if self.items.len() >= self.depth {
            self.rejected += 1;
            return Err(Reject::QueueFull);
        }
        self.items.push_back(req);
        self.enqueued += 1;
        Ok(())
    }

    pub fn pop(&mut self) -> Option<InferenceRequest> {
        self.items.pop_front()
    }

    pub fn peek(&self) -> Option<&InferenceRequest> {
        self.items.front()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Drop everything (tenant eviction); returns the drained requests so
    /// the caller can complete them with `Reject::TenantEvicted`.
    pub fn drain(&mut self) -> Vec<InferenceRequest> {
        self.items.drain(..).collect()
    }
}

/// All tenants' queues; index == tenant id.
#[derive(Debug)]
pub struct QueueSet {
    queues: Vec<TenantQueue>,
    depth: usize,
}

impl QueueSet {
    pub fn new(n_tenants: usize, depth: usize) -> Self {
        Self {
            queues: (0..n_tenants).map(|_| TenantQueue::new(depth)).collect(),
            depth,
        }
    }

    /// Add a queue for a late-registered tenant; returns its index.
    pub fn add_tenant(&mut self) -> usize {
        self.queues.push(TenantQueue::new(self.depth));
        self.queues.len() - 1
    }

    pub fn push(&mut self, req: InferenceRequest) -> Result<(), Reject> {
        let t = req.tenant;
        self.queues
            .get_mut(t)
            .ok_or_else(|| Reject::BadRequest(format!("unknown tenant {t}")))?
            .push(req)
    }

    pub fn tenant(&self, id: usize) -> Option<&TenantQueue> {
        self.queues.get(id)
    }

    pub fn tenant_mut(&mut self, id: usize) -> Option<&mut TenantQueue> {
        self.queues.get_mut(id)
    }

    pub fn n_tenants(&self) -> usize {
        self.queues.len()
    }

    pub fn total_pending(&self) -> usize {
        self.queues.iter().map(TenantQueue::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.queues.iter().all(TenantQueue::is_empty)
    }

    /// Tenants with at least one pending request, ascending id.
    pub fn backlogged(&self) -> Vec<usize> {
        (0..self.queues.len())
            .filter(|&i| !self.queues[i].is_empty())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ShapeClass;
    use std::time::Instant;

    fn req(id: u64, tenant: usize) -> InferenceRequest {
        InferenceRequest {
            id,
            tenant,
            class: ShapeClass::batched_gemm(8, 8, 8),
            payload: vec![],
            arrived: Instant::now(),
            deadline: Instant::now(),
        }
    }

    #[test]
    fn fifo_order() {
        let mut q = TenantQueue::new(4);
        q.push(req(1, 0)).unwrap();
        q.push(req(2, 0)).unwrap();
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 2);
        assert!(q.pop().is_none());
    }

    #[test]
    fn backpressure_at_depth() {
        let mut q = TenantQueue::new(2);
        q.push(req(1, 0)).unwrap();
        q.push(req(2, 0)).unwrap();
        assert_eq!(q.push(req(3, 0)), Err(Reject::QueueFull));
        assert_eq!(q.rejected, 1);
        assert_eq!(q.enqueued, 2);
        // Popping frees a slot.
        q.pop();
        assert!(q.push(req(3, 0)).is_ok());
    }

    #[test]
    fn drain_empties() {
        let mut q = TenantQueue::new(8);
        for i in 0..5 {
            q.push(req(i, 0)).unwrap();
        }
        let drained = q.drain();
        assert_eq!(drained.len(), 5);
        assert!(q.is_empty());
    }

    #[test]
    fn queue_set_routes_by_tenant() {
        let mut qs = QueueSet::new(3, 4);
        qs.push(req(1, 0)).unwrap();
        qs.push(req(2, 2)).unwrap();
        assert_eq!(qs.tenant(0).unwrap().len(), 1);
        assert_eq!(qs.tenant(1).unwrap().len(), 0);
        assert_eq!(qs.tenant(2).unwrap().len(), 1);
        assert_eq!(qs.total_pending(), 2);
        assert_eq!(qs.backlogged(), vec![0, 2]);
        assert!(matches!(qs.push(req(3, 9)), Err(Reject::BadRequest(_))));
    }

    #[test]
    fn add_tenant_grows() {
        let mut qs = QueueSet::new(1, 4);
        let id = qs.add_tenant();
        assert_eq!(id, 1);
        qs.push(req(1, 1)).unwrap();
        assert_eq!(qs.tenant(1).unwrap().len(), 1);
    }
}
