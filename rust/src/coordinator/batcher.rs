//! The dynamic inter-model batcher — the mechanism behind the paper's
//! space-time scheduler (§4): merge many concurrent small GEMM problems
//! from *disjoint* model graphs into a small set of batched super-kernels
//! that together fill the device.
//!
//! `cublasSgemmBatched` (and our Pallas analog) requires all fused problems
//! to share (M, N, K); MAGMA-style variable-size batching is emulated by
//! *shape-class bucketing*: requests fuse only within a class, and the lane
//! count rounds up to the next precompiled R bucket with zero-padded lanes
//! (waste is accounted and ablated in `benches/ablation_batcher.rs`).

use std::collections::BTreeMap;

use crate::coordinator::request::{InferenceRequest, ShapeClass};
#[cfg(test)]
use crate::coordinator::request::Priority;

/// A planned super-kernel launch: `entries.len()` real problems padded up
/// to `r_bucket` lanes of one artifact execution.
#[derive(Debug)]
pub struct Launch {
    pub class: ShapeClass,
    pub entries: Vec<InferenceRequest>,
    pub r_bucket: usize,
}

impl Launch {
    /// Fraction of lanes carrying real problems. The batcher guarantees
    /// `entries.len() <= r_bucket`; a hand-built over-full launch (tests,
    /// external callers) clamps to 1.0 rather than reporting >100%.
    pub fn occupancy(&self) -> f64 {
        (self.entries.len() as f64 / self.r_bucket.max(1) as f64).min(1.0)
    }

    /// Zero-padded lanes in this launch. Saturating: an over-full launch
    /// (`entries.len() > r_bucket`) reports 0 padding instead of panicking
    /// on usize underflow in debug builds.
    pub fn padded_lanes(&self) -> usize {
        self.r_bucket.saturating_sub(self.entries.len())
    }
}

/// Padding/occupancy accounting across a batcher's lifetime.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct BatcherStats {
    pub launches: u64,
    pub problems: u64,
    pub padded_lanes: u64,
}

impl BatcherStats {
    /// Fraction of executed lanes that were padding.
    pub fn padding_waste(&self) -> f64 {
        let lanes = self.problems + self.padded_lanes;
        if lanes == 0 {
            0.0
        } else {
            self.padded_lanes as f64 / lanes as f64
        }
    }

    /// Mean problems per launch (the R the device actually sees).
    pub fn mean_fused(&self) -> f64 {
        if self.launches == 0 {
            0.0
        } else {
            self.problems as f64 / self.launches as f64
        }
    }
}

/// How a chunk that doesn't exactly match an R bucket is dispatched.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PaddingPolicy {
    /// Round up to the next bucket; padded lanes compute zeros. Fewest
    /// launches — right when lanes are (near-)free, i.e. a parallel device
    /// with idle SMs (the paper's V100 setting).
    PadToBucket,
    /// Decompose the chunk into its binary bucket representation
    /// (5 → 4+1): zero padding, ≤ log2(max) launches. Right when a padded
    /// lane costs real compute (serial hardware) or when padding waste is
    /// the binding constraint. Ablated in `benches/ablation_batcher.rs`.
    SplitExact,
}

/// The batcher: groups by shape class, chunks to `max_batch`, dispatches
/// chunks per the [`PaddingPolicy`].
#[derive(Debug)]
pub struct DynamicBatcher {
    /// Available R buckets (ascending), from the artifact manifest.
    buckets: Vec<usize>,
    /// Cap on problems fused into one launch.
    max_batch: usize,
    policy: PaddingPolicy,
    pub stats: BatcherStats,
    /// Per-class staging buffers recycled across rounds ([`plan_into`]):
    /// keys persist (the class set is small and stable under steady
    /// load), values are drained each round but keep their capacity — so
    /// grouping allocates nothing after warmup.
    ///
    /// [`plan_into`]: DynamicBatcher::plan_into
    by_class: BTreeMap<ShapeClass, Vec<InferenceRequest>>,
}

impl DynamicBatcher {
    pub fn new(buckets: Vec<usize>, max_batch: usize) -> Self {
        Self::with_policy(buckets, max_batch, PaddingPolicy::PadToBucket)
    }

    pub fn with_policy(
        mut buckets: Vec<usize>,
        max_batch: usize,
        policy: PaddingPolicy,
    ) -> Self {
        assert!(!buckets.is_empty(), "need at least one R bucket");
        assert!(max_batch >= 1);
        buckets.sort_unstable();
        buckets.dedup();
        Self {
            buckets,
            max_batch,
            policy,
            stats: BatcherStats::default(),
            by_class: BTreeMap::new(),
        }
    }

    /// Powers-of-two buckets matching `python/compile/aot.py::R_BUCKETS`.
    pub fn default_buckets() -> Vec<usize> {
        vec![1, 2, 4, 8, 16, 32, 64]
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    pub fn policy(&self) -> PaddingPolicy {
        self.policy
    }

    pub fn largest_bucket(&self) -> usize {
        *self.buckets.last().unwrap()
    }

    /// Smallest bucket >= n (None if n exceeds the largest bucket).
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|&b| b >= n)
    }

    /// Plan launches for a set of pending requests (already drained from
    /// the queues by the scheduler). Grouping is deterministic: classes in
    /// sorted order, requests in the order given (schedulers drain
    /// round-robin for fairness).
    pub fn plan(&mut self, pending: Vec<InferenceRequest>) -> Vec<Launch> {
        let mut pending = pending;
        let mut launches = Vec::new();
        self.plan_into(&mut pending, &mut launches);
        launches
    }

    /// [`DynamicBatcher::plan`] over recycled buffers — the driver's
    /// allocation-free round path: `pending` is drained (keeping its
    /// capacity for the next round's staging) and launches are appended
    /// to `out` (the arena's recycled vector). Only each launch's owned
    /// entry vector is freshly allocated, because launches carry their
    /// requests away with them.
    // lint: hot-path
    // lint: pure
    pub fn plan_into(&mut self, pending: &mut Vec<InferenceRequest>, out: &mut Vec<Launch>) {
        let mut by_class = std::mem::take(&mut self.by_class);
        for r in pending.drain(..) {
            by_class.entry(r.class).or_default().push(r);
        }
        let chunk_cap = self.max_batch.min(self.largest_bucket());
        for (class, reqs) in by_class.iter_mut() {
            while !reqs.is_empty() {
                let take = chunk_cap.min(reqs.len());
                // lint: allow(hot-path-alloc) — each launch carries its
                // entries away by value, so this owned vector is the one
                // deliberate per-launch allocation the round path keeps
                // (see the doc comment above).
                let chunk: Vec<InferenceRequest> = reqs.drain(..take).collect();
                self.dispatch_chunk(*class, chunk, out);
            }
        }
        self.by_class = by_class;
    }

    /// Split an already-planned launch after its first `k` entries **in
    /// the order given** (the deadline-aware planner pre-sorts entries by
    /// deadline so the prefix is the urgent subset). The head becomes ONE
    /// launch (rounded up to its covering bucket — the deadline-protected
    /// piece must stay a single launch); the remainder re-dispatches
    /// through the batcher's [`PaddingPolicy`], so under `SplitExact` it
    /// decomposes into exact-bucket launches instead of padding. All
    /// pieces are re-canonicalized to the (tenant, id) lane order the
    /// fusion cache keys on, and the lifetime stats are corrected (the
    /// original launch's accounting is replaced by the new pieces').
    ///
    /// Panics if `k` is not strictly inside `(0, entries.len())` or the
    /// launch is over-full (the batcher never emits one).
    pub fn split_launch(&mut self, launch: Launch, k: usize) -> (Launch, Vec<Launch>) {
        let Launch { class, mut entries, r_bucket } = launch;
        assert!(k > 0 && k < entries.len(), "split point must be interior");
        assert!(entries.len() <= r_bucket, "over-full launch");
        let n = entries.len();
        let tail = entries.split_off(k);
        let mut head = entries;
        head.sort_by_key(|r| (r.tenant, r.id));
        let head_bucket = self
            .bucket_for(head.len())
            .expect("head smaller than original bucket");
        // Replace the original launch's accounting with the new pieces':
        // uncount it, count the head, let dispatch_chunk count the tail.
        self.stats.launches = self.stats.launches.saturating_sub(1);
        self.stats.problems = self.stats.problems.saturating_sub(n as u64);
        self.stats.padded_lanes = self
            .stats
            .padded_lanes
            .saturating_sub((r_bucket - n) as u64);
        self.stats.launches += 1;
        self.stats.problems += head.len() as u64;
        self.stats.padded_lanes += (head_bucket - head.len()) as u64;
        let mut tails = Vec::new();
        self.dispatch_chunk(class, tail, &mut tails);
        (Launch { class, entries: head, r_bucket: head_bucket }, tails)
    }

    fn dispatch_chunk(
        &mut self,
        class: ShapeClass,
        mut chunk: Vec<InferenceRequest>,
        out: &mut Vec<Launch>,
    ) {
        // Canonical lane assignment: sort by (tenant, id). All requests in
        // a chunk complete in the same launch, so intra-chunk order carries
        // no fairness meaning — but a *stable* assignment makes recurring
        // tenant sets hit the fusion cache (same key ⇒ weight operands
        // already device-resident) regardless of drain order, and keeps
        // per-tenant FIFO (ids ascend within a tenant).
        chunk.sort_by_key(|r| (r.tenant, r.id));
        match self.policy {
            PaddingPolicy::PadToBucket => {
                let r_bucket = self
                    .bucket_for(chunk.len())
                    .expect("chunk_cap bounded by largest bucket");
                self.stats.launches += 1;
                self.stats.problems += chunk.len() as u64;
                self.stats.padded_lanes += (r_bucket - chunk.len()) as u64;
                out.push(Launch { class, entries: chunk, r_bucket });
            }
            PaddingPolicy::SplitExact => {
                // Greedy largest-bucket-first decomposition. With the
                // default power-of-two buckets this is exactly the binary
                // representation of the chunk size (zero padding); with
                // arbitrary buckets the final fragment may still pad.
                let mut rest = chunk;
                while !rest.is_empty() {
                    let take = self
                        .buckets
                        .iter()
                        .rev()
                        .copied()
                        .find(|&b| b <= rest.len())
                        .unwrap_or_else(|| self.buckets[0]);
                    let take = take.min(rest.len());
                    let piece: Vec<InferenceRequest> =
                        rest.drain(..take).collect();
                    let r_bucket = self
                        .bucket_for(piece.len())
                        .expect("piece fits smallest covering bucket");
                    self.stats.launches += 1;
                    self.stats.problems += piece.len() as u64;
                    self.stats.padded_lanes += (r_bucket - piece.len()) as u64;
                    out.push(Launch { class, entries: piece, r_bucket });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn req(id: u64, tenant: usize, class: ShapeClass) -> InferenceRequest {
        InferenceRequest {
            id,
            tenant,
            class,
            payload: vec![],
            arrived: Instant::now(),
            deadline: Instant::now(),
            priority: Priority::Normal,
            trace_id: 0,
        }
    }

    fn gemm(m: usize) -> ShapeClass {
        ShapeClass::batched_gemm(m, 64, 64)
    }

    #[test]
    fn fuses_same_class_across_tenants() {
        let mut b = DynamicBatcher::new(DynamicBatcher::default_buckets(), 64);
        let pending = (0..5).map(|i| req(i, i as usize, gemm(128))).collect();
        let launches = b.plan(pending);
        assert_eq!(launches.len(), 1);
        assert_eq!(launches[0].entries.len(), 5);
        assert_eq!(launches[0].r_bucket, 8, "5 rounds up to bucket 8");
        assert_eq!(launches[0].padded_lanes(), 3);
        let tenants: Vec<usize> = launches[0].entries.iter().map(|e| e.tenant).collect();
        assert_eq!(tenants, vec![0, 1, 2, 3, 4], "cross-tenant fusion");
    }

    #[test]
    fn distinct_classes_never_fuse() {
        let mut b = DynamicBatcher::new(DynamicBatcher::default_buckets(), 64);
        let pending = vec![
            req(0, 0, gemm(128)),
            req(1, 1, gemm(256)),
            req(2, 2, gemm(128)),
        ];
        let launches = b.plan(pending);
        assert_eq!(launches.len(), 2);
        for l in &launches {
            assert!(l.entries.iter().all(|e| e.class == l.class));
        }
    }

    #[test]
    fn splits_at_max_batch() {
        let mut b = DynamicBatcher::new(DynamicBatcher::default_buckets(), 4);
        let pending = (0..10).map(|i| req(i, 0, gemm(64))).collect();
        let launches = b.plan(pending);
        assert_eq!(launches.len(), 3); // 4 + 4 + 2
        assert_eq!(launches[0].entries.len(), 4);
        assert_eq!(launches[0].r_bucket, 4);
        assert_eq!(launches[2].entries.len(), 2);
        assert_eq!(launches[2].r_bucket, 2);
    }

    #[test]
    fn exact_bucket_has_zero_padding() {
        let mut b = DynamicBatcher::new(DynamicBatcher::default_buckets(), 64);
        let launches = b.plan((0..16).map(|i| req(i, 0, gemm(64))).collect());
        assert_eq!(launches[0].r_bucket, 16);
        assert_eq!(launches[0].padded_lanes(), 0);
        assert_eq!(b.stats.padding_waste(), 0.0);
        assert_eq!(launches[0].occupancy(), 1.0);
    }

    #[test]
    fn stats_accumulate() {
        let mut b = DynamicBatcher::new(vec![1, 2, 4], 4);
        b.plan((0..3).map(|i| req(i, 0, gemm(64))).collect()); // 3 -> bucket 4
        b.plan((0..2).map(|i| req(i, 0, gemm(64))).collect()); // 2 -> bucket 2
        assert_eq!(b.stats.launches, 2);
        assert_eq!(b.stats.problems, 5);
        assert_eq!(b.stats.padded_lanes, 1);
        assert!((b.stats.padding_waste() - 1.0 / 6.0).abs() < 1e-12);
        assert!((b.stats.mean_fused() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn lane_assignment_is_canonical_and_fifo_per_tenant() {
        let mut b = DynamicBatcher::new(vec![1, 2, 4, 8], 8);
        let launches = b.plan((0..6).map(|i| req(i, (i % 3) as usize, gemm(64))).collect());
        // Sorted by (tenant, id): tenant 0 -> {0,3}, 1 -> {1,4}, 2 -> {2,5}.
        let lanes: Vec<(usize, u64)> =
            launches[0].entries.iter().map(|e| (e.tenant, e.id)).collect();
        assert_eq!(lanes, vec![(0, 0), (0, 3), (1, 1), (1, 4), (2, 2), (2, 5)]);
        // The same request set drained in a different order produces the
        // SAME lane assignment (the fusion-cache key stability property).
        let mut b2 = DynamicBatcher::new(vec![1, 2, 4, 8], 8);
        let mut reqs: Vec<_> = (0..6).map(|i| req(i, (i % 3) as usize, gemm(64))).collect();
        reqs.reverse();
        let launches2 = b2.plan(reqs);
        let lanes2: Vec<(usize, u64)> =
            launches2[0].entries.iter().map(|e| (e.tenant, e.id)).collect();
        assert_eq!(lanes, lanes2);
    }

    #[test]
    fn overfull_launch_saturates_instead_of_panicking() {
        // Regression: entries.len() > r_bucket used to underflow (debug
        // panic) in padded_lanes() and report >100% occupancy. The batcher
        // never emits such a launch, but Launch is a public type.
        let overfull = Launch {
            class: gemm(64),
            entries: (0..5).map(|i| req(i, 0, gemm(64))).collect(),
            r_bucket: 2,
        };
        assert_eq!(overfull.padded_lanes(), 0);
        assert_eq!(overfull.occupancy(), 1.0);
        // Zero-bucket degenerate case stays finite too.
        let zero = Launch { class: gemm(64), entries: vec![], r_bucket: 0 };
        assert_eq!(zero.padded_lanes(), 0);
        assert_eq!(zero.occupancy(), 0.0);
    }

    #[test]
    fn plan_into_matches_plan_and_recycles_staging() {
        let mk = |n: usize| -> Vec<InferenceRequest> {
            (0..n).map(|i| req(i as u64, i % 3, gemm(64))).collect()
        };
        let mut a = DynamicBatcher::new(DynamicBatcher::default_buckets(), 4);
        let mut b = DynamicBatcher::new(DynamicBatcher::default_buckets(), 4);
        let mut pending = mk(10);
        let mut out = Vec::new();
        a.plan_into(&mut pending, &mut out);
        let reference = b.plan(mk(10));
        assert!(pending.is_empty(), "plan_into drains the staging vector");
        assert_eq!(out.len(), reference.len());
        for (x, y) in out.iter().zip(&reference) {
            assert_eq!(x.class, y.class);
            assert_eq!(x.r_bucket, y.r_bucket);
            let ids = |l: &Launch| l.entries.iter().map(|e| e.id).collect::<Vec<_>>();
            assert_eq!(ids(x), ids(y));
        }
        assert_eq!(a.stats, b.stats);
        // Steady rounds reuse the per-class staging buffers: capacity of
        // the recycled vectors is flat after the first round.
        pending.extend(mk(10));
        out.clear();
        a.plan_into(&mut pending, &mut out);
        let cap = pending.capacity();
        for _ in 0..8 {
            pending.extend(mk(10));
            out.clear();
            a.plan_into(&mut pending, &mut out);
        }
        assert_eq!(pending.capacity(), cap, "staging capacity must be stable");
    }

    #[test]
    fn empty_plan_is_empty() {
        let mut b = DynamicBatcher::new(vec![1, 2], 2);
        assert!(b.plan(vec![]).is_empty());
        assert_eq!(b.stats, BatcherStats::default());
    }

    #[test]
    fn split_launch_rebuckets_and_fixes_stats() {
        let mut b = DynamicBatcher::new(DynamicBatcher::default_buckets(), 64);
        let launches = b.plan((0..6).map(|i| req(i, i as usize, gemm(64))).collect());
        assert_eq!(launches.len(), 1);
        assert_eq!(launches[0].r_bucket, 8); // 6 -> bucket 8, 2 padded
        assert_eq!(b.stats.padded_lanes, 2);
        let launch = launches.into_iter().next().unwrap();
        let (head, tails) = b.split_launch(launch, 2);
        assert_eq!(head.entries.len(), 2);
        assert_eq!(head.r_bucket, 2);
        assert_eq!(tails.len(), 1, "PadToBucket tail is one rounded-up launch");
        assert_eq!(tails[0].entries.len(), 4);
        assert_eq!(tails[0].r_bucket, 4);
        // Lane order stays canonical (tenant, id) in both pieces.
        assert!(head.entries.windows(2).all(|w| (w[0].tenant, w[0].id)
            <= (w[1].tenant, w[1].id)));
        assert!(tails[0].entries.windows(2).all(|w| (w[0].tenant, w[0].id)
            <= (w[1].tenant, w[1].id)));
        // Stats: one extra launch, padding now exact (2+4 fill buckets 2+4).
        assert_eq!(b.stats.launches, 2);
        assert_eq!(b.stats.problems, 6);
        assert_eq!(b.stats.padded_lanes, 0);
    }

    #[test]
    fn split_launch_preserves_split_exact_zero_padding() {
        // An exact 8-wide SplitExact launch split at an exact bucket (2)
        // must stay zero-padding: head 2, tail decomposed 4+2.
        let mut b = DynamicBatcher::with_policy(
            DynamicBatcher::default_buckets(),
            64,
            PaddingPolicy::SplitExact,
        );
        let launches = b.plan((0..8).map(|i| req(i, 0, gemm(64))).collect());
        assert_eq!(launches.len(), 1);
        assert_eq!(b.stats.padded_lanes, 0);
        let launch = launches.into_iter().next().unwrap();
        let (head, tails) = b.split_launch(launch, 2);
        assert_eq!(head.entries.len(), 2);
        assert_eq!(head.r_bucket, 2);
        let tail_sizes: Vec<usize> = tails.iter().map(|l| l.entries.len()).collect();
        assert_eq!(tail_sizes, vec![4, 2], "tail re-decomposes exactly");
        assert!(tails.iter().all(|l| l.entries.len() == l.r_bucket));
        assert_eq!(b.stats.padded_lanes, 0, "SplitExact invariant survives");
        assert_eq!(b.stats.problems, 8);
        assert_eq!(b.stats.launches, 3);
    }

    #[test]
    fn split_exact_is_binary_decomposition() {
        let mut b = DynamicBatcher::with_policy(
            DynamicBatcher::default_buckets(),
            64,
            PaddingPolicy::SplitExact,
        );
        // 13 = 8 + 4 + 1 — three launches, zero padding.
        let launches = b.plan((0..13).map(|i| req(i, 0, gemm(64))).collect());
        let sizes: Vec<usize> = launches.iter().map(|l| l.entries.len()).collect();
        assert_eq!(sizes, vec![8, 4, 1]);
        assert!(launches.iter().all(|l| l.padded_lanes() == 0));
        assert_eq!(b.stats.padding_waste(), 0.0);
        // FIFO preserved across the split.
        let ids: Vec<u64> = launches
            .iter()
            .flat_map(|l| l.entries.iter().map(|e| e.id))
            .collect();
        assert_eq!(ids, (0..13).collect::<Vec<u64>>());
    }

    #[test]
    fn split_exact_conserves_and_respects_cap() {
        let mut b = DynamicBatcher::with_policy(vec![1, 2, 4, 8], 6, PaddingPolicy::SplitExact);
        let launches = b.plan((0..11).map(|i| req(i, i as usize % 3, gemm(64))).collect());
        let total: usize = launches.iter().map(|l| l.entries.len()).sum();
        assert_eq!(total, 11);
        assert!(launches.iter().all(|l| l.entries.len() <= 6));
        assert!(launches.iter().all(|l| l.entries.len() <= l.r_bucket));
    }
}
