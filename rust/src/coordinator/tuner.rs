//! Offline configuration autotuner — the engine behind `stgpu tune`.
//!
//! Searches the space-time scheduler's knob space — static `lanes` vs the
//! adaptive controller (with its `max_lanes` / `dwell_rounds` /
//! `improvement` / `slo_target` hysteresis knobs), `pipeline_depth`, EDF
//! deadline-aware planning with its `deadline_slack` margin, and
//! work-conserving lane execution (`steal` / `steal_min_queue`) — against
//! gpusim ground truth for a named workload, scoring **SLO-met goodput**
//! (requests completed within deadline per second, the utility the paper's
//! controller optimizes). The search is a deterministic coarse grid (the
//! committed fig12 reference configuration always evaluated first) followed
//! by greedy local refinement around the incumbent, both bounded by an
//! evaluation budget.
//!
//! The only workload today is `"fig12"`: the phase-shifting trace from
//! `benches/fig12_adaptive_lanes.rs` (deterministic latency-critical waves,
//! a Poisson batch flood, then a mixed phase). The replay here is a knob-
//! parameterized port of that bench — **keep the two in sync**: with the
//! [`TunePoint::reference`] knobs it reproduces the bench's adaptive run
//! decision-for-decision, which is what anchors the tuner's scores to the
//! committed `BENCH_fig12_adaptive_lanes.json` baseline.
//!
//! `pipeline_depth` is modeled as *where planning time goes*: depth >= 2
//! overlaps planning with execution (the driver's pipelined round loop), so
//! rounds pay nothing; depth == 1 is the serial loop, so every round is
//! charged [`PLAN_OVERHEAD_S`] of wall clock before its launches start.
//!
//! The winner is emitted two ways: a `[server]`/`[controller]` TOML
//! fragment that is *self-validated* by round-tripping through
//! [`ServerConfig::from_doc`] (the tuner can never recommend a config the
//! server would reject), and a JSON leaderboard of every evaluated point.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::schema::ServerConfig;
use crate::config::toml_lite::TomlDoc;
use crate::coordinator::controller::{
    AdaptiveController, ControlSignals, ControllerParams, Decision, SignalTracker,
};
use crate::coordinator::costmodel::CostModel;
use crate::coordinator::queue::QueueSet;
use crate::coordinator::request::{InferenceRequest, Priority, ShapeClass};
use crate::coordinator::scheduler::{Scheduler, SpaceTimeSched};
use crate::gpusim::cost::{kernel_service_time, CostCtx};
use crate::gpusim::{DeviceSpec, GemmShape, KernelDesc};
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::util::stats;

// ---------------------------------------------------------------------------
// The fig12 workload (keep in sync with benches/fig12_adaptive_lanes.rs).
// ---------------------------------------------------------------------------

/// Device-filling "latency-critical" classes (occupancy-saturated: lanes
/// stretch launches ~n×, overlap never pays).
const LAT_CLASSES: [ShapeClass; 4] = [
    ShapeClass { kind: "batched_gemm", m: 8192, n: 8192, k: 128 },
    ShapeClass { kind: "batched_gemm", m: 8192, n: 8064, k: 128 },
    ShapeClass { kind: "batched_gemm", m: 8064, n: 8192, k: 128 },
    ShapeClass { kind: "batched_gemm", m: 8064, n: 8064, k: 128 },
];
/// Small underfilling classes (fig10's regime: lanes nearly double
/// throughput).
const BATCH_CLASSES: [ShapeClass; 4] = [
    ShapeClass { kind: "batched_gemm", m: 256, n: 128, k: 1152 },
    ShapeClass { kind: "batched_gemm", m: 128, n: 256, k: 1152 },
    ShapeClass { kind: "batched_gemm", m: 256, n: 128, k: 1024 },
    ShapeClass { kind: "batched_gemm", m: 128, n: 256, k: 1024 },
];
const N_LAT: usize = 8;
const N_BATCH: usize = 8;
const LAT_SLO_S: f64 = 0.0115;
const BATCH_SLO_S: f64 = 0.400;
const MAX_BATCH: usize = 16;
const PH_A: f64 = 1.0;
const PH_B: f64 = 1.5;
const PH_C: f64 = 2.0;
const HORIZON: f64 = PH_A + PH_B + PH_C;
const WAVE_PERIOD_S: f64 = 0.025;
const B_BATCH_RPS: f64 = 68_000.0;
const C_BATCH_RPS: f64 = 200.0;
const SEED: u64 = 1042;

/// Wall-clock charged to every round when `pipeline_depth == 1` (the
/// serial plan → execute → collect loop; fig11's measured round overhead is
/// of this order). Depth >= 2 overlaps planning with execution for free.
pub const PLAN_OVERHEAD_S: f64 = 200e-6;

fn tenant_class(t: usize) -> ShapeClass {
    if t < N_LAT {
        LAT_CLASSES[t / 2]
    } else {
        BATCH_CLASSES[(t - N_LAT) / 2]
    }
}

fn tenant_slo_s(t: usize) -> f64 {
    if t < N_LAT {
        LAT_SLO_S
    } else {
        BATCH_SLO_S
    }
}

fn phase_of(t_arrival: f64) -> usize {
    if t_arrival < PH_A {
        0
    } else if t_arrival < PH_A + PH_B {
        1
    } else {
        2
    }
}

/// The phase-shifting arrival trace: deterministic latency-critical waves
/// (A: two classes; C: all four) plus Poisson batch floods (heavy in B,
/// light in C). Identical to the fig12 bench's `trace()`.
fn trace() -> Vec<(f64, usize)> {
    let mut reqs: Vec<(f64, usize)> = Vec::new();
    let mut k = 1usize;
    while k as f64 * WAVE_PERIOD_S < PH_A {
        for t in 0..4 {
            reqs.push((k as f64 * WAVE_PERIOD_S, t));
        }
        k += 1;
    }
    let mut k = 1usize;
    while PH_A + PH_B + k as f64 * WAVE_PERIOD_S < HORIZON {
        for t in 0..N_LAT {
            reqs.push((PH_A + PH_B + k as f64 * WAVE_PERIOD_S, t));
        }
        k += 1;
    }
    let mut rng = Rng::new(SEED);
    for t in N_LAT..N_LAT + N_BATCH {
        for (t0, t1, rate) in [
            (PH_A, PH_A + PH_B, B_BATCH_RPS / N_BATCH as f64),
            (PH_A + PH_B, HORIZON, C_BATCH_RPS / N_BATCH as f64),
        ] {
            let mut x = t0 + rng.gen_exp(rate);
            while x < t1 {
                reqs.push((x, t));
                x += rng.gen_exp(rate);
            }
        }
    }
    reqs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    reqs
}

/// gpusim ground truth for a fused launch of `r` problems of `class` with
/// `active` lanes concurrently resident (same construction as fig10/fig12).
fn ground_truth(spec: &DeviceSpec, class: ShapeClass, r: usize, active: usize) -> f64 {
    let shape =
        GemmShape::new(class.m.max(1) as u32, class.n.max(1) as u32, class.k.max(1) as u32);
    let mut merged = KernelDesc::sgemm(0, shape);
    let r = r.max(1);
    merged.flops *= r as f64;
    merged.bytes *= r as f64;
    merged.ctas = merged.ctas.saturating_mul(r as u32);
    merged.fused = r as u32;
    let active = active.max(1);
    spec.launch_overhead_s
        + kernel_service_time(
            spec,
            &merged,
            &CostCtx {
                sms: spec.sms as f64 / active as f64,
                concurrency: active as u32,
                static_bw_partition: false,
            },
        )
}

// ---------------------------------------------------------------------------
// Candidate points and the replay.
// ---------------------------------------------------------------------------

/// One point in the knob space: everything the emitted `[server]` /
/// `[controller]` TOML fragment can say about the space-time scheduler.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TunePoint {
    /// Run the adaptive controller (`lanes` is then the starting lane
    /// count, `max_lanes` the cap) vs a static `lanes` setting.
    pub adaptive: bool,
    pub lanes: usize,
    pub max_lanes: usize,
    pub pipeline_depth: usize,
    /// EDF deadline-aware planning and its safety margin (seconds).
    pub edf: bool,
    pub deadline_slack_s: f64,
    /// Controller hysteresis knobs (ignored when `adaptive == false`).
    pub dwell_rounds: u32,
    pub improvement: f64,
    pub slo_target: f64,
    /// Work-conserving lane execution: idle lanes take the back of the
    /// longest lane's queue (the `[server] steal` knob).
    pub steal: bool,
    /// Victim floor for a steal (the `[server] steal_min_queue` knob).
    pub steal_min_queue: usize,
}

impl TunePoint {
    /// The committed fig12 configuration: the adaptive run of
    /// `benches/fig12_adaptive_lanes.rs`, pipelined planning. Evaluating
    /// this point reproduces that bench decision-for-decision, so its
    /// goodput is the one anchored by `BENCH_fig12_adaptive_lanes.json`.
    pub fn reference() -> Self {
        Self {
            adaptive: true,
            lanes: 1,
            max_lanes: 4,
            pipeline_depth: 2,
            edf: false,
            deadline_slack_s: 0.0,
            dwell_rounds: 4,
            improvement: 0.10,
            slo_target: 0.99,
            steal: false,
            steal_min_queue: 1,
        }
    }

    pub fn label(&self) -> String {
        let mode = if self.adaptive {
            format!("adaptive(max_lanes={})", self.max_lanes)
        } else {
            format!("static(lanes={})", self.lanes)
        };
        let edf = if self.edf {
            format!(" edf(slack={:.4}s)", self.deadline_slack_s)
        } else {
            String::new()
        };
        let steal = if self.steal {
            format!(" steal(min={})", self.steal_min_queue.max(1))
        } else {
            String::new()
        };
        format!(
            "{mode} depth={}{edf}{steal} dwell={} improv={:.2} slo={:.2}",
            self.pipeline_depth, self.dwell_rounds, self.improvement, self.slo_target
        )
    }

    /// The `[server]` + `[controller]` TOML fragment for this point, in the
    /// exact dialect `ServerConfig::from_doc` validates.
    pub fn to_toml(&self) -> String {
        let mut s = String::new();
        s.push_str("[server]\n");
        s.push_str("scheduler = \"space-time\"\n");
        s.push_str(&format!("max_batch = {MAX_BATCH}\n"));
        s.push_str("slo_aware = true\n");
        s.push_str(&format!("edf = {}\n", self.edf));
        s.push_str(&format!("deadline_slack = {:.6}\n", self.deadline_slack_s));
        s.push_str(&format!("lanes = {}\n", self.lanes));
        s.push_str(&format!("pipeline_depth = {}\n", self.pipeline_depth));
        s.push_str(&format!("steal = {}\n", self.steal));
        s.push_str(&format!("steal_min_queue = {}\n", self.steal_min_queue.max(1)));
        s.push_str("\n[controller]\n");
        s.push_str(&format!("adaptive = {}\n", self.adaptive));
        s.push_str(&format!("dwell_rounds = {}\n", self.dwell_rounds));
        s.push_str(&format!("improvement = {:.4}\n", self.improvement));
        s.push_str(&format!("slo_target = {:.4}\n", self.slo_target));
        s.push_str(&format!("max_lanes = {}\n", self.max_lanes.max(1)));
        s.push_str(&format!("max_depth = {}\n", self.pipeline_depth.max(1)));
        s
    }

    /// Round-trip the emitted fragment through the validated config path.
    /// Every candidate the tuner can generate must pass; the `tune` entry
    /// point asserts this for the winner before emitting anything.
    pub fn validated_config(&self) -> Result<ServerConfig, String> {
        ServerConfig::from_doc(&TomlDoc::parse(&self.to_toml())?)
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("adaptive", Json::Bool(self.adaptive)),
            ("lanes", Json::num(self.lanes as f64)),
            ("max_lanes", Json::num(self.max_lanes as f64)),
            ("pipeline_depth", Json::num(self.pipeline_depth as f64)),
            ("edf", Json::Bool(self.edf)),
            ("deadline_slack_s", Json::num(self.deadline_slack_s)),
            ("dwell_rounds", Json::num(self.dwell_rounds)),
            ("improvement", Json::num(self.improvement)),
            ("slo_target", Json::num(self.slo_target)),
            ("steal", Json::Bool(self.steal)),
            ("steal_min_queue", Json::num(self.steal_min_queue as f64)),
        ])
    }
}

/// One evaluated candidate: the replayed goodput and latency shape.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    pub point: TunePoint,
    pub label: String,
    /// Whole-trace SLO-met throughput, req/s (the score).
    pub goodput_rps: f64,
    /// Per-phase SLO-met throughput (hits of requests arriving in the
    /// phase, over the phase span).
    pub phase_goodput: [f64; 3],
    pub attainment: f64,
    pub completed: u64,
    pub reconfigs: u64,
    /// Launches rebalanced by the replay's work-stealing model (0 when
    /// the point has `steal == false`).
    pub steals: u64,
    pub p50_s: f64,
    pub p99_s: f64,
}

impl TuneOutcome {
    fn to_json(&self, rank: usize) -> Json {
        Json::obj(vec![
            ("rank", Json::num(rank as f64)),
            ("label", Json::str(self.label.clone())),
            ("goodput_rps", Json::num(self.goodput_rps)),
            ("slo_attainment", Json::num(self.attainment)),
            ("goodput_phase_a", Json::num(self.phase_goodput[0])),
            ("goodput_phase_b", Json::num(self.phase_goodput[1])),
            ("goodput_phase_c", Json::num(self.phase_goodput[2])),
            ("p50_s", Json::num(self.p50_s)),
            ("p99_s", Json::num(self.p99_s)),
            ("completed", Json::num(self.completed as f64)),
            ("reconfigs", Json::num(self.reconfigs as f64)),
            ("steals", Json::num(self.steals as f64)),
            ("point", self.point.to_json()),
        ])
    }
}

/// The replay's model of the lane pool's back-of-queue stealing: while
/// the longest lane's tail launch would finish strictly sooner appended
/// to the shortest lane (and the victim still holds `min_queue`
/// launches), move it there. Mirrors `LanePool` semantics — owners run
/// their queue front to back, thieves append stolen work after their
/// own — so every launch's completion time weakly decreases and the
/// round makespan never grows. Deterministic: ties pick the lowest lane.
fn steal_rebalance(
    lane_q: &mut [Vec<usize>],
    stolen: &mut Vec<(usize, usize)>,
    durs: &[f64],
    min_queue: usize,
) -> u64 {
    let mut total: Vec<f64> = lane_q
        .iter()
        .map(|q| q.iter().map(|&i| durs[i]).sum())
        .collect();
    let mut steals = 0u64;
    loop {
        let (mut v, mut th) = (0usize, 0usize);
        for l in 1..total.len() {
            if total[l] > total[v] {
                v = l;
            }
            if total[l] < total[th] {
                th = l;
            }
        }
        if v == th || lane_q[v].len() < min_queue.max(1) {
            break;
        }
        let Some(&cand) = lane_q[v].last() else { break };
        if total[v] - total[th] <= durs[cand] {
            break;
        }
        lane_q[v].pop();
        stolen.push((th, cand));
        total[v] -= durs[cand];
        total[th] += durs[cand];
        steals += 1;
    }
    steals
}

/// Replay the fig12 trace through the real `SpaceTimeSched` (and, when
/// `point.adaptive`, the real `AdaptiveController` via `set_lanes` — the
/// driver's reconfiguration path) on a simulated clock with gpusim
/// ground-truth launch durations. Port of the fig12 bench's `run()` with
/// the knobs opened up; at [`TunePoint::reference`] it is the same replay.
pub fn evaluate(point: &TunePoint) -> TuneOutcome {
    let spec = DeviceSpec::v100();
    let tr = trace();
    let base = Instant::now();
    let plan_charge_s = if point.pipeline_depth == 1 { PLAN_OVERHEAD_S } else { 0.0 };
    let mut sched = SpaceTimeSched::new(vec![1, 2, 4, 8, 16, 32, 64], MAX_BATCH)
        .spatial_lanes(point.lanes, None);
    if point.edf {
        let cost = Arc::new(Mutex::new(CostModel::with_spec(DeviceSpec::v100())));
        sched = sched.deadline_aware(cost, point.deadline_slack_s);
    }
    let mut ctl = point.adaptive.then(|| {
        AdaptiveController::new(
            ControllerParams {
                max_lanes: point.max_lanes.max(1),
                max_depth: 1, // the replay models no pipeline decisions
                dwell_rounds: point.dwell_rounds,
                improvement: point.improvement,
                slo_target: point.slo_target,
            },
            Decision { lanes: point.lanes, depth: 1 },
        )
    });
    if point.adaptive {
        sched.set_lanes(point.lanes);
    }
    let mut tracker = SignalTracker::default();
    let mut q = QueueSet::new(N_LAT + N_BATCH, 1 << 16);
    let mut idx = 0usize;
    let mut t = 0.0f64;
    let mut hits = 0u64;
    let mut misses = 0u64;
    let mut win_hits = 0u64;
    let mut win_misses = 0u64;
    let mut phase_hits = [0u64; 3];
    let mut completed = 0u64;
    let mut steals = 0u64;
    let mut lanes_seen: HashMap<usize, u64> = HashMap::new();
    let mut lanes_now = point.lanes;
    let mut latencies = Vec::with_capacity(tr.len());
    loop {
        while idx < tr.len() && tr[idx].0 <= t {
            let (arr, tenant) = tr[idx];
            let arrived = base + Duration::from_secs_f64(arr);
            q.push(InferenceRequest {
                id: idx as u64,
                tenant,
                class: tenant_class(tenant),
                payload: vec![],
                arrived,
                deadline: arrived + Duration::from_secs_f64(tenant_slo_s(tenant)),
                priority: Priority::Normal,
                trace_id: 0,
            })
            .expect("tuner queues are effectively unbounded");
            idx += 1;
        }
        if q.is_empty() {
            match tr.get(idx) {
                Some(&(next, _)) => {
                    t = next; // idle-skip to the next arrival
                    continue;
                }
                None => break,
            }
        }
        if let Some(ctl) = &mut ctl {
            if ctl.tick() {
                let now = base + Duration::from_secs_f64(t);
                let signals = ControlSignals {
                    backlog: q.total_pending(),
                    arrival_rate: q.arrival_rate(now),
                    launches_per_round: tracker.launches_per_round(),
                    requests_per_round: tracker.requests_per_round(),
                    mean_launch_s: tracker.mean_launch_s(),
                    plan_s: plan_charge_s,
                    stretch: tracker
                        .stretch_table(point.max_lanes.max(1), |n| spec.lane_stretch(n as u32)),
                    slo_attainment: if win_hits + win_misses > 0 {
                        Some(win_hits as f64 / (win_hits + win_misses) as f64)
                    } else {
                        None
                    },
                    min_slo_s: LAT_SLO_S,
                    steal_rate: 0.0,
                };
                let decision = ctl.decide(&signals);
                win_hits = 0;
                win_misses = 0;
                if decision.lanes != lanes_now {
                    lanes_now = decision.lanes;
                    sched.set_lanes(lanes_now);
                }
            }
        }
        let now = base + Duration::from_secs_f64(t);
        let plan = sched.plan_round_at(&mut q, now);
        // Serial round loop: planning blocks the device before anything
        // launches. Pipelined depth hides this entirely.
        t += plan_charge_s;
        let drained = plan.drained;
        let active = plan.lanes_used().max(1);
        *lanes_seen.entry(active).or_default() += 1;
        let n_lanes = plan.n_lanes.max(1);
        let durs: Vec<f64> = plan
            .launches
            .iter()
            .map(|l| ground_truth(&spec, l.class, l.r_bucket, active))
            .collect();
        if ctl.is_some() {
            for (i, launch) in plan.launches.iter().enumerate() {
                let solo = ground_truth(&spec, launch.class, launch.r_bucket, 1);
                tracker.observe_launch(solo);
                if active > 1 {
                    tracker.observe_stretch(active, durs[i] / solo.max(1e-12));
                }
            }
        }
        // Per-lane queues in plan order; stealing (when enabled) moves
        // tail launches of the longest lane onto the shortest one.
        let mut lane_q: Vec<Vec<usize>> = vec![Vec::new(); n_lanes];
        for i in 0..plan.launches.len() {
            lane_q[plan.lane(i)].push(i);
        }
        let mut stolen: Vec<(usize, usize)> = Vec::new();
        if point.steal {
            steals += steal_rebalance(&mut lane_q, &mut stolen, &durs, point.steal_min_queue);
        }
        let mut lane_time = vec![0.0f64; n_lanes];
        let mut done_s = vec![0.0f64; plan.launches.len()];
        for (lane, q) in lane_q.iter().enumerate() {
            for &i in q {
                lane_time[lane] += durs[i];
                done_s[i] = lane_time[lane];
            }
        }
        for &(th, i) in &stolen {
            lane_time[th] += durs[i];
            done_s[i] = lane_time[th];
        }
        for (i, launch) in plan.launches.iter().enumerate() {
            let done = base + Duration::from_secs_f64(t + done_s[i]);
            for e in &launch.entries {
                completed += 1;
                let arr_s = e.arrived.duration_since(base).as_secs_f64();
                latencies.push(done.duration_since(e.arrived).as_secs_f64());
                if done <= e.deadline {
                    hits += 1;
                    win_hits += 1;
                    phase_hits[phase_of(arr_s)] += 1;
                } else {
                    misses += 1;
                    win_misses += 1;
                }
            }
        }
        if ctl.is_some() {
            tracker.observe_round(plan.launches.len(), drained, plan_charge_s);
        }
        t += lane_time.iter().cloned().fold(0.0, f64::max);
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let spans = [PH_A, PH_B, PH_C];
    TuneOutcome {
        point: *point,
        label: point.label(),
        goodput_rps: hits as f64 / HORIZON,
        phase_goodput: [
            phase_hits[0] as f64 / spans[0],
            phase_hits[1] as f64 / spans[1],
            phase_hits[2] as f64 / spans[2],
        ],
        attainment: hits as f64 / (hits + misses).max(1) as f64,
        completed,
        reconfigs: ctl.as_ref().map_or(0, |c| c.reconfigs()),
        steals,
        p50_s: stats::percentile(&latencies, 50.0),
        p99_s: stats::percentile(&latencies, 99.0),
    }
}

// ---------------------------------------------------------------------------
// The search: deterministic grid + greedy local refinement.
// ---------------------------------------------------------------------------

/// The coarse grid, reference configuration first, duplicates removed.
/// Deterministic: same list on every call.
pub fn candidates() -> Vec<TunePoint> {
    let mut out = vec![TunePoint::reference()];
    for &lanes in &[1usize, 2, 4] {
        for &depth in &[2usize, 1] {
            for &(edf, slack) in &[(false, 0.0), (true, 0.002)] {
                // Stealing only has work to move with >= 2 lanes; the
                // lanes == 1 steal variants would be duplicates.
                let steal_axis: &[(bool, usize)] =
                    if lanes >= 2 { &[(false, 1), (true, 1), (true, 2)] } else { &[(false, 1)] };
                for &(steal, steal_min_queue) in steal_axis {
                    out.push(TunePoint {
                        adaptive: false,
                        lanes,
                        max_lanes: lanes,
                        pipeline_depth: depth,
                        edf,
                        deadline_slack_s: slack,
                        dwell_rounds: 4,
                        improvement: 0.10,
                        slo_target: 0.99,
                        steal,
                        steal_min_queue,
                    });
                }
            }
        }
    }
    for &max_lanes in &[4usize, 2] {
        for &depth in &[2usize, 1] {
            for &dwell in &[4u32, 2, 8] {
                for &improvement in &[0.10f64, 0.05] {
                    for &slo_target in &[0.99f64, 0.95] {
                        out.push(TunePoint {
                            adaptive: true,
                            lanes: 1,
                            max_lanes,
                            pipeline_depth: depth,
                            edf: false,
                            deadline_slack_s: 0.0,
                            dwell_rounds: dwell,
                            improvement,
                            slo_target,
                            steal: false,
                            steal_min_queue: 1,
                        });
                    }
                }
            }
        }
    }
    // Work-conserving adaptive variant: the controller plus stealing.
    out.push(TunePoint { steal: true, ..TunePoint::reference() });
    dedup(out)
}

/// Single-knob perturbations of `p`, all within the validated config
/// ranges. The refinement loop evaluates these around each new incumbent.
pub fn neighbors(p: &TunePoint) -> Vec<TunePoint> {
    let mut out = Vec::new();
    let lane_steps: &[usize] = &[1, 2, 4, 8];
    if p.adaptive {
        for &ml in lane_steps {
            if ml != p.max_lanes {
                out.push(TunePoint { max_lanes: ml, ..*p });
            }
        }
        for &dw in &[p.dwell_rounds.saturating_sub(p.dwell_rounds / 2).max(1), p.dwell_rounds * 2]
        {
            if dw != p.dwell_rounds && dw <= 64 {
                out.push(TunePoint { dwell_rounds: dw, ..*p });
            }
        }
        for &imp in &[p.improvement * 0.5, p.improvement * 2.0] {
            if imp > 1e-4 && imp <= 1.0 {
                out.push(TunePoint { improvement: imp, ..*p });
            }
        }
        out.push(TunePoint {
            slo_target: if p.slo_target >= 0.99 { 0.95 } else { 0.99 },
            ..*p
        });
    } else {
        for &l in lane_steps {
            if l != p.lanes {
                out.push(TunePoint { lanes: l, max_lanes: l, ..*p });
            }
        }
        out.push(TunePoint { adaptive: true, lanes: 1, max_lanes: 4, ..*p });
    }
    out.push(TunePoint {
        pipeline_depth: if p.pipeline_depth == 1 { 2 } else { 1 },
        ..*p
    });
    if p.edf {
        for &s in &[p.deadline_slack_s * 0.5, (p.deadline_slack_s * 2.0).max(0.001)] {
            if (s - p.deadline_slack_s).abs() > 1e-12 && s <= 0.1 {
                out.push(TunePoint { deadline_slack_s: s, ..*p });
            }
        }
        out.push(TunePoint { edf: false, deadline_slack_s: 0.0, ..*p });
    } else {
        out.push(TunePoint { edf: true, deadline_slack_s: 0.002, ..*p });
    }
    if p.steal {
        for &mq in &[1usize, 2, 4] {
            if mq != p.steal_min_queue {
                out.push(TunePoint { steal_min_queue: mq, ..*p });
            }
        }
        out.push(TunePoint { steal: false, steal_min_queue: 1, ..*p });
    } else {
        out.push(TunePoint { steal: true, steal_min_queue: 1, ..*p });
    }
    dedup(out)
}

fn dedup(points: Vec<TunePoint>) -> Vec<TunePoint> {
    let mut out: Vec<TunePoint> = Vec::with_capacity(points.len());
    for p in points {
        if !out.contains(&p) {
            out.push(p);
        }
    }
    out
}

/// The full tuning report: every evaluated point plus the winner.
#[derive(Debug, Clone)]
pub struct TuneReport {
    pub workload: String,
    pub budget: usize,
    pub outcomes: Vec<TuneOutcome>,
    /// Index of the winner in `outcomes`.
    pub best: usize,
}

impl TuneReport {
    pub fn best(&self) -> &TuneOutcome {
        &self.outcomes[self.best]
    }

    /// The winning `[server]`/`[controller]` TOML fragment with a
    /// provenance header. Already round-tripped through the validated
    /// config path by [`tune`].
    pub fn best_toml(&self) -> String {
        let b = self.best();
        format!(
            "# stgpu tune: workload '{}', {} candidates evaluated (budget {})\n\
             # winner: {} -> {:.1} req/s SLO-met goodput, attainment {:.4}\n{}",
            self.workload,
            self.outcomes.len(),
            self.budget,
            b.label,
            b.goodput_rps,
            b.attainment,
            b.point.to_toml()
        )
    }

    /// Leaderboard of every evaluated point, best first.
    pub fn leaderboard_json(&self) -> Json {
        let mut ranked: Vec<&TuneOutcome> = self.outcomes.iter().collect();
        ranked.sort_by(|a, b| b.goodput_rps.partial_cmp(&a.goodput_rps).unwrap());
        Json::obj(vec![
            ("workload", Json::str(self.workload.clone())),
            ("budget", Json::num(self.budget as f64)),
            ("evaluated", Json::num(self.outcomes.len() as f64)),
            ("best", self.best().to_json(1)),
            (
                "leaderboard",
                Json::Arr(
                    ranked
                        .iter()
                        .enumerate()
                        .map(|(i, o)| o.to_json(i + 1))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Tune `workload` (only `"fig12"` today) with at most `budget` replay
/// evaluations: the coarse grid first (about two thirds of the budget),
/// then greedy local refinement around the incumbent with the remainder.
/// Deterministic for a given (workload, budget).
pub fn tune(workload: &str, budget: usize) -> Result<TuneReport, String> {
    if workload != "fig12" {
        return Err(format!(
            "unknown tune workload {workload:?} (expected \"fig12\")"
        ));
    }
    let budget = budget.max(1);
    let grid = candidates();
    let grid_budget = if budget > 8 { (budget * 2).div_ceil(3) } else { budget };
    let mut outcomes: Vec<TuneOutcome> = Vec::with_capacity(budget);
    let mut best = 0usize;
    for p in grid.iter().take(grid_budget) {
        outcomes.push(evaluate(p));
        if outcomes.last().unwrap().goodput_rps > outcomes[best].goodput_rps {
            best = outcomes.len() - 1;
        }
    }
    // Greedy refinement: walk the incumbent's single-knob neighborhood,
    // restarting the frontier whenever the incumbent improves.
    let mut frontier = neighbors(&outcomes[best].point);
    let mut fi = 0usize;
    while outcomes.len() < budget && fi < frontier.len() {
        let p = frontier[fi];
        fi += 1;
        if outcomes.iter().any(|o| o.point == p) {
            continue;
        }
        outcomes.push(evaluate(&p));
        if outcomes.last().unwrap().goodput_rps > outcomes[best].goodput_rps {
            best = outcomes.len() - 1;
            frontier = neighbors(&p);
            fi = 0;
        }
    }
    let report = TuneReport {
        workload: workload.to_string(),
        budget,
        outcomes,
        best,
    };
    // The winner must survive the validated config path before anyone
    // writes it to disk.
    report.best().point.validated_config()?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidates_are_deterministic_and_start_at_reference() {
        let a = candidates();
        let b = candidates();
        assert_eq!(a, b, "candidate grid must be deterministic");
        assert_eq!(a[0], TunePoint::reference());
        for (i, p) in a.iter().enumerate() {
            assert!(
                !a[..i].contains(p),
                "duplicate candidate at index {i}: {p:?}"
            );
        }
        assert!(a.len() >= 32, "grid should cover the knob space");
        assert!(
            a.iter().any(|p| p.steal),
            "grid must cover work-conserving (steal) points"
        );
        assert!(
            a.iter().all(|p| !(p.steal && !p.adaptive && p.lanes < 2)),
            "single-lane static steal points are meaningless"
        );
    }

    #[test]
    fn stealing_never_hurts_the_static_replay() {
        // The replay's steal model only moves a tail launch when it
        // strictly finishes sooner on the shortest lane, so for the SAME
        // static plan every completion time weakly decreases: goodput and
        // attainment cannot regress with stealing on.
        let off = TunePoint {
            adaptive: false,
            lanes: 4,
            max_lanes: 4,
            pipeline_depth: 2,
            edf: false,
            deadline_slack_s: 0.0,
            dwell_rounds: 4,
            improvement: 0.10,
            slo_target: 0.99,
            steal: false,
            steal_min_queue: 1,
        };
        let on = TunePoint { steal: true, ..off };
        let a = evaluate(&off);
        let b = evaluate(&on);
        assert_eq!(a.steals, 0, "steal-off must never rebalance");
        assert!(
            b.goodput_rps >= a.goodput_rps,
            "stealing regressed goodput: {} -> {}",
            a.goodput_rps,
            b.goodput_rps
        );
        assert!(b.attainment >= a.attainment);
        assert_eq!(a.completed, b.completed, "stealing moves work, never drops it");
    }

    #[test]
    fn steal_knobs_round_trip_through_toml_and_json() {
        let p = TunePoint { steal: true, steal_min_queue: 2, ..TunePoint::reference() };
        let cfg = p.validated_config().unwrap();
        assert!(cfg.steal);
        assert_eq!(cfg.steal_min_queue, 2);
        let j = p.to_json();
        assert_eq!(j.get("steal").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("steal_min_queue").and_then(Json::as_usize), Some(2));
        assert!(p.label().contains("steal(min=2)"));
        let out = evaluate(&p);
        let row = out.to_json(1);
        assert!(row.get("steals").and_then(Json::as_f64).is_some());
    }

    #[test]
    fn every_candidate_and_neighbor_emits_valid_toml() {
        for p in candidates() {
            let cfg = p
                .validated_config()
                .unwrap_or_else(|e| panic!("{}: {e}", p.label()));
            assert_eq!(cfg.lanes, p.lanes);
            assert_eq!(cfg.pipeline_depth, p.pipeline_depth);
            assert_eq!(cfg.edf, p.edf);
            assert_eq!(cfg.controller.adaptive, p.adaptive);
            assert_eq!(cfg.controller.dwell_rounds, p.dwell_rounds);
            assert_eq!(cfg.controller.max_lanes, p.max_lanes.max(1));
            assert!((cfg.controller.improvement - p.improvement).abs() < 1e-4);
            assert!((cfg.controller.slo_target - p.slo_target).abs() < 1e-4);
            assert!((cfg.deadline_slack - p.deadline_slack_s).abs() < 1e-6);
            assert_eq!(cfg.steal, p.steal);
            assert_eq!(cfg.steal_min_queue, p.steal_min_queue.max(1));
            for n in neighbors(&p) {
                n.validated_config()
                    .unwrap_or_else(|e| panic!("neighbor of {}: {e}", p.label()));
            }
        }
    }

    #[test]
    fn unknown_workload_is_rejected() {
        assert!(tune("fig99", 4).is_err());
    }

    #[test]
    fn reference_point_beats_committed_fig12_baseline() {
        // The replay at the reference knobs reproduces the fig12 bench's
        // adaptive run, so its goodput must clear the committed baseline
        // (bench_gate enforces the same floor on the bench itself).
        let baseline =
            Json::parse(include_str!("../../bench_baselines/BENCH_fig12_adaptive_lanes.json"))
                .expect("committed baseline parses");
        let floor = baseline
            .get("throughput")
            .and_then(Json::as_f64)
            .expect("baseline has a throughput");
        let out = evaluate(&TunePoint::reference());
        assert!(
            out.goodput_rps >= floor,
            "reference goodput {:.1} req/s below committed fig12 baseline {floor:.1}",
            out.goodput_rps
        );
        assert!(out.reconfigs > 0, "reference replay never reconfigured");
        assert!(out.attainment > 0.5 && out.attainment <= 1.0);
    }

    #[test]
    fn tune_emits_validated_winner_and_leaderboard() {
        let report = tune("fig12", 2).unwrap();
        assert_eq!(report.outcomes.len(), 2, "budget caps evaluations");
        assert_eq!(report.outcomes[0].point, TunePoint::reference());
        let toml = report.best_toml();
        assert!(toml.starts_with("# stgpu tune:"));
        assert!(
            ServerConfig::from_doc(&TomlDoc::parse(&toml).unwrap()).is_ok(),
            "emitted TOML (with header comments) must load through the validated path"
        );
        let lb = report.leaderboard_json();
        assert_eq!(lb.get("workload").and_then(Json::as_str), Some("fig12"));
        assert_eq!(lb.get("evaluated").and_then(Json::as_f64), Some(2.0));
        let rows = lb.get("leaderboard").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 2);
        let top = rows[0].get("goodput_rps").and_then(Json::as_f64).unwrap();
        let second = rows[1].get("goodput_rps").and_then(Json::as_f64).unwrap();
        assert!(top >= second, "leaderboard sorted best-first");
        assert_eq!(
            report.best().goodput_rps,
            report
                .outcomes
                .iter()
                .map(|o| o.goodput_rps)
                .fold(f64::NEG_INFINITY, f64::max),
            "winner is the evaluated maximum"
        );
        // Round-trip: the leaderboard JSON re-parses.
        assert!(Json::parse(&lb.to_string()).is_ok());
    }
}
